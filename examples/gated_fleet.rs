//! Motion-gated detection, end to end, in both engine modes.
//!
//! Part 1 (virtual time): the content sweep — gated vs always-detect
//! across the lobby/highway/sports content-dynamics presets, showing
//! the gate trading quiet frames for effective per-device FPS while
//! sustained-motion content passes through untouched.
//!
//! Part 2 (replay): a gated lobby run's full wire log — admission
//! decisions plus origin-tagged gate verdicts — encodes to JSON and
//! decodes back verbatim, the same `EventLog` contract every other
//! control-plane producer honours.
//!
//! Part 3 (wall clock): the same gate inside `serve_fleet` on OS
//! threads, skipping quiet frames of a rastered lobby-style clip before
//! they reach a worker.
//!
//! ```sh
//! cargo run --release --example gated_fleet
//! ```

use std::time::Duration;

use eva::control::{ControlOrigin, EventLog};
use eva::detector::Detector;
use eva::experiments::fleet::pool_of;
use eva::experiments::gate::content_sweep;
use eva::fleet::{
    run_fleet_with, serve_fleet_logged, AdmissionPolicy, FleetServeConfig, Scenario, StreamSpec,
};
use eva::gate::{GateConfig, MotionDynamics};
use eva::types::{Detection, Frame};
use eva::video::{generate, presets};

/// Ground-truth echo with a fixed service delay (stands in for a real
/// accelerator in the wall-clock part).
struct EchoDetector {
    delay: Duration,
}

impl Detector for EchoDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        std::thread::sleep(self.delay);
        frame
            .ground_truth
            .iter()
            .map(|gt| Detection {
                bbox: gt.bbox,
                class_id: gt.class_id,
                score: 0.9,
            })
            .collect()
    }

    fn label(&self) -> String {
        "echo".into()
    }
}

fn main() {
    // ---- Part 1: content sweep (virtual time) ---------------------------
    println!("== gated vs always-detect across content-dynamics presets ==\n");
    let (table, outcomes) = content_sweep(7);
    print!("{}", table.render());
    for pair in outcomes.chunks(2) {
        let (plain, gated) = (&pair[0], &pair[1]);
        println!(
            "[gate/sim] {}: effective device FPS {:.1} -> {:.1} ({:.2}x) at {:+.2}% mAP",
            plain.preset,
            plain.effective_device_fps,
            gated.effective_device_fps,
            gated.effective_device_fps / plain.effective_device_fps,
            (gated.delivered_map - plain.delivered_map) / plain.delivered_map * 100.0,
        );
    }

    // ---- Part 2: the gated wire log replays verbatim --------------------
    let scenario = Scenario::new(
        pool_of(1, 18.0),
        vec![StreamSpec::new("lobby", 15.0, 450).with_window(4)],
    )
    .with_admission(AdmissionPolicy::admit_all())
    .with_seed(7)
    .with_gate(GateConfig::for_dynamics(MotionDynamics::lobby()));
    let out = run_fleet_with(&scenario, None);
    let log = out.wire_log();
    let decoded = EventLog::decode(&log.encode()).expect("gated wire log must decode");
    assert_eq!(decoded, log, "encode -> decode must be verbatim");
    let verdicts = log
        .events
        .iter()
        .filter(|e| e.origin == ControlOrigin::Gate)
        .count();
    println!(
        "\n[gate/wire] lobby run: {} wire events ({} gate verdicts) survive encode -> decode verbatim\n",
        log.len(),
        verdicts
    );

    // ---- Part 3: wall-clock gated serving -------------------------------
    // A short lobby-style clip (nearly static content) served paced at
    // 15 FPS by one worker; the gate drops quiet frames before they cost
    // worker time.
    // (The wall-clock gate keys its synthetic motion model off the
    // stream name, so a metadata-only tiny clip is enough here.)
    let clip = generate(&presets::tiny_clip(48, 60, 15.0, 11), None);
    let streams = vec![(
        &clip,
        StreamSpec::new("lobby", 15.0, 60).with_window(4),
    )];
    let config = FleetServeConfig {
        admission: AdmissionPolicy::default(),
        device_rates: vec![100.0],
        paced: true,
        gate: Some(GateConfig::for_dynamics(MotionDynamics::lobby())),
    };
    println!("== wall-clock gated serving: 1 x 15-FPS lobby stream, 1 worker ==\n");
    let (report, wire) = serve_fleet_logged(&streams, &config, |_| {
        Ok(Box::new(EchoDetector {
            delay: Duration::from_millis(2),
        }) as Box<dyn Detector>)
    })
    .expect("wall-clock gated run");
    print!("{}", report.stream_table().render());
    let gated_events = wire
        .events
        .iter()
        .filter(|e| e.origin == ControlOrigin::Gate)
        .count();
    println!(
        "\n[gate/wall] {} — {} gate verdicts on the wire log",
        report.summary(),
        gated_events
    );
}
