//! Scheduler tour: one homogeneous workload through all four schedulers,
//! reporting capacity, online drop rate, mAP, latency and reorder depth —
//! the full metrics surface of the coordinator.

use eva::coordinator::{run_online, RunConfig, SchedulerKind, SourceMode};
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, Fleet};
use eva::experiments::common::{map_against, quality_detectors, saturated_fps};
use eva::util::table::{f, pct, Table};
use eva::video::{generate, presets};

fn main() {
    let spec = presets::eth_sunnyday(5);
    let clip = generate(&spec, None);
    let fleet = Fleet::ncs2_sticks(4, DetectorModelId::Yolov3, LinkProfile::usb3());
    println!(
        "workload: {} (λ = {} FPS), fleet: 4× NCS2 (μ = 2.5 each)\n",
        spec.name, spec.fps
    );

    let mut t = Table::new(
        "All schedulers, 4×NCS2, ETH-Sunnyday",
        &["Scheduler", "σ_P (FPS)", "drop %", "mAP %", "p50 lat (ms)", "p99 lat (ms)", "reorder≤"],
    );
    for s in [
        SchedulerKind::RoundRobin,
        SchedulerKind::WeightedRoundRobin,
        SchedulerKind::Proportional,
        SchedulerKind::Fcfs,
    ] {
        let cap = saturated_fps(&clip, &fleet, s, 1);
        let cfg = RunConfig::new(s, SourceMode::Paced, 2);
        let run = run_online(&clip, &fleet, quality_detectors(&fleet, &spec.name, 3), &cfg);
        let dets: Vec<Vec<eva::types::Detection>> =
            run.records.iter().map(|r| r.detections.clone()).collect();
        let map = map_against(&clip, &dets);
        let mut m = run.metrics;
        t.row(vec![
            s.label().to_string(),
            f(cap, 1),
            f(m.drop_rate() * 100.0, 1),
            pct(map),
            f(m.latency.p50() * 1e3, 0),
            f(m.latency.p99() * 1e3, 0),
            format!("{}", m.max_reorder_depth),
        ]);
    }
    print!("{}", t.render());
    println!("\nhomogeneous fleets: all schedulers reach ≈ n·μ capacity (the");
    println!("paper's Table VII 'NCS2 Only' rows); they differ on latency and");
    println!("only diverge in throughput once the fleet is heterogeneous.");
}
