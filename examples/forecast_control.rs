//! Forecast-fused control, end to end, on the diurnal ramp.
//!
//! Part 1: a single stream's forecaster learns a square-wave day shape
//! — watch the prediction converge and the confidence band tighten.
//!
//! Part 2: the same diurnal load served twice — purely reactive, then
//! with the forecast layer armed. Reactive control only attaches
//! devices *after* the ramp lands (every attach sits in a high phase);
//! fused control pre-provisions in the low phase right before it, and
//! pays no extra migrations for the privilege.
//!
//! ```sh
//! cargo run --release --example forecast_control
//! ```

use eva::autoscale::ladder::ModelLadder;
use eva::experiments::forecast::{
    attach_phases, delivered_quality, diurnal_profile, diurnal_scenario, forecast_tuning,
};
use eva::forecast::StreamForecaster;
use eva::shard::run_sharded;

fn main() {
    // ---- Part 1: one forecaster learning the day shape ---------------
    let mut fc = StreamForecaster::new(forecast_tuning());
    println!("[forecast] learning a 1.4/2.8-FPS square wave (period 4):");
    for epoch in 0..16usize {
        let rate = if epoch % 4 >= 2 { 2.8 } else { 1.4 };
        fc.observe(rate);
        if let Some(f) = fc.forecast() {
            println!(
                "  epoch {epoch:2}: observed {rate:.1} -> predicts {:.2} ± {:<8}",
                f.rate,
                if f.band.is_finite() { format!("{:.2}", f.band) } else { "∞".into() },
            );
        }
    }

    // ---- Part 2: reactive vs fused on the full diurnal co-sim --------
    let reactive = run_sharded(&diurnal_scenario(29, false));
    let fused = run_sharded(&diurnal_scenario(29, true));
    let ladder = ModelLadder::from_profiles("eth_sunnyday");
    for (mode, report) in [("reactive", &reactive), ("fused", &fused)] {
        let (pre, post) = attach_phases(report);
        println!(
            "[{mode}] {} migrations, {} scale actions ({pre} pre-ramp, {post} post-step attaches), worst p99 {:.2}s, delivered quality {:.1}%, {} forecast digests",
            report.migrations,
            report.scale_actions(),
            report.worst_p99(),
            delivered_quality(report, &ladder) * 100.0,
            report.forecast_trace.len(),
        );
    }
    let (re_pre, _) = attach_phases(&reactive);
    let (fu_pre, _) = attach_phases(&fused);
    assert!(fu_pre > re_pre, "fused control must pre-provision");
    assert!(fused.migrations <= reactive.migrations);

    // The published forecast-Σλ trace: (epoch, shard, predicted Σλ) in
    // publish order — the slot that rides every gossip digest once the
    // band is tight. Show the first few.
    println!("[fused] first forecast digests (epoch, shard, predicted Σλ):");
    for (epoch, shard, rate) in fused.forecast_trace.iter().take(6) {
        println!("  epoch {epoch:2}, shard {shard}: {rate:.2} FPS");
    }
    // Attaches ahead of the ramp: every pre-ramp attach fired while the
    // day-shape multiplier was still 1.0.
    let profile = diurnal_profile();
    for c in &fused.control_log {
        if let Some(eva::control::ControlAction::AttachDevice(_)) = c.event.as_action() {
            if c.event.origin == eva::control::ControlOrigin::Controller
                && profile.multiplier_at(c.event.at) <= 1.0
            {
                println!(
                    "[fused] pre-ramp attach on shard {} at t={:.1}s (low phase)",
                    c.shard, c.event.at
                );
            }
        }
    }
    println!("OK: forecast fusion pre-provisions ahead of the ramp at no migration cost");
}
