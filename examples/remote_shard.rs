//! Cross-host sharded serving, end to end, over real sockets.
//!
//! Part 1: the 2-shard co-simulation runs with each fleet instance
//! behind its own loopback TCP socket — handshake, capacity gossip,
//! placement and epoch slices all cross length-prefixed frames — and is
//! compared against the in-process twin (delivered FPS matches).
//!
//! Part 2: connection loss. One of three shard sockets drops mid-run
//! (no goodbye); peer loss surfaces as shard loss and the orphaned
//! streams are re-placed on the survivors within one gossip interval.
//!
//! Part 3: a remote `fleet::serve` consumer on a Unix-domain socket: a
//! driver ships stream membership as control frames, the consumer
//! serves with real worker threads driven by the decoded event log, and
//! its admission decisions come back over the same wire.
//!
//! ```sh
//! cargo run --release --example remote_shard
//! ```

use eva::detector::Detector;
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::fleet::{AdmissionPolicy, FleetServeConfig, StreamSpec};
use eva::shard::{run_sharded, run_sharded_remote, RemoteTransport, ShardScenario};
use eva::transport::{drive_remote_serve, run_serve_consumer, Endpoint, Listener};
use eva::types::{Detection, Frame};

fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
    (0..n)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
        .collect()
}

/// Echoes ground truth (the wall-clock examples' stand-in detector).
struct EchoDetector;

impl Detector for EchoDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        std::thread::sleep(std::time::Duration::from_millis(2));
        frame
            .ground_truth
            .iter()
            .map(|gt| Detection {
                bbox: gt.bbox,
                class_id: gt.class_id,
                score: 0.9,
            })
            .collect()
    }

    fn label(&self) -> String {
        "echo".into()
    }
}

fn main() {
    // ---- Part 1: loopback TCP vs in-process parity --------------------
    let streams: Vec<StreamSpec> = [4.0, 2.0, 3.0, 2.0, 4.0, 2.0, 3.0, 2.0]
        .iter()
        .enumerate()
        .map(|(i, &fps)| {
            StreamSpec::new(&format!("cam{i}"), fps, (fps * 40.0) as u64).with_window(4)
        })
        .collect();
    let scenario = ShardScenario::builder(vec![pool(5, 2.5), pool(5, 2.5)], streams)
        .gossip(5.0)
        .epochs(10)
        .seed(7)
        .build();

    println!("== remote sharding: 8 streams over 2 fleet instances behind TCP sockets ==\n");
    let remote = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");
    print!("{}", remote.stream_table().render());
    print!("{}", remote.shard_table().render());
    let inproc = run_sharded(&scenario);
    println!(
        "delivered σ = {:.2} FPS over TCP vs {:.2} FPS in-process ({:.3}×), {} control frames crossed the wire\n",
        remote.delivered_fps(),
        inproc.delivered_fps(),
        remote.delivered_fps() / inproc.delivered_fps().max(1e-9),
        remote.control_log.len(),
    );

    // ---- Part 2: connection loss --------------------------------------
    let streams: Vec<StreamSpec> = (0..9)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 150).with_window(4))
        .collect();
    let scenario = ShardScenario::builder(
        vec![pool(4, 2.5), pool(4, 2.5), pool(4, 2.5)],
        streams,
    )
    .gossip(10.0)
    .epochs(8)
    .seed(11)
    .failure(2, 0)
    .build();
    let report = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");

    println!("== connection loss: shard 0's socket drops at epoch 2, no goodbye ==\n");
    print!("{}", report.stream_table().render());
    println!(
        "{} orphans, worst re-placement gap {:.1} s (gossip interval {:.1} s), all within one interval: {}\n",
        report.orphan_count(),
        report.worst_orphan_gap(),
        report.gossip_interval,
        report.orphans_replaced_within(report.gossip_interval),
    );

    // ---- Part 3: remote fleet::serve consumer over UDS ----------------
    println!("== remote serve consumer: wall-clock fleet driven by a decoded event log ==\n");
    let endpoint = Endpoint::temp_uds("example-serve");
    let listener = Listener::bind(&endpoint).expect("bind consumer socket");
    let config = FleetServeConfig {
        admission: AdmissionPolicy::default(),
        device_rates: vec![120.0, 120.0],
        paced: false,
        gate: None,
    };
    let consumer = std::thread::spawn(move || {
        run_serve_consumer(&listener, &config, |_| {
            Ok(Box::new(EchoDetector) as Box<dyn Detector>)
        })
    });
    let specs = vec![
        StreamSpec::new("remote-a", 20.0, 60).with_window(4),
        StreamSpec::new("remote-b", 20.0, 60).with_window(4),
    ];
    let outcome = drive_remote_serve(&endpoint, &specs).expect("drive consumer");
    for ev in &outcome.decisions {
        println!("  decision frame <- {}", ev.encode());
    }
    println!(
        "consumer processed {} frames across {} streams ({:.2} s busy)",
        outcome.processed,
        outcome.streams.len(),
        outcome.busy,
    );
    let served = consumer
        .join()
        .expect("consumer thread")
        .expect("consumer run")
        .expect("consumer served");
    assert_eq!(served.1.len(), outcome.decisions.len());
    println!("driver and consumer agree on {} admission decisions", outcome.decisions.len());
}
