//! End-to-end serving driver (the repo's required full-stack proof):
//! loads the AOT-compiled TinyDet (L1 Pallas matmul inside, L2 JAX graph,
//! compiled once at build time), generates a real synthetic clip with
//! pixels, and serves it through the L3 real-time pipeline — paced
//! ingestion, FCFS worker pool, sequence synchronizer — reporting
//! latency, throughput, drop rate and measured mAP.
//!
//! Run `make artifacts` first, then:
//!
//! ```sh
//! cargo run --release --example edge_serving            # defaults
//! EVA_WORKERS=4 EVA_FPS=30 cargo run --release --example edge_serving
//! ```
//!
//! Python is NOT on this path: the binary only reads artifacts/*.hlo.txt.

use std::path::PathBuf;

use anyhow::{anyhow, Result};
use eva::detector::pjrt::PjrtDetectorFactory;
use eva::detector::Detector;
use eva::experiments::common::map_against;
use eva::runtime::{load_manifest, ModelSpec};
use eva::server::{serve, ServeConfig};
use eva::video::{generate, presets};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> Result<()> {
    let model: String = env_or("EVA_MODEL", "essd".to_string());
    let workers: usize = env_or("EVA_WORKERS", 3);
    let fps: f64 = env_or("EVA_FPS", 20.0);
    let frames: u32 = env_or("EVA_FRAMES", 120);
    let seed: u64 = env_or("EVA_SEED", 7);
    // Emulated accelerator service time (ms): real TinyDet inference takes
    // ~3 ms on this host CPU, so without a throttle λ ≪ μ and the paper's
    // regime never appears. 150 ms ≈ a 6.7 FPS NCS2-class device (the
    // paper's substitution, DESIGN.md §3). Set 0 to disable.
    let throttle_ms: u64 = env_or("EVA_THROTTLE_MS", 150);

    let dir = PathBuf::from(env_or("EVA_ARTIFACTS", "artifacts".to_string()));
    // Missing artifacts is a skip, not a failure: the PJRT paths need
    // `make artifacts` (python + real xla), which CI and the offline
    // build containers don't have — same convention as the PJRT tests.
    // A manifest that exists but fails to load is a real error: a broken
    // artifact pipeline must not be green-lit as "skipped".
    if !dir.join("manifest.json").exists() {
        println!(
            "skipping edge_serving: no manifest at {}",
            dir.join("manifest.json").display()
        );
        println!("hint: run `make artifacts` first to exercise the PJRT path");
        return Ok(());
    }
    let manifest = load_manifest(&dir)
        .map_err(|e| anyhow!("{e}\nhint: re-run `make artifacts`; the manifest is unreadable"))?;
    let meta = manifest
        .get(&model)
        .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?
        .clone();
    println!(
        "model {}: input {}x{}, grid {}x{}, {} params, {:.1} MFLOPs/frame",
        meta.name,
        meta.input_size,
        meta.input_size,
        meta.grid,
        meta.grid,
        meta.params,
        meta.flops_per_frame as f64 / 1e6,
    );

    let mut factory = PjrtDetectorFactory::new(ModelSpec::new(meta.clone()));
    if throttle_ms > 0 {
        factory = factory
            .with_min_service(std::time::Duration::from_millis(throttle_ms));
        println!(
            "emulated accelerator: ≥{throttle_ms} ms/frame (μ ≈ {:.1} FPS per replica)",
            1000.0 / throttle_ms as f64
        );
    }
    let size = meta.input_size;
    println!("generating clip: {frames} frames @ {fps} FPS, {size}x{size}, seed {seed}");
    let mut spec = presets::tiny_clip(size, frames, fps, seed);
    // Street-scene object speeds (so stale boxes misalign measurably).
    spec.min_speed = 0.35;
    spec.max_speed = 0.80;
    let clip = generate(&spec, Some(size));

    // Serve single-replica first (the paper's "single AI hardware"
    // baseline), then the parallel pool.
    for (label, w) in [("single replica", 1usize), ("parallel pool", workers)] {
        let cfg = ServeConfig {
            workers: w,
            window: None,
            paced: true,
        };
        let report = serve(&clip, &cfg, |worker| {
            let det = factory.build()?;
            if worker == 0 {
                println!("  [worker 0] {} ready", det.label());
            }
            Ok(Box::new(det) as Box<dyn Detector>)
        })?;
        let mut m = report.metrics;
        let dets: Vec<Vec<eva::types::Detection>> =
            report.records.iter().map(|r| r.detections.clone()).collect();
        let map = map_against(&clip, &dets);
        println!("\n== {label} (workers = {w}) ==");
        println!("  {}", m.summary());
        println!(
            "  throughput {:.1} FPS over {:.2}s wall, mAP {:.1}%",
            m.frames_processed as f64 / report.wall.as_secs_f64(),
            report.wall.as_secs_f64(),
            map * 100.0
        );
        for (i, (frames, mean)) in report.worker_stats.iter().enumerate() {
            if *frames > 0 {
                println!("  worker {i}: {frames} frames, mean inference {:.1} ms", mean * 1e3);
            }
        }
    }
    Ok(())
}
