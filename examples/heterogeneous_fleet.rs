//! Heterogeneous fleet study (the Table VII scenario as a library user
//! would write it): a fast CPU plus a growing pile of NCS2 sticks, under
//! every scheduler — showing why FCFS is the paper's default and how the
//! performance-aware proportional scheduler closes most of the gap
//! without FCFS's opportunistic dispatch.

use eva::coordinator::SchedulerKind;
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, DeviceKind, Fleet};
use eva::experiments::common::saturated_fps;
use eva::util::table::{f, Table};
use eva::video::{generate, presets};

fn main() {
    let clip = generate(&presets::eth_sunnyday(3), None);
    let model = DetectorModelId::Yolov3;

    for cpu in [DeviceKind::FastCpu, DeviceKind::SlowCpu] {
        let mut t = Table::new(
            &format!("{} + n×NCS2 (YOLOv3, σ_P in FPS)", cpu.label()),
            &["n", "round-robin", "weighted-rr", "proportional", "fcfs", "ideal Σμ"],
        );
        for n in [1usize, 3, 5, 7] {
            let fleet = Fleet::cpu_plus_sticks(cpu, n, model, LinkProfile::usb3());
            let ideal = fleet.aggregate_rate();
            let mut row = vec![format!("{n}")];
            for s in [
                SchedulerKind::RoundRobin,
                SchedulerKind::WeightedRoundRobin,
                SchedulerKind::Proportional,
                SchedulerKind::Fcfs,
            ] {
                row.push(f(saturated_fps(&clip, &fleet, s, 11 + n as u64), 1));
            }
            row.push(f(ideal, 1));
            t.row(row);
        }
        print!("{}", t.render());
        println!();
    }

    println!("reading: RR barriers on the slowest member each round; FCFS is");
    println!("work-conserving; WRR/proportional recover most of the gap with");
    println!("weighted rounds (proportional needs no offline calibration).");
}
