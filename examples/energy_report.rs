//! Energy report: Table VI plus the per-frame energy extension, and a
//! measured busy-energy run showing what an n-stick fleet actually burns
//! serving a clip (idle-time excluded), via the engine's EnergyMeter.

use eva::coordinator::{run_online, RunConfig, SchedulerKind, SourceMode};
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, Fleet};
use eva::experiments::common::quality_detectors;
use eva::experiments::energy;
use eva::util::table::{f, Table};
use eva::video::{generate, presets};

fn main() {
    let (t6, _) = energy::table6();
    print!("{}", t6.render());
    println!();
    let (tj, _) = energy::joules_per_frame_comparison();
    print!("{}", tj.render());
    println!();

    // Measured busy energy for the ETH clip at different n.
    let spec = presets::eth_sunnyday(9);
    let clip = generate(&spec, None);
    let mut t = Table::new(
        "Measured busy energy serving ETH-Sunnyday (25.3 s of video)",
        &["n×NCS2", "processed", "dropped", "busy J", "J/frame", "mean util %"],
    );
    for n in [1usize, 4, 6, 7] {
        let fleet = Fleet::ncs2_sticks(n, DetectorModelId::Yolov3, LinkProfile::usb3());
        let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 4);
        let run = run_online(&clip, &fleet, quality_detectors(&fleet, &spec.name, 5), &cfg);
        let m = &run.metrics;
        let util: f64 =
            (0..n).map(|d| m.utilization(d)).sum::<f64>() / n as f64 * 100.0;
        t.row(vec![
            format!("{n}"),
            format!("{}", m.frames_processed),
            format!("{}", m.frames_dropped),
            f(m.energy.busy_joules(), 1),
            f(m.joules_per_frame(), 2),
            f(util, 0),
        ]);
    }
    print!("{}", t.render());
    println!("\nnote how J/frame stays ≈0.8 J while drops vanish: parallel sticks");
    println!("add capacity at constant per-frame energy — the paper's §IV-B point.");
}
