//! Stream sharding across fleet instances, end to end.
//!
//! Part 1: 8 mixed-rate streams are partitioned over 2 shards (each its
//! own device pool + admission) by least-loaded placement; the capacity
//! gossip keeps both shards inside the Σμ-vs-Σλ band. Prints per-stream
//! and per-shard results plus the serialised control log — every
//! placement and migration crossed the wire as a JSON `WireEvent`.
//!
//! Part 2: shard loss. One of three shards dies mid-run; its orphaned
//! streams are re-placed on the survivors within one gossip interval.
//!
//! Part 3: autoscale per shard. Round-robin parks 2× the admission
//! capacity on shard 0; with an embedded `AutoscaleController` the
//! shard grows its own pool (digests advertise post-scale headroom, so
//! the migration planner stays idle) and every scale action lands in
//! the coordinator's replayable audit log.
//!
//! ```sh
//! cargo run --release --example sharded_serving
//! ```

use eva::control::{ControlOrigin, EventLog};
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::experiments::shard::overload_scenario;
use eva::fleet::StreamSpec;
use eva::shard::{run_sharded, PlacementPolicy, ShardScenario};

fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
    (0..n)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
        .collect()
}

fn main() {
    // ---- Part 1: balanced sharding under mixed load -------------------
    let streams: Vec<StreamSpec> = [4.0, 2.0, 3.0, 2.0, 4.0, 2.0, 3.0, 2.0]
        .iter()
        .enumerate()
        .map(|(i, &fps)| {
            StreamSpec::new(&format!("cam{i}"), fps, (fps * 40.0) as u64).with_window(4)
        })
        .collect();
    let scenario = ShardScenario::builder(vec![pool(5, 2.5), pool(5, 2.5)], streams)
        .policy(PlacementPolicy::LeastLoaded)
        .gossip(5.0)
        .epochs(10)
        .seed(7)
        .build();
    let report = run_sharded(&scenario);

    println!("== sharded serving: 8 streams over 2 fleet instances ==\n");
    print!("{}", report.stream_table().render());
    print!("{}", report.shard_table().render());
    println!(
        "delivered σ = {:.2} FPS, drop rate {:.1}%, {} migrations, {} gossip epochs\n",
        report.delivered_fps(),
        report.drop_rate() * 100.0,
        report.migrations,
        report.epochs_run,
    );

    // Every control decision crossed the wire. Show the first few as the
    // shards received them, then prove the log survives a JSON hop.
    println!("serialised control log (first 6 events):");
    for c in report.control_log.iter().take(6) {
        println!("  shard {} <- {}", c.shard, c.event.encode());
    }
    let mut log = EventLog::new();
    for c in &report.control_log {
        log.push(c.event.clone());
    }
    let decoded = EventLog::decode(&log.encode()).expect("wire log round-trips");
    assert_eq!(decoded, log);
    println!(
        "wire log: {} events, {} bytes of JSON, decodes back identically\n",
        log.len(),
        log.encode().len(),
    );

    // ---- Part 2: shard loss and re-placement --------------------------
    let streams: Vec<StreamSpec> = (0..9)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 150).with_window(4))
        .collect();
    let scenario = ShardScenario::builder(
        vec![pool(4, 2.5), pool(4, 2.5), pool(4, 2.5)],
        streams,
    )
    .gossip(10.0)
    .epochs(8)
    .seed(11)
    .failure(2, 0)
    .build();
    let report = run_sharded(&scenario);

    println!("== shard loss: 1 of 3 instances dies at t = 20 s ==\n");
    print!("{}", report.stream_table().render());
    println!(
        "{} orphans, worst re-placement gap {:.1} s (gossip interval {:.1} s), all within one interval: {}\n",
        report.orphan_count(),
        report.worst_orphan_gap(),
        report.gossip_interval,
        report.orphans_replaced_within(report.gossip_interval),
    );

    // ---- Part 3: autoscale per shard at 2× load ------------------------
    let migrate_only = run_sharded(&overload_scenario(13, false));
    let scaled = run_sharded(&overload_scenario(13, true));

    println!("== autoscale per shard: 2× overload on shard 0 ==\n");
    println!(
        "migrate-only: {} migrations, {} scale actions, worst p99 {:.2} s",
        migrate_only.migrations,
        migrate_only.scale_actions(),
        migrate_only.worst_p99(),
    );
    println!(
        "autoscale:    {} migrations, {} scale actions, worst p99 {:.2} s",
        scaled.migrations,
        scaled.scale_actions(),
        scaled.worst_p99(),
    );
    assert!(scaled.migrations < migrate_only.migrations);
    println!("\nshard-local scale actions, as the coordinator audited them:");
    for c in scaled
        .control_log
        .iter()
        .filter(|c| c.event.origin == ControlOrigin::Controller)
        .take(6)
    {
        println!("  shard {} -> {}", c.shard, c.event.encode());
    }
    let audit = scaled.audit_log();
    let decoded = EventLog::decode(&audit.encode()).expect("audit log round-trips");
    assert_eq!(decoded, audit);
    println!(
        "audit log: {} events ({} scale actions), decodes back identically",
        audit.len(),
        scaled.scale_actions(),
    );
}
