//! Quickstart: the paper's headline result in ~40 lines.
//!
//! A 14 FPS stream hits a single NCS2-class detector (μ = 2.5 FPS):
//! heavy random dropping, mAP collapses. Run n = 6 replicas behind the
//! FCFS parallel-detection scheduler: throughput ≈ 15 FPS, dropping
//! vanishes, mAP recovers to the zero-drop baseline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use eva::coordinator::{nselect, SchedulerKind};
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, Fleet};
use eva::experiments::common::{online_map, saturated_fps, zero_drop_baseline};
use eva::video::{generate, presets};

fn main() {
    let spec = presets::eth_sunnyday(7);
    println!(
        "clip: {} — {} frames @ {} FPS ({}x{})",
        spec.name, spec.num_frames, spec.fps, spec.width, spec.height
    );
    let clip = generate(&spec, None);
    let model = DetectorModelId::Yolov3;

    // Zero-drop offline reference (Figure 1a).
    let (mu, map0) = zero_drop_baseline(&clip, model, 42);
    println!("\nzero-drop reference: μ = {mu} FPS, mAP = {:.1}%", map0 * 100.0);

    // §III-B: choose n.
    let band = nselect::recommended_range(spec.fps, mu);
    println!("recommended n ∈ [{}, {}]  (λ = {}, μ = {mu})", band.lo, band.hi, spec.fps);

    // Online, single device vs parallel detection.
    for n in [1usize, band.hi] {
        let fleet = Fleet::ncs2_sticks(n, model, LinkProfile::usb3());
        let sigma_p = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, 1);
        let (map, drop) = online_map(&clip, &fleet, SchedulerKind::Fcfs, 2);
        println!(
            "n = {n}: σ_P = {sigma_p:.1} FPS, drop rate = {:.1}%, mAP = {:.1}%",
            drop * 100.0,
            map * 100.0
        );
    }

    println!("\n(see `eva table --id 4` for the full Table IV sweep)");
}
