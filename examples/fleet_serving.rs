//! Multi-stream fleet serving, end to end, in both modes.
//!
//! Part 1 (virtual time): 8 paced streams — mixed rates and weights —
//! contend for a 4-device heterogeneous pool (fast CPU + 3 NCS2-class
//! sticks). Admission control degrades/rejects what the pool cannot
//! carry; mid-run a fifth device joins, showing the registry's dynamic
//! attach path. Prints per-stream and fleet-level metrics.
//!
//! Part 2 (wall clock): 3 paced streams served by 2 worker threads with
//! a real (if synthetic) detector doing per-frame work, through the same
//! admission/window/synchronizer machinery on OS threads.
//!
//! ```sh
//! cargo run --release --example fleet_serving
//! ```

use std::time::Duration;

use eva::detector::Detector;
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::fleet::{
    run_fleet, serve_fleet, AdmissionPolicy, ControlAction, ControlEvent, FleetServeConfig,
    Scenario, StreamSpec,
};
use eva::types::{Detection, Frame};
use eva::video::{generate, presets};

/// Ground-truth echo with a fixed service delay (stands in for a real
/// accelerator in the wall-clock part).
struct EchoDetector {
    delay: Duration,
}

impl Detector for EchoDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        std::thread::sleep(self.delay);
        frame
            .ground_truth
            .iter()
            .map(|gt| Detection {
                bbox: gt.bbox,
                class_id: gt.class_id,
                score: 0.9,
            })
            .collect()
    }

    fn label(&self) -> String {
        "echo".into()
    }
}

fn hetero_pool() -> Vec<DeviceInstance> {
    let mut devices = vec![DeviceInstance::new(
        DeviceKind::FastCpu,
        DetectorModelId::Yolov3,
        0,
    )];
    devices.extend(
        (0..3).map(|i| DeviceInstance::new(DeviceKind::Ncs2, DetectorModelId::Yolov3, i + 1)),
    );
    devices
}

fn main() {
    // ---- Part 1: virtual-time fleet -------------------------------------
    // Pool Σμ = 13.5 + 3×2.5 = 21 FPS; offered = 4×5 + 4×10 = 60 FPS
    // (≈ 2.9× overload): admission has real work to do.
    let mut streams = Vec::new();
    for i in 0..4 {
        streams.push(
            StreamSpec::new(&format!("cam{i}"), 5.0, 300)
                .with_window(4)
                .with_weight(1.0),
        );
    }
    for i in 0..4 {
        streams.push(
            StreamSpec::new(&format!("hd{i}"), 10.0, 600)
                .with_window(6)
                .with_weight(2.0),
        );
    }

    let scenario = Scenario::new(hetero_pool(), streams)
        .with_admission(AdmissionPolicy::default())
        .with_seed(7)
        .with_events(vec![ControlEvent {
            at: 30.0,
            action: ControlAction::AttachDevice(DeviceInstance::new(
                DeviceKind::Ncs2,
                DetectorModelId::Yolov3,
                4,
            )),
        }]);

    println!("== virtual-time fleet: 8 streams vs fast-CPU + 3×NCS2 (+1 NCS2 at t=30s) ==\n");
    let report = run_fleet(&scenario);
    print!("{}", report.stream_table().render());
    println!();
    print!("{}", report.device_table().render());
    println!("\n[fleet/sim] {}\n", report.summary());

    // ---- Part 2: wall-clock fleet ---------------------------------------
    // 3 streams × 20 FPS against 2 workers at 25 ms service each
    // (≈ 80 FPS pool): comfortable headroom, so nothing drops.
    let clips: Vec<_> = (0..3)
        .map(|i| generate(&presets::tiny_clip(32, 60, 20.0, 40 + i), None))
        .collect();
    let wall_streams: Vec<(&eva::video::Clip, StreamSpec)> = clips
        .iter()
        .enumerate()
        .map(|(i, clip)| {
            (
                clip,
                StreamSpec::new(&format!("live{i}"), 20.0, 60).with_window(4),
            )
        })
        .collect();
    let config = FleetServeConfig {
        admission: AdmissionPolicy::default(),
        device_rates: vec![40.0, 40.0],
        paced: true,
        gate: None,
    };

    println!("== wall-clock fleet: 3 × 20-FPS streams vs 2 workers (25 ms service) ==\n");
    let wall_report = serve_fleet(&wall_streams, &config, |_| {
        Ok(Box::new(EchoDetector {
            delay: Duration::from_millis(25),
        }) as Box<dyn Detector>)
    })
    .expect("wall-clock fleet run");
    print!("{}", wall_report.stream_table().render());
    println!();
    print!("{}", wall_report.device_table().render());
    println!("\n[fleet/wall] {}", wall_report.summary());
}
