//! End-to-end telemetry on a fleet run: span traces, stage budgets,
//! origin attribution, and the metric registry — all zero-dependency.
//!
//! Part 1 (stage budgets): the overload sweep traces every frame
//! through capture → admit → detect → deliver and decomposes delivered
//! p99 into per-stage contributions that sum to the end-to-end number
//! exactly (consecutive span timestamps partition the interval).
//!
//! Part 2 (attribution): traces join against the replayable `EventLog`
//! to attribute each frame's latency to the control class that last
//! touched its stream — gate verdicts, scripted events, or nothing.
//!
//! Part 3 (artifacts): one traced overload run dumped the way
//! `eva trace --metrics-out/--trace-out` writes it — JSONL span traces
//! plus a Prometheus-style text exposition — and the registry snapshot
//! round-tripped through its JSON codec.
//!
//! Part 4 (observer contract): tracing never perturbs virtual time.
//!
//! ```sh
//! cargo run --release --example traced_fleet
//! ```

use eva::experiments::telemetry::{attribution, overload_sweep, traced_run, tracing_overhead};
use eva::telemetry::{p99_breakdown, Registry, STAGES};

fn main() {
    // ---- Part 1: stage budgets across the load sweep --------------------
    println!("== p99 stage budgets across offered load ==\n");
    let (table, points) = overload_sweep(7);
    print!("{}", table.render());
    for p in &points {
        assert!(
            p.residue < 0.01,
            "stage budget must partition p99 within 1%: load {} residue {:.4}",
            p.load,
            p.residue
        );
    }
    let heavy = points.last().expect("sweep has points");
    println!(
        "[trace/budget] at {:.1}x load, queueing is {:.0}% of the p99 ({:.0} ms of {:.0} ms)\n",
        heavy.load,
        heavy.stages[1] / heavy.e2e_p99 * 100.0,
        heavy.stages[1] * 1e3,
        heavy.e2e_p99 * 1e3,
    );

    // ---- Part 2: latency by control origin ------------------------------
    println!("== delivered latency attributed to control origin ==\n");
    let (table, rows) = attribution(7);
    print!("{}", table.render());
    println!(
        "[trace/attr] {} control classes touched delivered frames\n",
        rows.len()
    );

    // ---- Part 3: the artifacts one traced run produces ------------------
    let out = traced_run(7);
    let tel = out.telemetry.as_ref().expect("traced run carries telemetry");
    let jsonl = tel.traces_jsonl();
    println!("== span traces (first 3 of {} JSONL lines) ==\n", tel.traces.len());
    for line in jsonl.lines().take(3) {
        println!("{line}");
    }
    let breakdown = p99_breakdown(&tel.traces).expect("overload run delivers frames");
    println!(
        "\n[trace/spans] delivered {} frames; p99 {:.0} ms = {}",
        breakdown.delivered,
        breakdown.e2e_p99 * 1e3,
        STAGES
            .iter()
            .zip(breakdown.stages.iter())
            .map(|(s, v)| format!("{s} {:.0} ms", v * 1e3))
            .collect::<Vec<_>>()
            .join(" + "),
    );
    let exposition = tel.registry.text_exposition();
    println!("\n== metric exposition (first 10 lines) ==\n");
    for line in exposition.lines().take(10) {
        println!("{line}");
    }
    let snapshot = tel.registry.to_json();
    let reparsed = Registry::from_json(&snapshot).expect("snapshot must round-trip");
    assert_eq!(
        reparsed.to_json().to_string(),
        snapshot.to_string(),
        "registry JSON codec must round-trip byte-identically"
    );
    println!("\n[trace/snapshot] registry JSON snapshot round-trips byte-identically");

    // ---- Part 4: tracing is a pure observer -----------------------------
    let (_, overhead) = tracing_overhead(7);
    assert!(
        overhead.virtual_identical,
        "tracing must not perturb virtual-time outputs"
    );
    println!(
        "[trace/overhead] virtual-time outputs identical under tracing; wall overhead {:.2}% over {} frames",
        overhead.wall_overhead * 100.0,
        overhead.frames,
    );
}
