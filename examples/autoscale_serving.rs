//! Closed-loop autoscale serving, end to end, in both engines.
//!
//! Part 1 (virtual time): the step-load scenario — 3 steady cams on a
//! 4-device pool, 5 more burst in at t=40 (≈ 2× overload) and leave at
//! t=100. Three policies run side by side: stride-only degradation,
//! quality-aware model-ladder admission, and the full closed loop
//! (ladder + device autoscaling). The table shows delivered mAP during
//! the overload, worst p99, and how fast full-quality models come back.
//!
//! Part 2 (wall clock): the same feedback law at epoch granularity over
//! real worker threads — an overloaded first epoch pushes the fleet one
//! ladder rung down (detectors actually get faster and coarser), and a
//! healthy epoch brings the full model back.
//!
//! ```sh
//! cargo run --release --example autoscale_serving
//! ```

use std::time::Duration;

use eva::autoscale::{AutoscaleConfig, ModelLadder, Rung};
use eva::detector::Detector;
use eva::experiments::autoscale as sweeps;
use eva::fleet::StreamSpec;
use eva::types::{Detection, Frame};
use eva::video::{generate, presets, Clip};

/// Ground-truth echo whose per-frame cost depends on the ladder rung.
struct RungEcho {
    delay: Duration,
}

impl Detector for RungEcho {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        std::thread::sleep(self.delay);
        frame
            .ground_truth
            .iter()
            .map(|gt| Detection {
                bbox: gt.bbox,
                class_id: gt.class_id,
                score: 0.9,
            })
            .collect()
    }

    fn label(&self) -> String {
        "rung-echo".into()
    }
}

fn main() {
    // ---- Part 1: virtual-time closed loop -------------------------------
    println!("== virtual time: 2× load step under three degradation policies ==\n");
    let (table, outcomes) = sweeps::step_load(7);
    print!("{}", table.render());
    let auto = &outcomes[2];
    println!(
        "\n[autoscale/sim] closed loop: peak {} devices, {} control actions, \
         full-quality restored {:.1}s after the burst left\n",
        auto.peak_devices, auto.control_actions, auto.recovery_seconds
    );

    // ---- Part 2: wall-clock epochs --------------------------------------
    // 2 × 25-FPS streams vs one worker: the full model costs 25 ms/frame
    // (≈ 40 FPS < 50 offered), the tiny rung 5 ms. Three epochs of 20
    // frames each: overload -> rung down -> healthy -> rung back up.
    println!("== wall clock: epoch-level feedback over real worker threads ==\n");
    let clips: Vec<Clip> = (0..2)
        .map(|i| generate(&presets::tiny_clip(32, 60, 25.0, 70 + i), None))
        .collect();
    let streams: Vec<(&Clip, StreamSpec)> = clips
        .iter()
        .enumerate()
        .map(|(i, c)| {
            (
                c,
                StreamSpec::new(&format!("live{i}"), 25.0, 60).with_window(2),
            )
        })
        .collect();
    let ladder = ModelLadder::pareto(vec![
        Rung { name: "full".into(), speedup: 1.0, quality: 0.86 },
        Rung { name: "tiny".into(), speedup: 5.0, quality: 0.60 },
    ]);
    let cfg = AutoscaleConfig {
        p99_bound: 0.25,
        max_drop_rate: 0.05,
        device_rate: 40.0,
        max_devices: 2,
        ..AutoscaleConfig::default()
    }
    .with_ladder(ladder);

    let points = eva::autoscale::run_autoscale_serve(&streams, &cfg, 1, 20, 3, |_, rung| {
        Ok(Box::new(RungEcho {
            delay: Duration::from_millis(if rung == 0 { 25 } else { 5 }),
        }) as Box<dyn Detector>)
    })
    .expect("wall-clock autoscale loop");

    for p in &points {
        println!(
            "[autoscale/wall] epoch {}: {} worker(s), rung {} -> \
             p99 {:.0} ms, {:.1}% dropped ({}/{} frames)",
            p.epoch,
            p.workers,
            p.rung,
            p.p99 * 1e3,
            p.drop_rate * 100.0,
            p.processed,
            p.frames,
        );
    }
}
