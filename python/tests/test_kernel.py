"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the core
correctness signal for everything the AOT artifact computes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as pconv
from compile.kernels import matmul as pmat
from compile.kernels import ref


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------- matmul --

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_fp32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.float32)
    y = _rand(rng, (k, n), jnp.float32)
    np.testing.assert_allclose(
        pmat.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_bf16(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.bfloat16)
    y = _rand(rng, (k, n), jnp.bfloat16)
    out = pmat.matmul(x, y)
    assert out.dtype == jnp.float32  # fp32 accumulate
    np.testing.assert_allclose(
        out, ref.matmul_ref(x, y), rtol=2e-2, atol=2e-2
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 32, 128, 256]),
    k=st.sampled_from([16, 128, 384]),
    n=st.sampled_from([8, 128, 256]),
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([32, 128]),
    bk=st.sampled_from([16, 128]),
)
def test_matmul_block_shape_invariance(m, k, n, bm, bn, bk):
    """Result must not depend on the chosen tiling."""
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    x = _rand(rng, (m, k), jnp.float32)
    y = _rand(rng, (k, n), jnp.float32)
    out = pmat.matmul(x, y, bm=bm, bn=bn, bk=bk)
    # Accumulation order differs across tilings: fp32 noise only.
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-3, atol=5e-4)


def test_matmul_shape_mismatch_raises():
    x = jnp.zeros((4, 5), jnp.float32)
    y = jnp.zeros((6, 3), jnp.float32)
    with pytest.raises(ValueError):
        pmat.matmul(x, y)


def test_matmul_identity():
    x = jnp.eye(32, dtype=jnp.float32)
    y = jnp.arange(32 * 8, dtype=jnp.float32).reshape(32, 8)
    np.testing.assert_allclose(pmat.matmul(x, y), y, rtol=1e-6)


def test_matmul_zeros():
    x = jnp.zeros((16, 24), jnp.float32)
    y = jnp.zeros((24, 8), jnp.float32)
    np.testing.assert_array_equal(pmat.matmul(x, y), jnp.zeros((16, 8)))


# ------------------------------------------------------------ block picker --

@settings(max_examples=60, deadline=None)
@given(dim=st.integers(1, 2048), pref=st.sampled_from([8, 64, 128, 256]))
def test_pick_block_divides(dim, pref):
    b = pmat._pick_block(dim, pref, 8)
    assert 1 <= b <= max(dim, 1)
    assert dim % b == 0
    assert b <= max(pref, dim if dim <= pref else pref)


def test_pick_block_prefers_aligned():
    # 256 has divisor 128 which is 128-aligned.
    assert pmat._pick_block(256, 128, 128) == 128
    # dim smaller than pref -> whole dim.
    assert pmat._pick_block(40, 128, 8) == 40


# ------------------------------------------------------------------- conv --

@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(6, 24),
    w=st.integers(6, 24),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    n=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_lax(h, w, cin, cout, k, stride, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, h, w, cin), jnp.float32)
    wt = _rand(rng, (k, k, cin, cout), jnp.float32)
    out = pconv.conv2d(x, wt, stride)
    np.testing.assert_allclose(
        out, ref.conv2d_ref(x, wt, stride), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 20),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_matches_ref(h, k, stride, seed):
    if h < k:
        return
    rng = np.random.default_rng(seed)
    x = _rand(rng, (2, h, h, 3), jnp.float32)
    np.testing.assert_array_equal(
        pconv.im2col(x, k, k, stride), ref.im2col_ref(x, k, k, stride)
    )


def test_conv2d_same_output_shape():
    x = jnp.zeros((1, 15, 15, 3), jnp.float32)
    w = jnp.zeros((3, 3, 3, 4), jnp.float32)
    assert pconv.conv2d_same(x, w, 2).shape == (1, 8, 8, 4)
    assert pconv.conv2d_same(x, w, 1).shape == (1, 15, 15, 4)


def test_conv2d_channel_mismatch_raises():
    x = jnp.zeros((1, 8, 8, 3), jnp.float32)
    w = jnp.zeros((3, 3, 4, 4), jnp.float32)
    with pytest.raises(ValueError):
        pconv.conv2d(x, w)


# ----------------------------------------------------- perf estimators ----

def test_vmem_footprint_within_budget():
    """Default blocks must fit comfortably in 16 MiB VMEM."""
    b = pmat.vmem_footprint_bytes(pmat.DEFAULT_BM, pmat.DEFAULT_BN, pmat.DEFAULT_BK)
    assert b < 16 * 1024 * 1024 // 4  # < 1/4 of VMEM: double-buffer headroom


def test_mxu_utilization_perfect_when_aligned():
    u = pmat.mxu_utilization_estimate(256, 256, 256, 128, 128, 128)
    assert abs(u - 1.0) < 1e-9


def test_mxu_utilization_degrades_when_misaligned():
    u = pmat.mxu_utilization_estimate(100, 100, 100, 50, 50, 50)
    assert 0 < u < 0.5
