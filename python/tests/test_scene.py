"""Scene-generator tests: bounds, determinism, class appearance contract."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.scene import CLASS_APPEARANCE, draw_object, make_batch, make_scene, render_background


def test_background_bounds_and_shape():
    rng = np.random.default_rng(0)
    img = render_background(rng, 64)
    assert img.shape == (64, 64, 3)
    assert img.min() >= 0.0 and img.max() <= 1.0
    # Grayish: channels identical up to the per-pixel noise.
    assert np.abs(img[..., 0] - img[..., 1]).max() < 0.15


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size=st.sampled_from([32, 64, 96]))
def test_make_scene_valid(seed, size):
    rng = np.random.default_rng(seed)
    img, boxes = make_scene(rng, size)
    assert img.shape == (size, size, 3)
    assert img.min() >= 0.0 and img.max() <= 1.0
    assert boxes.shape == (4, 6)
    valid = boxes[boxes[:, 0] > 0.5]
    assert len(valid) >= 1
    assert (valid[:, 1] >= 0).all() and (valid[:, 1] < len(CLASS_APPEARANCE)).all()
    assert (valid[:, 2:4] >= 0).all() and (valid[:, 2:4] <= 1).all()


def test_determinism_same_seed():
    a_img, a_box = make_scene(np.random.default_rng(42), 48)
    b_img, b_box = make_scene(np.random.default_rng(42), 48)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_box, b_box)


def test_draw_object_colours_match_contract():
    """Drawn pixels must be dominated by the class colour channel."""
    dominant = {0: 0, 1: 2, 2: 1}  # person->R, cyclist->B, car->G
    for cls, dom in dominant.items():
        rng = np.random.default_rng(5)
        img = np.full((64, 64, 3), 0.5, np.float32)
        cx, cy, w, h = draw_object(img, rng, cls, 0.5, 0.5, 0.4)
        x0, x1 = int((cx - w / 4) * 64), int((cx + w / 4) * 64)
        y0, y1 = int((cy - h / 4) * 64), int((cy + h / 4) * 64)
        patch = img[y0:y1, x0:x1]
        means = patch.reshape(-1, 3).mean(axis=0)
        assert means.argmax() == dom, (cls, means)


def test_draw_object_clips_offscreen():
    rng = np.random.default_rng(1)
    img = np.full((32, 32, 3), 0.5, np.float32)
    # Mostly off-screen object must not crash and must keep bounds.
    draw_object(img, rng, 2, 0.02, 0.02, 0.4)
    assert img.min() >= 0.0 and img.max() <= 1.0


def test_make_batch_shapes():
    rng = np.random.default_rng(0)
    imgs, boxes = make_batch(rng, 3, 32)
    assert imgs.shape == (3, 32, 32, 3)
    assert boxes.shape == (3, 4, 6)
