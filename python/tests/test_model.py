"""L2 correctness: TinyDet shapes, decode invariants, pallas/ref agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    NUM_CLASSES,
    VARIANTS,
    TinyDetConfig,
    decode,
    flops_estimate,
    forward,
    init_params,
    num_params,
    raw_head,
)

# A miniature config so tests run in milliseconds.
TINY = TinyDetConfig(name="tiny", input_size=32, channels=(8, 16), extra_convs=0,
                     head_channels=16)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


def test_variant_registry_shapes():
    essd, eyolo = VARIANTS["essd"], VARIANTS["eyolo"]
    assert essd.input_size == 96 and essd.grid == 12
    assert eyolo.input_size == 128 and eyolo.grid == 16
    assert essd.out_cols == 5 + NUM_CLASSES
    # eyolo must cost more than essd (mirrors YOLOv3 > SSD300).
    assert flops_estimate(eyolo) > 1.5 * flops_estimate(essd)


def test_init_params_shapes(tiny_params):
    assert tiny_params["w0"].shape == (3, 3, 3, 8)
    assert tiny_params["b0"].shape == (8,)
    assert num_params(tiny_params) > 0
    # Objectness bias initialised negative.
    assert float(tiny_params[f"b{2 + TINY.extra_convs + 1}"][0]) == pytest.approx(-4.0)


def test_raw_head_shape(tiny_params):
    x = jnp.zeros((2, 32, 32, 3), jnp.float32)
    out = raw_head(tiny_params, x, TINY, use_pallas=False)
    assert out.shape == (2, TINY.grid, TINY.grid, TINY.out_cols)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_decode_ranges(tiny_params, seed):
    """Decoded geometry and probabilities live in [0, 1]."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 1, (1, 32, 32, 3)), jnp.float32)
    out = np.asarray(forward(tiny_params, x, TINY, use_pallas=False))[0]
    assert out.shape == (TINY.out_rows, TINY.out_cols)
    assert (out[:, 0] >= 0).all() and (out[:, 0] <= 1).all()       # objectness
    assert (out[:, 1:5] >= 0).all() and (out[:, 1:5] <= 1).all()   # geometry
    probs = out[:, 5:]
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, rtol=1e-4)  # softmax


def test_decode_cell_offsets():
    """A logit grid of zeros decodes to cell-centred boxes."""
    g = 4
    cfg = TinyDetConfig(name="t", input_size=16, channels=(8, 16), extra_convs=0,
                        head_channels=8)
    logits = jnp.zeros((1, g, g, cfg.out_cols), jnp.float32)
    out = np.asarray(decode(logits, cfg))[0]
    # sigmoid(0) = 0.5 -> centre of each cell.
    cx = out[:, 1].reshape(g, g)
    cy = out[:, 2].reshape(g, g)
    for row in range(g):
        for col in range(g):
            assert cx[row, col] == pytest.approx((col + 0.5) / g)
            assert cy[row, col] == pytest.approx((row + 0.5) / g)


def test_pallas_and_ref_paths_agree(tiny_params):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.uniform(0, 1, (1, 32, 32, 3)), jnp.float32)
    out_p = forward(tiny_params, x, TINY, use_pallas=True)
    out_r = forward(tiny_params, x, TINY, use_pallas=False)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-4, atol=1e-5)


def test_forward_batch_independence(tiny_params):
    """Each batch element is processed independently."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0, 1, (1, 32, 32, 3)), jnp.float32)
    b = jnp.asarray(rng.uniform(0, 1, (1, 32, 32, 3)), jnp.float32)
    ab = jnp.concatenate([a, b], axis=0)
    out_ab = forward(tiny_params, ab, TINY, use_pallas=False)
    out_a = forward(tiny_params, a, TINY, use_pallas=False)
    np.testing.assert_allclose(out_ab[0], out_a[0], rtol=1e-5, atol=1e-6)


def test_flops_estimate_positive_and_monotone():
    assert flops_estimate(TINY) > 0
    bigger = TinyDetConfig(name="b", input_size=64, channels=(8, 16),
                           extra_convs=0, head_channels=16)
    assert flops_estimate(bigger) > flops_estimate(TINY)
