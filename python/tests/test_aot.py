"""AOT pipeline tests: HLO text emission, weight round-trip, manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import load_weights, sanity_check, save_weights, to_hlo_text
from compile.model import TinyDetConfig, init_params, make_inference_fn

TINY = TinyDetConfig(name="tiny", input_size=32, channels=(8, 16), extra_convs=0,
                     head_channels=16)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_is_parsable_text():
    params = init_params(TINY, jax.random.PRNGKey(0))
    infer = make_inference_fn(params, TINY, use_pallas=False)
    spec = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
    text = to_hlo_text(jax.jit(infer).lower(spec))
    assert "HloModule" in text
    assert "ENTRY" in text
    # Weights are baked: in the ENTRY computation there is exactly one
    # parameter (the image). Subcomputations (pad/reduce) may have more.
    entry = text[text.index("ENTRY"):]
    assert "parameter(0)" in entry
    assert "parameter(1)" not in entry


def test_weight_save_load_roundtrip(tmp_path):
    params = init_params(TINY, jax.random.PRNGKey(3))
    p = str(tmp_path / "w.npz")
    save_weights(p, params)
    loaded = load_weights(p)
    assert set(loaded) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(loaded[k]))


def test_sanity_check_passes_for_fresh_params():
    params = init_params(TINY, jax.random.PRNGKey(1))
    err = sanity_check(params, TINY)
    assert err < 1e-3


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_contract():
    """The manifest the Rust runtime parses must stay on-contract."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    names = {m["name"] for m in manifest["models"]}
    assert {"essd", "eyolo"} <= names
    for m in manifest["models"]:
        assert os.path.exists(os.path.join(ARTIFACTS, m["hlo"]))
        assert m["input_shape"][0] == 1 and m["input_shape"][3] == 3
        assert m["out_rows"] == m["grid"] ** 2
        assert m["out_cols"] == 5 + m["num_classes"]
        assert m["row_layout"][0] == "objectness"
        assert m["params"] > 0 and m["flops_per_frame"] > 0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_artifact_hlo_single_param_entry():
    """Every artifact takes exactly one parameter (the frame)."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    for m in manifest["models"]:
        with open(os.path.join(ARTIFACTS, m["hlo"])) as f:
            text = f.read()
        entry = text[text.index("ENTRY"):]
        assert "parameter(0)" in entry
        assert "parameter(1)" not in entry
