"""Training-substrate tests: target building, loss behaviour, short loops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import NUM_CLASSES, TinyDetConfig, init_params
from compile.train import adam_init, build_targets, detection_loss, train, train_step

TINY = TinyDetConfig(name="tiny", input_size=32, channels=(8, 16), extra_convs=0,
                     head_channels=16)


def test_build_targets_single_object():
    grid = 4
    boxes = np.zeros((1, 4, 6), np.float32)
    boxes[0, 0] = [1.0, 2.0, 0.6, 0.3, 0.2, 0.4]  # car at (0.6, 0.3)
    obj, txy, twh, cls = build_targets(boxes, grid, NUM_CLASSES)
    gx, gy = int(0.6 * grid), int(0.3 * grid)  # (2, 1)
    assert obj[0, gy, gx, 0] == 1.0
    assert obj.sum() == 1.0
    np.testing.assert_allclose(
        txy[0, gy, gx], [0.6 * grid - gx, 0.3 * grid - gy], rtol=1e-5
    )
    np.testing.assert_allclose(twh[0, gy, gx], [0.2, 0.4], rtol=1e-5)
    assert cls[0, gy, gx, 2] == 1.0 and cls[0, gy, gx].sum() == 1.0


def test_build_targets_ignores_invalid_rows():
    boxes = np.zeros((2, 4, 6), np.float32)  # all valid=0
    obj, txy, twh, cls = build_targets(boxes, 4, NUM_CLASSES)
    assert obj.sum() == 0 and cls.sum() == 0


def test_build_targets_edge_coordinates():
    """cx = cy = 1.0 must clamp into the last cell, not overflow."""
    boxes = np.zeros((1, 4, 6), np.float32)
    boxes[0, 0] = [1.0, 0.0, 1.0, 1.0, 0.1, 0.1]
    obj, *_ = build_targets(boxes, 4, NUM_CLASSES)
    assert obj[0, 3, 3, 0] == 1.0


def test_loss_is_finite_and_positive():
    params = init_params(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.uniform(0, 1, (2, 32, 32, 3)), jnp.float32)
    boxes = np.zeros((2, 4, 6), np.float32)
    boxes[0, 0] = [1.0, 1.0, 0.5, 0.5, 0.3, 0.3]
    tgt = build_targets(boxes, TINY.grid, NUM_CLASSES)
    loss = detection_loss(params, imgs, *map(jnp.asarray, tgt), TINY)
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_train_step_decreases_loss_on_fixed_batch():
    """Repeated steps on one batch must fit it (loss strictly improves)."""
    params = init_params(TINY, jax.random.PRNGKey(1))
    opt = adam_init(params)
    rng = np.random.default_rng(1)
    imgs = jnp.asarray(rng.uniform(0, 1, (4, 32, 32, 3)), jnp.float32)
    boxes = np.zeros((4, 4, 6), np.float32)
    for i in range(4):
        boxes[i, 0] = [1.0, i % 3, 0.3 + 0.1 * i, 0.5, 0.2, 0.3]
    tgt = [jnp.asarray(t) for t in build_targets(boxes, TINY.grid, NUM_CLASSES)]
    first = None
    loss = None
    for _ in range(30):
        params, opt, loss = train_step(params, opt, imgs, *tgt, TINY, 1e-3)
        if first is None:
            first = float(loss)
    assert float(loss) < 0.5 * first


@pytest.mark.slow
def test_short_training_run_converges():
    params = train(TINY, steps=40, batch=4, verbose=False)
    assert all(np.isfinite(np.asarray(v)).all() for v in params.values())
