"""Synthetic scene generator for TinyDet training.

Mirrors the Rust video substrate (``rust/src/video``): textured background
plus solid-ish rectangles of three object classes with class-specific aspect
ratios and colours. Keeping the two generators statistically aligned is what
makes the build-time-trained TinyDet work on the Rust-generated clips in the
end-to-end serving example.

Class appearance contract (shared with rust/src/video/objects.rs):
  person  — tall  (aspect h/w ~ 2.6), reddish   (r high, g/b low)
  cyclist — square (aspect ~ 1.1),    bluish    (b high)
  car     — wide  (aspect ~ 0.45),    greenish  (g high)
Background: low-frequency grayish noise in [0.25, 0.65].
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .model import NUM_CLASSES

# (aspect h/w, base colour rgb) per class — keep in sync with Rust.
CLASS_APPEARANCE = [
    (2.6, (0.85, 0.25, 0.20)),   # person
    (1.1, (0.25, 0.30, 0.85)),   # cyclist
    (0.45, (0.20, 0.80, 0.30)),  # car
]


def render_background(rng: np.random.Generator, size: int) -> np.ndarray:
    """Low-frequency grayish noise background, (S, S, 3) float32 in [0,1]."""
    coarse = rng.uniform(0.25, 0.65, size=(size // 8 + 1, size // 8 + 1))
    idx = np.arange(size) / 8.0
    xi = np.clip(idx.astype(np.int32), 0, coarse.shape[0] - 2)
    fx = (idx - xi).astype(np.float32)
    row = coarse[xi, :] * (1 - fx)[:, None] + coarse[xi + 1, :] * fx[:, None]
    col = row[:, xi] * (1 - fx)[None, :] + row[:, xi + 1] * fx[None, :]
    img = np.repeat(col[:, :, None], 3, axis=2).astype(np.float32)
    img += rng.normal(0, 0.02, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def draw_object(
    img: np.ndarray,
    rng: np.random.Generator,
    cls: int,
    cx: float,
    cy: float,
    height: float,
) -> Tuple[float, float, float, float]:
    """Rasterise one object; returns its (cx, cy, w, h) in [0,1] coords."""
    size = img.shape[0]
    aspect, colour = CLASS_APPEARANCE[cls]
    h = height
    w = h / aspect
    x0 = int(round((cx - w / 2) * size))
    x1 = int(round((cx + w / 2) * size))
    y0 = int(round((cy - h / 2) * size))
    y1 = int(round((cy + h / 2) * size))
    x0c, x1c = max(x0, 0), min(x1, size)
    y0c, y1c = max(y0, 0), min(y1, size)
    if x1c <= x0c or y1c <= y0c:
        return (cx, cy, w, h)
    shade = rng.uniform(0.75, 1.15)
    block = np.array(colour, np.float32) * shade
    img[y0c:y1c, x0c:x1c, :] = np.clip(
        block[None, None, :]
        + rng.normal(0, 0.04, (y1c - y0c, x1c - x0c, 3)).astype(np.float32),
        0.0,
        1.0,
    )
    # Darker border helps localisation.
    if y1c - y0c > 2 and x1c - x0c > 2:
        img[y0c, x0c:x1c, :] *= 0.5
        img[y1c - 1, x0c:x1c, :] *= 0.5
        img[y0c:y1c, x0c, :] *= 0.5
        img[y0c:y1c, x1c - 1, :] *= 0.5
    return (cx, cy, w, h)


def make_scene(
    rng: np.random.Generator, size: int, max_objects: int = 4
) -> Tuple[np.ndarray, np.ndarray]:
    """One training scene.

    Returns:
      image:  (S, S, 3) float32 in [0, 1]
      boxes:  (max_objects, 6) float32 rows [valid, cls, cx, cy, w, h]
    """
    img = render_background(rng, size)
    n = int(rng.integers(1, max_objects + 1))
    boxes = np.zeros((max_objects, 6), np.float32)
    for i in range(n):
        cls = int(rng.integers(0, NUM_CLASSES))
        height = float(rng.uniform(0.18, 0.45))
        cx = float(rng.uniform(0.12, 0.88))
        cy = float(rng.uniform(0.12, 0.88))
        cx2, cy2, w, h = draw_object(img, rng, cls, cx, cy, height)
        boxes[i] = [1.0, float(cls), cx2, cy2, w, h]
    return img, boxes


def make_batch(
    rng: np.random.Generator, batch: int, size: int, max_objects: int = 4
) -> Tuple[np.ndarray, np.ndarray]:
    """Batch of scenes: (B, S, S, 3) images + (B, max_objects, 6) boxes."""
    imgs = np.zeros((batch, size, size, 3), np.float32)
    boxes = np.zeros((batch, max_objects, 6), np.float32)
    for b in range(batch):
        imgs[b], boxes[b] = make_scene(rng, size, max_objects)
    return imgs, boxes
