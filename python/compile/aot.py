"""AOT pipeline: train TinyDet variants, lower to HLO text, emit manifest.

Runs once via ``make artifacts``. Emits, per variant:

  artifacts/<name>.hlo.txt     — HLO text of the full inference graph
                                 (Pallas conv path, weights baked as
                                 constants, in-graph decode)
  artifacts/<name>.weights.npz — trained weights (cache: retrain is skipped
                                 when present unless --retrain)
  artifacts/manifest.json      — shapes/grid/decode metadata for the Rust
                                 runtime

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids.
See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels import matmul as pallas_matmul
from .model import CLASSES, VARIANTS, TinyDetConfig, flops_estimate, make_inference_fn, num_params
from .train import train


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is REQUIRED: the default HLO printer
    # elides big constants ("{...}"), and the text parser then reads the
    # baked TinyDet weights back as zeros — the artifact would silently
    # predict nothing but head biases.
    return comp.as_hlo_text(print_large_constants=True)


def save_weights(path: str, params) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_weights(path: str):
    data = np.load(path)
    return {k: jnp.asarray(data[k]) for k in data.files}


def sanity_check(params, cfg: TinyDetConfig) -> float:
    """Pallas vs reference inference paths must agree on a random frame."""
    from .model import forward

    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.uniform(0, 1, (1, cfg.input_size, cfg.input_size, 3)),
                    jnp.float32)
    out_p = forward(params, x, cfg, use_pallas=True)
    out_r = forward(params, x, cfg, use_pallas=False)
    err = float(jnp.max(jnp.abs(out_p - out_r)))
    if err > 1e-3:
        raise AssertionError(f"pallas/ref divergence {err} for {cfg.name}")
    return err


def build_variant(name: str, out_dir: str, steps: int, retrain: bool) -> dict:
    cfg = VARIANTS[name]
    wpath = os.path.join(out_dir, f"{name}.weights.npz")
    if os.path.exists(wpath) and not retrain:
        print(f"[aot] {name}: reusing cached weights {wpath}", flush=True)
        params = load_weights(wpath)
    else:
        print(f"[aot] {name}: training {steps} steps ...", flush=True)
        params = train(cfg, steps=steps)
        save_weights(wpath, params)

    err = sanity_check(params, cfg)
    print(f"[aot] {name}: pallas-vs-ref max|err| = {err:.2e}", flush=True)

    infer = make_inference_fn(params, cfg, use_pallas=True)
    spec = jax.ShapeDtypeStruct((1, cfg.input_size, cfg.input_size, 3), jnp.float32)
    t0 = time.time()
    lowered = jax.jit(infer).lower(spec)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    print(f"[aot] {name}: wrote {len(text)} chars to {hlo_path} "
          f"({time.time() - t0:.1f}s)", flush=True)

    return {
        "name": name,
        "hlo": f"{name}.hlo.txt",
        "input_shape": [1, cfg.input_size, cfg.input_size, 3],
        "input_size": cfg.input_size,
        "grid": cfg.grid,
        "num_classes": cfg.num_classes,
        "classes": CLASSES,
        "out_rows": cfg.out_rows,
        "out_cols": cfg.out_cols,
        "row_layout": ["objectness", "cx", "cy", "w", "h", "class_probs..."],
        "params": num_params(params),
        "flops_per_frame": flops_estimate(cfg),
        "pallas_blocks": {
            "bm": pallas_matmul.DEFAULT_BM,
            "bn": pallas_matmul.DEFAULT_BN,
            "bk": pallas_matmul.DEFAULT_BK,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="TinyDet AOT pipeline")
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--steps", type=int, default=400, help="training steps")
    ap.add_argument("--retrain", action="store_true", help="ignore weight cache")
    ap.add_argument("--variants", default="essd,eyolo")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name in args.variants.split(","):
        entries.append(build_variant(name.strip(), out_dir, args.steps, args.retrain))

    manifest = {"format": 1, "models": entries}
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {mpath}", flush=True)


if __name__ == "__main__":
    main()
