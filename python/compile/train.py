"""Build-time training loop for TinyDet.

Runs once inside ``make artifacts`` (python never on the request path).
Training uses the pure-jnp reference conv path for speed; the AOT-lowered
inference graph uses the Pallas kernels with the same weights (pytest
asserts the two paths agree numerically).

Loss (YOLO-lite, anchor-free, one box per cell):
  * objectness: BCE, cell positive iff an object's centre falls in it;
  * box: squared error on (sigmoid-space cx, cy in-cell offsets and w, h)
    for positive cells;
  * class: cross-entropy for positive cells.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import scene
from .model import TinyDetConfig, init_params, raw_head

MAX_OBJECTS = 4


def build_targets(boxes: np.ndarray, grid: int, num_classes: int) -> Tuple[np.ndarray, ...]:
    """Per-cell training targets from (B, M, 6) [valid, cls, cx, cy, w, h].

    Returns (obj, txy, twh, cls_onehot) with shapes
    (B,G,G,1), (B,G,G,2), (B,G,G,2), (B,G,G,C).
    Later objects overwrite earlier ones in the rare same-cell collision.
    """
    b = boxes.shape[0]
    obj = np.zeros((b, grid, grid, 1), np.float32)
    txy = np.zeros((b, grid, grid, 2), np.float32)
    twh = np.zeros((b, grid, grid, 2), np.float32)
    cls = np.zeros((b, grid, grid, num_classes), np.float32)
    for i in range(b):
        for row in boxes[i]:
            valid, c, cx, cy, w, h = row
            if valid < 0.5:
                continue
            gx = min(int(cx * grid), grid - 1)
            gy = min(int(cy * grid), grid - 1)
            obj[i, gy, gx, 0] = 1.0
            txy[i, gy, gx] = [cx * grid - gx, cy * grid - gy]
            twh[i, gy, gx] = [w, h]
            cls[i, gy, gx] = 0.0
            cls[i, gy, gx, int(c)] = 1.0
    return obj, txy, twh, cls


def detection_loss(params, imgs, obj_t, txy_t, twh_t, cls_t, cfg: TinyDetConfig):
    """Scalar loss over a batch (reference conv path for speed)."""
    logits = raw_head(params, imgs, cfg, use_pallas=False)
    obj_l = logits[..., 0:1]
    txy_l = jax.nn.sigmoid(logits[..., 1:3])
    twh_l = jax.nn.sigmoid(logits[..., 3:5])
    cls_l = logits[..., 5:]

    # Objectness BCE with positive-cell upweighting (grids are mostly empty).
    pos_weight = 8.0
    bce = jnp.maximum(obj_l, 0) - obj_l * obj_t + jnp.log1p(jnp.exp(-jnp.abs(obj_l)))
    w = 1.0 + (pos_weight - 1.0) * obj_t
    loss_obj = jnp.mean(bce * w)

    mask = obj_t
    npos = jnp.maximum(jnp.sum(mask), 1.0)
    loss_box = jnp.sum(mask * ((txy_l - txy_t) ** 2 + 4.0 * (twh_l - twh_t) ** 2)) / npos

    logp = jax.nn.log_softmax(cls_l, axis=-1)
    loss_cls = -jnp.sum(mask * jnp.sum(cls_t * logp, axis=-1, keepdims=True)) / npos

    return loss_obj + 2.0 * loss_box + 0.5 * loss_cls


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def train_step(params, opt, imgs, obj_t, txy_t, twh_t, cls_t, cfg: TinyDetConfig, lr: float):
    loss, grads = jax.value_and_grad(detection_loss)(
        params, imgs, obj_t, txy_t, twh_t, cls_t, cfg
    )
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mhat, vhat
    )
    return params, {"m": m, "v": v, "t": t}, loss


def train(
    cfg: TinyDetConfig,
    steps: int = 400,
    batch: int = 16,
    lr: float = 1e-3,
    seed: int = 7,
    verbose: bool = True,
) -> Dict[str, jax.Array]:
    """Train a TinyDet variant; returns trained params."""
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    t0 = time.time()
    for step in range(steps):
        imgs, boxes = scene.make_batch(rng, batch, cfg.input_size, MAX_OBJECTS)
        obj_t, txy_t, twh_t, cls_t = build_targets(boxes, cfg.grid, cfg.num_classes)
        params, opt, loss = train_step(
            params, opt, jnp.asarray(imgs), jnp.asarray(obj_t), jnp.asarray(txy_t),
            jnp.asarray(twh_t), jnp.asarray(cls_t), cfg, lr,
        )
        if verbose and (step % 50 == 0 or step == steps - 1):
            print(f"[train:{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return params
