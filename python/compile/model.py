"""L2: TinyDet — single-shot grid detector in JAX, calling the L1 kernels.

TinyDet is the edge-scale stand-in for the paper's SSD300/YOLOv3 (DESIGN.md
§3): a real conv detector, trained at build time on the synthetic object
distribution that the Rust video substrate generates, then AOT-lowered to
HLO text and served by the Rust coordinator via PJRT.

Two variants mirror the paper's two models:

  * ``essd``  — 96x96 input, 3-stage backbone, 12x12 grid  (SSD300 analog)
  * ``eyolo`` — 128x128 input, 4-stage backbone, 16x16 grid (YOLOv3 analog,
                ~2x the FLOPs of ``essd``, mirroring the input-size ratio)

Architecture (anchor-free, one box per grid cell):

  backbone: [conv3x3 s2 + leaky_relu] per stage      (SAME padding)
  head:     conv3x3 s1 -> (G, G, 5 + C)
  decode:   in-graph sigmoid/softmax + cell offsets ->
            (G*G, 5 + C) rows = [score, cx, cy, w, h, p_class...]
            with cx/cy/w/h normalised to [0, 1] image coordinates.

The decode lives inside the lowered HLO so the Rust hot path only
thresholds + runs NMS. Every conv funnels through the Pallas matmul
(``kernels/conv.py``); training uses the pure-jnp reference path
(``use_pallas=False``) for speed — pytest asserts the two paths agree, so
weights transfer exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from .kernels import conv as pallas_conv
from .kernels import ref as kref

# Object classes shared with the Rust video substrate (rust/src/video).
CLASSES: List[str] = ["person", "cyclist", "car"]
NUM_CLASSES = len(CLASSES)


@dataclasses.dataclass(frozen=True)
class TinyDetConfig:
    """Static architecture description for one TinyDet variant."""

    name: str
    input_size: int                 # square input, pixels
    channels: tuple                 # backbone stage widths (all stride 2)
    extra_convs: int                # stride-1 3x3 convs after the backbone
    head_channels: int              # width of the pre-head conv
    num_classes: int = NUM_CLASSES

    @property
    def grid(self) -> int:
        return self.input_size // (2 ** len(self.channels))

    @property
    def out_rows(self) -> int:
        return self.grid * self.grid

    @property
    def out_cols(self) -> int:
        return 5 + self.num_classes


VARIANTS: Dict[str, TinyDetConfig] = {
    # SSD300 analog: smaller input, shallower.
    "essd": TinyDetConfig(
        name="essd", input_size=96, channels=(16, 32, 64), extra_convs=0,
        head_channels=64,
    ),
    # YOLOv3 analog: larger input, deeper (~2x essd FLOPs).
    "eyolo": TinyDetConfig(
        name="eyolo", input_size=128, channels=(24, 48, 96), extra_convs=2,
        head_channels=96,
    ),
}


def leaky_relu(x: jax.Array) -> jax.Array:
    return jnp.where(x >= 0, x, 0.1 * x)


def init_params(cfg: TinyDetConfig, key: jax.Array) -> Dict[str, jax.Array]:
    """He-initialised parameters for a TinyDet variant."""
    params: Dict[str, jax.Array] = {}
    cin = 3
    idx = 0

    def conv_init(k, kh, kw, ci, co):
        scale = jnp.sqrt(2.0 / (kh * kw * ci))
        return jax.random.normal(k, (kh, kw, ci, co), jnp.float32) * scale

    for co in cfg.channels:
        key, sub = jax.random.split(key)
        params[f"w{idx}"] = conv_init(sub, 3, 3, cin, co)
        params[f"b{idx}"] = jnp.zeros((co,), jnp.float32)
        cin = co
        idx += 1
    for _ in range(cfg.extra_convs):
        key, sub = jax.random.split(key)
        params[f"w{idx}"] = conv_init(sub, 3, 3, cin, cin)
        params[f"b{idx}"] = jnp.zeros((cin,), jnp.float32)
        idx += 1
    key, sub = jax.random.split(key)
    params[f"w{idx}"] = conv_init(sub, 3, 3, cin, cfg.head_channels)
    params[f"b{idx}"] = jnp.zeros((cfg.head_channels,), jnp.float32)
    idx += 1
    key, sub = jax.random.split(key)
    params[f"w{idx}"] = conv_init(sub, 1, 1, cfg.head_channels, cfg.out_cols)
    # Bias the objectness logit negative so early training predicts "empty".
    bias = jnp.zeros((cfg.out_cols,), jnp.float32).at[0].set(-4.0)
    params[f"b{idx}"] = bias
    return params


def num_params(params: Dict[str, jax.Array]) -> int:
    return int(sum(p.size for p in params.values()))


def _conv_same(x, w, stride, use_pallas: bool):
    if use_pallas:
        return pallas_conv.conv2d_same(x, w, stride)
    # Reference path: SAME-padded lax conv (fast; used in training).
    kh, kw = w.shape[0], w.shape[1]
    h, wd = x.shape[1], x.shape[2]
    oh = -(-h // stride)
    ow = -(-wd // stride)
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - wd, 0)
    x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                    (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    return kref.conv2d_ref(x, w, stride)


def raw_head(params: Dict[str, jax.Array], x: jax.Array, cfg: TinyDetConfig,
             use_pallas: bool = True) -> jax.Array:
    """Backbone + head logits: (N, S, S, 3) -> (N, G, G, 5+C)."""
    idx = 0
    for _ in cfg.channels:
        x = _conv_same(x, params[f"w{idx}"], 2, use_pallas) + params[f"b{idx}"]
        x = leaky_relu(x)
        idx += 1
    for _ in range(cfg.extra_convs):
        x = _conv_same(x, params[f"w{idx}"], 1, use_pallas) + params[f"b{idx}"]
        x = leaky_relu(x)
        idx += 1
    x = _conv_same(x, params[f"w{idx}"], 1, use_pallas) + params[f"b{idx}"]
    x = leaky_relu(x)
    idx += 1
    x = _conv_same(x, params[f"w{idx}"], 1, use_pallas) + params[f"b{idx}"]
    return x


def decode(logits: jax.Array, cfg: TinyDetConfig) -> jax.Array:
    """In-graph decode: (N, G, G, 5+C) logits -> (N, G*G, 5+C) detections.

    Output row layout: [objectness, cx, cy, w, h, class_probs...] with all
    geometry normalised to [0, 1] image coordinates. This runs inside the
    AOT artifact so the Rust side only thresholds + NMS.
    """
    n, g, _, _ = logits.shape
    obj = jax.nn.sigmoid(logits[..., 0:1])
    txy = jax.nn.sigmoid(logits[..., 1:3])
    twh = jax.nn.sigmoid(logits[..., 3:5])
    cls = jax.nn.softmax(logits[..., 5:], axis=-1)

    ys, xs = jnp.meshgrid(jnp.arange(g), jnp.arange(g), indexing="ij")
    cell = jnp.stack([xs, ys], axis=-1).astype(jnp.float32)  # (G, G, 2) as (x, y)
    cxy = (cell + txy) / g
    out = jnp.concatenate([obj, cxy, twh, cls], axis=-1)
    return out.reshape(n, g * g, cfg.out_cols)


def forward(params: Dict[str, jax.Array], x: jax.Array, cfg: TinyDetConfig,
            use_pallas: bool = True) -> jax.Array:
    """Full inference: image batch -> decoded detection rows."""
    return decode(raw_head(params, x, cfg, use_pallas), cfg)


def make_inference_fn(params: Dict[str, jax.Array], cfg: TinyDetConfig,
                      use_pallas: bool = True) -> Callable[[jax.Array], tuple]:
    """Close over trained weights (baked as HLO constants when lowered)."""

    def infer(x: jax.Array):
        return (forward(params, x, cfg, use_pallas=use_pallas),)

    return infer


def flops_estimate(cfg: TinyDetConfig) -> int:
    """Analytic MAC count for one frame (for DESIGN.md cost calibration)."""
    total = 0
    s = cfg.input_size
    cin = 3
    for co in cfg.channels:
        s = -(-s // 2)
        total += s * s * 3 * 3 * cin * co
        cin = co
    for _ in range(cfg.extra_convs):
        total += s * s * 3 * 3 * cin * cin
    total += s * s * 3 * 3 * cin * cfg.head_channels
    total += s * s * cfg.head_channels * cfg.out_cols
    return 2 * total
