"""L1 Pallas kernel: tiled matmul for the TinyDet conv/dense hot path.

This is the compute hot-spot of the whole detector: every convolution is
lowered to im2col + this matmul (see ``conv.py``), so a single well-tiled
kernel covers the entire inference FLOP budget.

TPU adaptation of the paper's VPU workload (DESIGN.md §4): the grid tiles
``(M, K) x (K, N)`` into ``(BM, BK) @ (BK, BN)`` blocks shaped for the MXU
systolic array — the lane dimension (last axis) is a multiple of 128 and the
sublane dimension a multiple of 8 whenever the problem size allows.  The
``BlockSpec`` index maps express the HBM->VMEM schedule; accumulation over
the K grid axis happens in a VMEM scratch-free accumulator pattern (output
block revisited across k steps), which Mosaic double-buffers on real TPUs.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO so the AOT
artifact runs anywhere (including the Rust PJRT client).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes.  Chosen for MXU friendliness (128-lane, 8-sublane)
# while staying well inside VMEM:  fp32 footprint per step =
# BM*BK + BK*BN + BM*BN floats = (128*128)*3*4B = 192 KiB << 16 MiB VMEM.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j].

    The output block is revisited for every k; on the first visit it is
    zero-initialised.  fp32 accumulation regardless of input dtype (MXU
    accumulates in fp32).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, y, preferred_element_type=jnp.float32)
    o_ref[...] += acc.astype(o_ref.dtype)


def _pick_block(dim: int, pref: int, unit: int) -> int:
    """Largest block <= pref that divides dim, preferring multiples of unit.

    Pallas (interpret mode included) wants the grid to cover the array
    exactly; rather than padding inside the kernel we pick a divisor block.
    Preference order: multiples of ``unit`` (MXU lane/sublane alignment),
    then any divisor.
    """
    if dim <= pref:
        return dim
    best = 1
    for b in range(pref, 0, -1):
        if dim % b == 0:
            if b % unit == 0:
                return b
            if best == 1:
                best = b
    return best


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """Pallas tiled matmul: ``x @ y`` with fp32 accumulation.

    Args:
      x: ``(M, K)`` array (fp32 or bf16).
      y: ``(K, N)`` array (same dtype family).
      bm/bn/bk: preferred block sizes; shrunk to exact divisors of the
        problem dims (MXU-aligned when possible).

    Returns:
      ``(M, N)`` fp32 array.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")

    bm = _pick_block(m, bm, 8)
    bn = _pick_block(n, bn, 128)
    bk = _pick_block(k, bk, 128)
    grid = (m // bm, n // bn, k // bk)

    kernel = functools.partial(_matmul_kernel, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, y)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (for DESIGN/EXPERIMENTS §Perf)."""
    return dtype_bytes * (bm * bk + bk * bn) + 4 * (bm * bn)


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU lanes busy, assuming 128x128 systolic tiles.

    Utilization is the ratio of useful MACs to MACs issued when each
    (bm, bk)x(bk, bn) block is zero-padded up to 8x128-aligned tiles.
    """
    def up(v: int, u: int) -> int:
        return ((v + u - 1) // u) * u

    useful = m * n * k
    padded = up(bm, 8) * up(bn, 128) * up(bk, 128)
    steps = (m // bm) * (n // bn) * (k // bk)
    issued = padded * steps
    return useful / issued if issued else 0.0
