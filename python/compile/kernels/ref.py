"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: ``python/tests/test_kernel.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels match
these references to tight tolerances.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference ``x @ y`` with fp32 accumulation."""
    return jnp.dot(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def im2col_ref(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """Reference im2col: NHWC image -> (N*OH*OW, KH*KW*C) patch matrix."""
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            sl = x[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            patches.append(sl)
    # list of (N, OH, OW, C) -> (N, OH, OW, KH*KW, C)
    stacked = jnp.stack(patches, axis=3)
    return stacked.reshape(n * oh * ow, kh * kw * c)


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Reference NHWC conv2d (VALID padding) via lax.conv_general_dilated.

    ``w`` is HWIO: (KH, KW, Cin, Cout).
    """
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
