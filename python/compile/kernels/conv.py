"""Conv2D lowered to im2col + the Pallas tiled matmul.

This is the TPU re-think of the paper's VPU conv workload (DESIGN.md §4):
instead of per-SHAVE-slice scheduling, patches are gathered once (im2col is
a pure data-movement op that XLA fuses) and the entire FLOP budget of the
layer funnels through the single MXU-shaped Pallas matmul in ``matmul.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul as pallas_matmul


def im2col(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """NHWC image -> (N*OH*OW, KH*KW*C) patch matrix (VALID padding)."""
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            sl = x[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            patches.append(sl)
    stacked = jnp.stack(patches, axis=3)  # (N, OH, OW, KH*KW, C)
    return stacked.reshape(n * oh * ow, kh * kw * c)


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """NHWC conv2d, VALID padding, via im2col + Pallas matmul.

    Args:
      x: ``(N, H, W, Cin)`` input.
      w: ``(KH, KW, Cin, Cout)`` HWIO filter.
      stride: spatial stride.

    Returns:
      ``(N, OH, OW, Cout)`` fp32 output.
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    if cin != cin2:
        raise ValueError(f"conv2d channel mismatch: {x.shape} vs {w.shape}")
    oh = (h - kh) // stride + 1
    ow = (wdt - kw) // stride + 1

    cols = im2col(x, kh, kw, stride)  # (N*OH*OW, KH*KW*Cin)
    wmat = w.reshape(kh * kw * cin, cout)
    out = pallas_matmul.matmul(cols, wmat)  # (N*OH*OW, Cout)
    return out.reshape(n, oh, ow, cout)


def conv2d_same(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """SAME-padded conv2d built on :func:`conv2d`.

    Pads spatially so that ``OH = ceil(H / stride)`` (TensorFlow SAME rule).
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, _ = w.shape
    oh = -(-h // stride)
    ow = -(-wdt // stride)
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - wdt, 0)
    x = jnp.pad(
        x,
        (
            (0, 0),
            (pad_h // 2, pad_h - pad_h // 2),
            (pad_w // 2, pad_w - pad_w // 2),
            (0, 0),
        ),
    )
    return conv2d(x, w, stride)
