//! Cross-module integration and property tests for the virtual-time
//! pipeline: conservation laws, ordering invariants, scheduler behaviour
//! under randomized fleets/workloads, and paper-shape stability across
//! seeds.

use eva::coordinator::{run_online, RunConfig, SchedulerKind, SourceMode};
use eva::detector::quality::{QualityModelDetector, QualityProfile};
use eva::detector::Detector;
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind, Fleet};
use eva::experiments::common::{online_map, quality_detectors, saturated_fps};
use eva::util::prop::{check, Config};
use eva::video::{generate, presets, ClipSpec};

fn small_clip(seed: u64, fps: f64, frames: u32) -> ClipSpec {
    let mut spec = presets::eth_sunnyday(seed);
    spec.fps = fps;
    spec.num_frames = frames;
    spec
}

fn any_scheduler(rng: &mut eva::util::Rng) -> SchedulerKind {
    *rng.choose(&[
        SchedulerKind::RoundRobin,
        SchedulerKind::WeightedRoundRobin,
        SchedulerKind::Fcfs,
        SchedulerKind::Proportional,
    ])
}

fn random_fleet(rng: &mut eva::util::Rng) -> Fleet {
    let n = rng.int_in(1, 6) as usize;
    let hetero = rng.chance(0.4);
    let mut devices: Vec<DeviceInstance> = (0..n)
        .map(|i| DeviceInstance::new(DeviceKind::Ncs2, DetectorModelId::Yolov3, i))
        .collect();
    if hetero {
        devices.push(DeviceInstance::new(
            *rng.choose(&[DeviceKind::FastCpu, DeviceKind::SlowCpu]),
            DetectorModelId::Yolov3,
            n,
        ));
    }
    Fleet {
        devices,
        hub: Some(if rng.chance(0.5) {
            LinkProfile::usb3()
        } else {
            LinkProfile::usb2()
        }),
    }
}

#[test]
fn property_conservation_and_ordering() {
    // Every frame gets exactly one record, in order; processed + dropped
    // = total; emit times monotone — for random fleets, schedulers,
    // modes and stream rates.
    check("conservation", Config { cases: 60, base_seed: 101 }, |rng| {
        let spec = small_clip(rng.next_u64(), rng.range(5.0, 40.0), 80);
        let clip = generate(&spec, None);
        let fleet = random_fleet(rng);
        let mut cfg = RunConfig::new(
            any_scheduler(rng),
            if rng.chance(0.5) { SourceMode::Paced } else { SourceMode::Saturated },
            rng.next_u64(),
        );
        if rng.chance(0.3) {
            cfg.window = Some(rng.int_in(1, 10) as usize);
        }
        let run = run_online(
            &clip,
            &fleet,
            quality_detectors(&fleet, &spec.name, rng.next_u64()),
            &cfg,
        );
        if run.records.len() != clip.len() {
            return Err(format!("{} records for {} frames", run.records.len(), clip.len()));
        }
        let m = &run.metrics;
        if m.frames_processed + m.frames_dropped != m.frames_total {
            return Err("conservation violated".into());
        }
        let mut prev_emit = f64::NEG_INFINITY;
        for (i, r) in run.records.iter().enumerate() {
            if r.frame_id != i as u64 {
                return Err(format!("record {i} has id {}", r.frame_id));
            }
            if r.emit_ts < prev_emit - 1e-9 {
                return Err(format!("emit time regressed at {i}"));
            }
            prev_emit = r.emit_ts;
            // Stale fills reference an earlier processed frame.
            if let Some(src) = r.stale_from {
                if src > r.frame_id {
                    return Err(format!("stale source {src} after frame {}", r.frame_id));
                }
            }
        }
        // Per-device processed counts sum to the total processed.
        let dev_sum: u64 = m.device_frames.iter().sum();
        if dev_sum != m.frames_processed {
            return Err(format!("device sum {dev_sum} != processed {}", m.frames_processed));
        }
        Ok(())
    });
}

#[test]
fn property_saturated_capacity_bounded_by_ideal() {
    // σ_P never exceeds Σμᵢ (work conservation upper bound), and FCFS
    // reaches ≥85% of it without a shared-hub bottleneck.
    check("capacity bound", Config { cases: 25, base_seed: 202 }, |rng| {
        let spec = small_clip(rng.next_u64(), 30.0, 250);
        let clip = generate(&spec, None);
        let mut fleet = random_fleet(rng);
        fleet.hub = Some(LinkProfile::usb3()); // negligible transfers
        let fps = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, rng.next_u64());
        let ideal = fleet.aggregate_rate();
        if fps > ideal * 1.05 {
            return Err(format!("fps {fps} exceeds ideal {ideal}"));
        }
        // A slow straggler holding the final frame inflates the makespan
        // on finite clips (the paper's 354/525-frame runs amortise it),
        // so the lower bound is deliberately loose.
        if fps < ideal * 0.72 {
            return Err(format!("fcfs fps {fps} below 72% of ideal {ideal}"));
        }
        Ok(())
    });
}

#[test]
fn property_fcfs_dominates_rr() {
    // Work conservation: FCFS capacity ≥ lockstep RR capacity (within
    // jitter noise) on ANY fleet.
    check("fcfs >= rr", Config { cases: 25, base_seed: 303 }, |rng| {
        let spec = small_clip(rng.next_u64(), 20.0, 80);
        let clip = generate(&spec, None);
        let fleet = random_fleet(rng);
        let seed = rng.next_u64();
        let fcfs = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, seed);
        let rr = saturated_fps(&clip, &fleet, SchedulerKind::RoundRobin, seed);
        if fcfs < rr * 0.93 {
            return Err(format!("fcfs {fcfs} < rr {rr}"));
        }
        Ok(())
    });
}

#[test]
fn property_more_devices_never_slower() {
    check("monotone in n", Config { cases: 15, base_seed: 404 }, |rng| {
        let spec = small_clip(rng.next_u64(), 30.0, 80);
        let clip = generate(&spec, None);
        let seed = rng.next_u64();
        let mut prev = 0.0;
        for n in 1..=5usize {
            let fleet = Fleet::ncs2_sticks(n, DetectorModelId::Yolov3, LinkProfile::usb3());
            let fps = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, seed);
            if fps < prev * 0.97 {
                return Err(format!("n={n}: {fps} < n-1 capacity {prev}"));
            }
            prev = fps;
        }
        Ok(())
    });
}

#[test]
fn paper_shape_stable_across_seeds() {
    // The Table IV headline shape must not depend on the seed.
    for seed in [5u64, 17, 91] {
        let spec = presets::eth_sunnyday(seed);
        let clip = generate(&spec, None);
        let f1 = Fleet::ncs2_sticks(1, DetectorModelId::Yolov3, LinkProfile::usb3());
        let f6 = Fleet::ncs2_sticks(6, DetectorModelId::Yolov3, LinkProfile::usb3());
        let (map1, drop1) = online_map(&clip, &f1, SchedulerKind::Fcfs, seed + 1);
        let (map6, drop6) = online_map(&clip, &f6, SchedulerKind::Fcfs, seed + 2);
        assert!(drop1 > 0.7, "seed {seed}: single-device drop {drop1}");
        assert!(drop6 < 0.08, "seed {seed}: n=6 drop {drop6}");
        assert!(
            map6 > map1 + 0.08,
            "seed {seed}: map6 {map6:.3} !>> map1 {map1:.3}"
        );
    }
}

#[test]
fn window_size_one_matches_naive_dropping() {
    // With window = 1 and one device, drops/processed ≈ λ/μ − 1 (§II's
    // naive approach arithmetic).
    let spec = presets::eth_sunnyday(33);
    let clip = generate(&spec, None);
    let fleet = Fleet::ncs2_sticks(1, DetectorModelId::Yolov3, LinkProfile::usb3());
    let mut cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 3);
    cfg.window = Some(1);
    let run = run_online(&clip, &fleet, quality_detectors(&fleet, &spec.name, 4), &cfg);
    let dpp = run.metrics.drops_per_processed();
    assert!((dpp - (14.0 / 2.5 - 1.0)).abs() < 0.8, "dpp {dpp}");
}

#[test]
fn proportional_converges_to_wrr_split() {
    // On a stable heterogeneous fleet the proportional scheduler's
    // device split approaches the static-weight split.
    let spec = small_clip(44, 30.0, 300);
    let clip = generate(&spec, None);
    let fleet = Fleet::cpu_plus_sticks(
        DeviceKind::FastCpu,
        2,
        DetectorModelId::Yolov3,
        LinkProfile::usb3(),
    );
    let cfg = RunConfig::new(SchedulerKind::Proportional, SourceMode::Saturated, 5);
    let run = run_online(&clip, &fleet, quality_detectors(&fleet, &spec.name, 6), &cfg);
    let cpu = run.metrics.device_frames[0] as f64;
    let stick = run.metrics.device_frames[1].max(1) as f64;
    let ratio = cpu / stick;
    // Rates 13.5 vs 2.5 -> ideal ratio 5.4; accept the integer-weight band.
    assert!(ratio > 3.0 && ratio < 8.0, "cpu/stick ratio {ratio}");
}

#[test]
fn stale_fill_contents_match_source_frame() {
    // A dropped frame's detections must be byte-identical to those of its
    // stale_from source record.
    let spec = presets::eth_sunnyday(55);
    let clip = generate(&spec, None);
    let fleet = Fleet::ncs2_sticks(1, DetectorModelId::Yolov3, LinkProfile::usb3());
    let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 9);
    let run = run_online(&clip, &fleet, quality_detectors(&fleet, &spec.name, 10), &cfg);
    let mut checked = 0;
    for r in &run.records {
        if let Some(src) = r.stale_from {
            let src_rec = &run.records[src as usize];
            if src_rec.processed_by.is_some() {
                assert_eq!(r.detections, src_rec.detections, "frame {}", r.frame_id);
                checked += 1;
            }
        }
    }
    assert!(checked > 50, "only {checked} stale fills verified");
}

#[test]
fn offline_detector_independent_of_fleet_rng() {
    // Quality detectors are deterministic per seed regardless of fleet.
    let spec = presets::eth_sunnyday(66);
    let clip = generate(&spec, None);
    let prof = QualityProfile::calibrated(DetectorModelId::Yolov3, "eth_sunnyday");
    let mut d1 = QualityModelDetector::new(prof.clone(), 5);
    let mut d2 = QualityModelDetector::new(prof, 5);
    for f in clip.frames.iter().take(30) {
        assert_eq!(d1.detect(f), d2.detect(f));
    }
}
