//! Integration tests for the serialisable control plane and the shard
//! subsystem: wire round-trip of every control action in a real
//! controlled run, log replay identity, detach-re-levelling driven by a
//! decoded wire event, sharded-vs-single parity, and shard-loss
//! re-placement.

use eva::autoscale::{AutoscaleConfig, AutoscaleController};
use eva::control::{ControlAction, ControlEvent, ControlOrigin, EventLog, WireEvent};
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::fleet::{run_fleet, run_fleet_with, AdmissionPolicy, Decision, Scenario, StreamSpec};
use eva::shard::{run_sharded, PlacementPolicy, ShardScenario};

fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
    rates
        .iter()
        .enumerate()
        .map(|(i, &r)| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r))
        .collect()
}

fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
    devices(&vec![rate; n])
}

fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
        .collect()
}

/// Acceptance: every control action in a controlled sim run round-trips
/// through `control::WireEvent` encode→decode, and replaying the decoded
/// log as scripted events reproduces an identical event log.
#[test]
fn controlled_run_control_log_roundtrips_and_replays_identically() {
    // Under-provisioned load so the autoscale controller emits real
    // actions (device attaches) on top of scripted membership changes.
    let cfg = AutoscaleConfig {
        max_devices: 8,
        ..AutoscaleConfig::default()
    };
    let scenario = Scenario::new(pool(2, 2.5), uniform_streams(4, 5.0, 300, 4))
        .with_admission(cfg.admission())
        .with_seed(41);
    let mut controller = AutoscaleController::new(cfg);
    let out = run_fleet_with(&scenario, Some(&mut controller));
    assert!(
        !out.control_log.is_empty(),
        "expected controller actions under 2x overload"
    );

    // Encode→decode the full log: identical events, byte-for-byte
    // reparseable JSON.
    let log = out.wire_log();
    assert_eq!(log.len(), out.control_log.len());
    let decoded = EventLog::decode(&log.encode()).expect("wire log decodes");
    assert_eq!(decoded, log, "decoded wire log differs from the original");

    // Replay: the decoded actions, scheduled as scripted events at their
    // recorded times, must be applied at exactly those times — the
    // replayed run's event log is identical (times, actions, order).
    let replay_scenario = Scenario::new(pool(2, 2.5), uniform_streams(4, 5.0, 300, 4))
        .with_admission(scenario.admission.clone())
        .with_seed(41)
        .with_events(decoded.scripted_events());
    let replayed = run_fleet_with(&replay_scenario, None);
    assert_eq!(replayed.control_log.len(), out.control_log.len());
    for (a, b) in replayed.control_log.iter().zip(&out.control_log) {
        assert_eq!(a.at, b.at, "replayed event time drifted");
        assert_eq!(a.action, b.action, "replayed action differs");
        // Replayed events are scripted by construction.
        assert_eq!(a.origin, ControlOrigin::Scripted);
    }
    // And the replay reaches the same capacity end-state (same attaches
    // applied at the same virtual times).
    assert_eq!(
        replayed.report.device_labels.len(),
        out.report.device_labels.len()
    );
}

/// Satellite regression: admission re-levelling on stream detach still
/// restores the survivors when the detach arrives as a decoded
/// `WireEvent` rather than a direct registry call.
#[test]
fn detach_as_decoded_wire_event_restores_survivor_admission() {
    // Pool capacity 7.125: two 5-FPS streams start degraded (share
    // 3.5625 → stride 2). Stream 0's detach arrives over the wire.
    let detach = WireEvent::action(
        20.0,
        ControlOrigin::Placement,
        ControlAction::DetachStream(0),
    );
    let json = detach.encode();
    let decoded = WireEvent::decode(&json).expect("detach event decodes");
    let action = decoded.as_action().expect("action payload").clone();
    let events = vec![ControlEvent {
        at: decoded.at,
        action,
    }];

    let scenario = Scenario::new(pool(3, 2.5), uniform_streams(2, 5.0, 300, 4))
        .with_seed(43)
        .with_events(events);
    let report = run_fleet(&scenario);
    let survivor = &report.streams[1];
    assert!(
        matches!(survivor.decision, Decision::Admit { .. }),
        "survivor not restored after wire-decoded detach: {:?}",
        survivor.decision
    );
    // Restored at full rate for 2/3 of its life: processes far more than
    // the degraded half share would allow.
    assert!(
        survivor.metrics.frames_processed > 180,
        "survivor processed {}",
        survivor.metrics.frames_processed
    );
    // The detached stream's record log stops near the detach point.
    assert!(report.streams[0].records.len() < 150);
}

/// Acceptance: a 2-shard balanced split matches the single pool's
/// delivered FPS within 5% at equal capacity.
#[test]
fn two_shard_split_matches_single_pool_within_5_percent() {
    let mk = |shards: usize| {
        let per = 8 / shards;
        let pools: Vec<Vec<DeviceInstance>> = (0..shards).map(|_| pool(per, 2.5)).collect();
        let scenario = ShardScenario::builder(pools, uniform_streams(8, 10.0, 300, 4))
            .admission(AdmissionPolicy::admit_all())
            .gossip(10.0)
            .epochs(5)
            .seed(47)
            .build();
        run_sharded(&scenario)
    };
    let single = mk(1);
    let two = mk(2);
    let ratio = two.delivered_fps() / single.delivered_fps();
    assert!(
        (ratio - 1.0).abs() < 0.05,
        "2-shard σ {:.2} vs single {:.2} (ratio {ratio:.3})",
        two.delivered_fps(),
        single.delivered_fps()
    );
    // Same accounting window in both runs.
    assert_eq!(single.epochs_run, two.epochs_run);
}

/// Acceptance: shard loss re-places every orphaned stream on surviving
/// shards within one gossip interval.
#[test]
fn shard_loss_replaces_all_orphans_within_one_gossip_interval() {
    let scenario = ShardScenario::builder(
        vec![pool(4, 2.5), pool(4, 2.5), pool(4, 2.5)],
        uniform_streams(9, 2.5, 200, 4),
    )
    .gossip(10.0)
    .epochs(10)
    .seed(53)
    .failure(3, 1)
    .build();
    let report = run_sharded(&scenario);
    assert!(!report.shard_alive[1]);
    assert_eq!(report.orphan_count(), 3);
    assert!(
        report.orphans_replaced_within(report.gossip_interval),
        "worst orphan gap {:.1}s vs gossip interval {:.1}s",
        report.worst_orphan_gap(),
        report.gossip_interval
    );
    for s in &report.streams {
        if s.orphaned_for.is_some() {
            assert!(
                matches!(s.final_shard, Some(0) | Some(2)),
                "orphan {} ended on {:?}",
                s.name,
                s.final_shard
            );
            assert!(s.frames_processed > 0, "orphan {} never served", s.name);
        }
    }
}

/// Satellite regression + acceptance: a sharded-autoscale run's decoded
/// audit log replays into scripted events that reproduce the
/// coordinator's control log verbatim — times, actions and order — and
/// the run is deterministic under its seed. The CI soak step re-runs
/// this with distinct seeds via `EVA_SOAK_SEED` so nondeterminism in
/// the new wire path fails loudly.
#[test]
fn sharded_autoscale_audit_log_replays_verbatim() {
    let seed = std::env::var("EVA_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(131);
    let scenario = eva::experiments::shard::overload_scenario(seed, true);
    let report = run_sharded(&scenario);
    // Local scaling pre-empts migration at 2× load...
    assert_eq!(report.migrations, 0, "seed {seed}");
    assert!(report.scale_actions() >= 1, "seed {seed}");
    // ...and every scale action is present in the decoded audit log.
    let audit = report.audit_log();
    assert_eq!(audit.len(), report.control_log.len());
    let decoded = EventLog::decode(&audit.encode()).expect("audit log decodes");
    assert_eq!(decoded, audit, "seed {seed}");
    // The decoded log lowers into scripted events that reproduce the
    // control log verbatim (a sharded run routes only action payloads,
    // so nothing is skipped).
    let scripted = decoded.scripted_events();
    assert_eq!(scripted.len(), report.control_log.len(), "seed {seed}");
    for (ev, c) in scripted.iter().zip(&report.control_log) {
        assert_eq!(ev.at, c.event.at, "seed {seed}: replayed event time drifted");
        assert_eq!(
            Some(&ev.action),
            c.event.as_action(),
            "seed {seed}: replayed action differs"
        );
    }
    // Determinism under the chosen seed: the wire path must not wobble.
    let again = run_sharded(&scenario);
    assert_eq!(again.control_log, report.control_log, "seed {seed}");
    assert_eq!(again.total_processed(), report.total_processed(), "seed {seed}");
}

/// Every control event a sharded run routes is the *decoded* form of
/// its JSON encoding, and the whole log survives another wire hop.
#[test]
fn shard_control_log_is_wire_clean() {
    let scenario = ShardScenario::builder(
        vec![pool(2, 2.5), pool(2, 2.5)],
        uniform_streams(4, 2.5, 100, 4),
    )
    .policy(PlacementPolicy::RoundRobin)
    .gossip(10.0)
    .epochs(6)
    .seed(59)
    .build();
    let report = run_sharded(&scenario);
    assert!(!report.control_log.is_empty());
    let mut log = EventLog::new();
    for c in &report.control_log {
        // Each routed event re-encodes and decodes to itself.
        let again = WireEvent::decode(&c.event.encode()).expect("event re-decodes");
        assert_eq!(again, c.event);
        assert_eq!(c.event.origin, ControlOrigin::Placement);
        log.push(c.event.clone());
    }
    let decoded = EventLog::decode(&log.encode()).expect("log decodes");
    assert_eq!(decoded, log);
}
