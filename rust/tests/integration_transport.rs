//! Integration tests for the cross-host transport: loopback parity of
//! the socket co-simulation against the in-process twin, connection
//! loss surfacing as shard loss with one-interval re-placement, the
//! remote `fleet::serve` consumer driven by a decoded event-log stream,
//! and determinism of the remote runner across repeated runs.

use eva::control::{ControlAction, ControlOrigin};
use eva::detector::Detector;
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::experiments::transport::{connection_loss, loopback_parity};
use eva::fleet::{AdmissionPolicy, FleetServeConfig, StreamSpec};
use eva::shard::{run_sharded, run_sharded_remote, RemoteTransport, ShardReport, ShardScenario};
use eva::transport::{drive_remote_serve, run_serve_consumer, Endpoint, Listener, TransportMsg};
use eva::types::{Detection, Frame};

fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
    (0..n)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
        .collect()
}

fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
        .collect()
}

struct EchoDetector;

impl Detector for EchoDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        frame
            .ground_truth
            .iter()
            .map(|gt| Detection {
                bbox: gt.bbox,
                class_id: gt.class_id,
                score: 0.9,
            })
            .collect()
    }

    fn label(&self) -> String {
        "echo".into()
    }
}

/// Acceptance: a 2-shard run over loopback TCP (and over Unix-domain
/// sockets) matches the in-process co-simulation's delivered FPS within
/// 5% at equal capacity.
#[test]
fn loopback_socket_cosim_matches_inproc_within_5_percent() {
    let (_, outcomes) = loopback_parity(83);
    assert_eq!(outcomes[0].transport, "inproc");
    assert_eq!(outcomes.len(), 3);
    for o in &outcomes[1..] {
        assert!(
            (o.vs_inproc - 1.0).abs() < 0.05,
            "{}: σ {:.2} is {:.3}× the in-process co-sim",
            o.transport,
            o.delivered_fps,
            o.vs_inproc
        );
        // The socket runs routed real control traffic (8 placements at
        // minimum), every event of it a decoded frame.
        assert!(o.control_events >= 8, "{}: {} events", o.transport, o.control_events);
    }
}

/// Acceptance: killing one shard's connection re-places all its
/// orphaned streams within one gossip interval.
#[test]
fn killed_connection_replaces_orphans_within_one_gossip_interval() {
    let (_, o) = connection_loss(89);
    assert_eq!(o.orphans, 3, "{o:?}");
    assert!(o.replaced_within_interval, "{o:?}");
    assert!(o.worst_gap <= 10.0 + 1e-9, "{o:?}");
    assert_eq!(o.shards_alive, 2);
    assert!(o.delivered_fps > 0.0);
}

/// The remote runner is deterministic: same scenario, same transport,
/// identical frame accounting and control logs across runs.
#[test]
fn remote_runs_are_deterministic_and_transport_agnostic() {
    let scenario = ShardScenario::builder(
        vec![pool(3, 2.5), pool(3, 2.5)],
        uniform_streams(6, 2.5, 120, 4),
    )
    .gossip(10.0)
    .epochs(8)
    .seed(97)
    .build();
    let tcp_a = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("tcp a");
    let tcp_b = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("tcp b");
    assert_eq!(tcp_a.total_processed(), tcp_b.total_processed());
    assert_eq!(tcp_a.control_log, tcp_b.control_log);
    // The transport family changes the socket, not the outcome.
    let uds = run_sharded_remote(&scenario, RemoteTransport::Uds).expect("uds");
    assert_eq!(uds.total_processed(), tcp_a.total_processed());
    assert_eq!(uds.control_log, tcp_a.control_log);
}

/// Satellite pin: a failure-free `--autoscale` run over tcp and uds
/// matches the in-process co-simulation's frame and scale-action counts
/// *exactly* — the shard-local scale actions (device attach/detach,
/// Controller origin) cross the wire as control frames and decode back
/// to the identical event sequence. Seed comes from `EVA_SOAK_SEED`
/// when set (the CI soak step re-runs this with distinct seeds).
#[test]
fn sharded_autoscale_parity_is_exact_over_tcp_and_uds() {
    let seed = std::env::var("EVA_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(137);
    let scenario = eva::experiments::shard::overload_scenario(seed, true);
    let inproc = run_sharded(&scenario);
    assert!(inproc.scale_actions() >= 1, "seed {seed}");
    assert_eq!(inproc.migrations, 0, "seed {seed}");
    fn scale_events(r: &ShardReport) -> Vec<eva::shard::ShardControl> {
        r.control_log
            .iter()
            .filter(|c| c.event.origin == ControlOrigin::Controller)
            .cloned()
            .collect()
    }
    for transport in [RemoteTransport::Tcp, RemoteTransport::Uds] {
        let remote = run_sharded_remote(&scenario, transport).expect("remote autoscale run");
        let label = transport.label();
        assert_eq!(remote.total_frames(), inproc.total_frames(), "{label} seed {seed}");
        assert_eq!(
            remote.total_processed(),
            inproc.total_processed(),
            "{label} seed {seed}"
        );
        assert_eq!(remote.epochs_run, inproc.epochs_run, "{label} seed {seed}");
        assert_eq!(remote.migrations, inproc.migrations, "{label} seed {seed}");
        assert_eq!(
            remote.scale_actions(),
            inproc.scale_actions(),
            "{label} seed {seed}"
        );
        // The scale-action sequence — shard attribution, times, payloads
        // — is identical event for event.
        assert_eq!(scale_events(&remote), scale_events(&inproc), "{label} seed {seed}");
    }
}

/// Forecast parity pin: the fused diurnal run — forecasters observing
/// every epoch, the predicted Σλ riding gossip digests, the hint
/// steering each shard's autoscale floor — produces *bit-identical*
/// forecast-Σλ digest sequences (and identical frame accounting and
/// control logs) in-process and over tcp/uds. Seed comes from
/// `EVA_SOAK_SEED` when set (the CI soak step re-runs this with
/// distinct seeds; the name carries "autoscale" so the soak filter
/// picks it up).
#[test]
fn forecast_fused_autoscale_digests_are_exact_over_tcp_and_uds() {
    let seed = std::env::var("EVA_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(137);
    let scenario = eva::experiments::forecast::diurnal_scenario(seed, true);
    let inproc = run_sharded(&scenario);
    assert!(
        !inproc.forecast_trace.is_empty(),
        "seed {seed}: the fused run must publish forecast digests"
    );
    for transport in [RemoteTransport::Tcp, RemoteTransport::Uds] {
        let remote = run_sharded_remote(&scenario, transport).expect("remote fused run");
        let label = transport.label();
        // Bit-equality on the published (epoch, shard, Σλ) sequence: the
        // remote forecaster mirror observed the same windows in the same
        // order with the same arithmetic.
        assert_eq!(remote.forecast_trace, inproc.forecast_trace, "{label} seed {seed}");
        assert_eq!(remote.total_frames(), inproc.total_frames(), "{label} seed {seed}");
        assert_eq!(
            remote.total_processed(),
            inproc.total_processed(),
            "{label} seed {seed}"
        );
        assert_eq!(remote.migrations, inproc.migrations, "{label} seed {seed}");
        assert_eq!(remote.control_log, inproc.control_log, "{label} seed {seed}");
    }
}

/// Telemetry pin: the metric registry a remote coordinator assembles
/// from per-epoch `TransportMsg::Telemetry` snapshots over tcp and uds
/// is *byte-identical* (JSON snapshot and text exposition alike) to the
/// in-process co-simulation's — under autoscale, where shard-local
/// scale actions also feed the registry. Seed comes from
/// `EVA_SOAK_SEED` when set, same as the parity pin above.
#[test]
fn telemetry_snapshots_match_inproc_exactly_with_autoscale() {
    let seed = std::env::var("EVA_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(137);
    let scenario = ShardScenario {
        telemetry: true,
        ..eva::experiments::shard::overload_scenario(seed, true)
    };
    let inproc = run_sharded(&scenario);
    assert!(
        inproc.telemetry.counter_family_total("eva_frames_total") > 0,
        "seed {seed}: traced run must populate the registry"
    );
    for transport in [RemoteTransport::Tcp, RemoteTransport::Uds] {
        let remote = run_sharded_remote(&scenario, transport).expect("remote traced run");
        let label = transport.label();
        assert_eq!(
            remote.telemetry.to_json().to_string(),
            inproc.telemetry.to_json().to_string(),
            "{label} seed {seed}: wire-assembled registry snapshot must match in-process exactly"
        );
        assert_eq!(
            remote.telemetry.text_exposition(),
            inproc.telemetry.text_exposition(),
            "{label} seed {seed}"
        );
    }
}

/// Tentpole pin: a binary-codec remote run is indistinguishable from
/// the JSON-codec run once decoded — same frame accounting, same
/// control log — and the coordinator's audit [`eva::control::EventLog`]
/// of the binary-transported run replays verbatim through
/// encode→decode.
#[test]
fn binary_codec_remote_run_replays_the_same_audit_log() {
    let scenario = ShardScenario::builder(
        vec![pool(3, 2.5), pool(3, 2.5)],
        uniform_streams(6, 2.5, 120, 4),
    )
    .gossip(10.0)
    .epochs(8)
    .seed(97)
    .build();
    let json_run = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("json run");
    let binary = ShardScenario {
        codec: eva::transport::Codec::Binary,
        ..scenario.clone()
    };
    let binary_run = run_sharded_remote(&binary, RemoteTransport::Tcp).expect("binary run");
    assert_eq!(binary_run.total_frames(), json_run.total_frames());
    assert_eq!(binary_run.total_processed(), json_run.total_processed());
    assert_eq!(binary_run.control_log, json_run.control_log);
    // The audit contract survives the codec swap bit-for-bit: the
    // binary run's log equals the JSON run's and replays through
    // another encode→decode hop unchanged.
    let audit = binary_run.audit_log();
    assert_eq!(audit, json_run.audit_log());
    let replayed = eva::control::EventLog::decode(&audit.encode()).expect("audit log decodes");
    assert_eq!(replayed, audit);
}

/// The remote serve consumer takes exactly the admission decisions the
/// in-process wall-clock engine takes for the same specs and pool, and
/// ships them back as decoded control frames.
#[test]
fn remote_serve_consumer_matches_local_decisions() {
    let endpoint = Endpoint::temp_uds("it-serve");
    let listener = Listener::bind(&endpoint).expect("bind");
    let config = FleetServeConfig {
        admission: AdmissionPolicy::default(),
        device_rates: vec![60.0],
        paced: false,
        gate: None,
    };
    let consumer_config = config.clone();
    let consumer = std::thread::spawn(move || {
        run_serve_consumer(&listener, &consumer_config, |_| {
            Ok(Box::new(EchoDetector) as Box<dyn Detector>)
        })
    });

    let specs = vec![
        StreamSpec::new("cam-a", 25.0, 40).with_window(4),
        StreamSpec::new("cam-b", 25.0, 40).with_window(4),
        StreamSpec::new("cam-c", 25.0, 40).with_window(4),
    ];
    let outcome = drive_remote_serve(&endpoint, &specs).expect("drive");
    let (report, decisions) = consumer
        .join()
        .expect("consumer thread")
        .expect("consumer ran")
        .expect("consumer served");

    // One decision frame per stream, identical to the consumer's local
    // wire log (they crossed the socket and decoded back equal).
    assert_eq!(outcome.decisions.len(), specs.len());
    assert_eq!(outcome.decisions, decisions.events);
    for (i, s) in report.streams.iter().enumerate() {
        assert_eq!(outcome.streams[i].id, s.id);
        assert_eq!(outcome.streams[i].processed, s.metrics.frames_processed);
    }
    assert!(outcome.processed > 0);
    assert_eq!(
        outcome.processed,
        report.streams.iter().map(|s| s.metrics.frames_processed).sum::<u64>()
    );
}

/// A remote run over TCP with a migration-provoking placement: the
/// control log shows the detach→attach pair crossing the wire and the
/// stream ends on the target shard.
#[test]
fn remote_migration_crosses_the_wire_as_detach_attach() {
    // Round-robin parks both heavy streams by arrival index: demands
    // [9, 1, 9, 1] put 18 FPS on shard 0 (capacity 14.25) — the gossip
    // rebalancer must migrate one heavy stream.
    let mut streams = Vec::new();
    for (i, fps) in [9.0, 1.0, 9.0, 1.0].iter().enumerate() {
        streams.push(StreamSpec::new(&format!("s{i}"), *fps, (*fps * 60.0) as u64).with_window(4));
    }
    let scenario = ShardScenario::builder(vec![pool(6, 2.5), pool(6, 2.5)], streams)
        .policy(eva::shard::PlacementPolicy::RoundRobin)
        .gossip(10.0)
        .epochs(8)
        .seed(101)
        .build();
    let report = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");
    assert_eq!(report.migrations, 1, "{:?}", report.control_log.len());
    let detaches = report
        .control_log
        .iter()
        .filter(|c| matches!(c.event.as_action(), Some(ControlAction::DetachStream(_))))
        .count();
    assert!(detaches >= 1);
    let migrated: Vec<_> = report.streams.iter().filter(|s| s.migrations > 0).collect();
    assert_eq!(migrated.len(), 1);
    assert_eq!(migrated[0].demand, 9.0);
}

/// Session-protocol sanity over a raw connection: a driver that speaks
/// garbage gets a framing error, not a hang or a panic.
#[test]
fn consumer_survives_driver_going_silent_after_bye() {
    let endpoint = Endpoint::temp_uds("it-bye");
    let listener = Listener::bind(&endpoint).expect("bind");
    let config = FleetServeConfig {
        admission: AdmissionPolicy::default(),
        device_rates: vec![50.0],
        paced: false,
        gate: None,
    };
    let consumer = std::thread::spawn(move || {
        run_serve_consumer(&listener, &config, |_| {
            Ok(Box::new(EchoDetector) as Box<dyn Detector>)
        })
    });
    let mut conn =
        eva::transport::connect_with_backoff(&endpoint, 10, std::time::Duration::from_millis(5))
            .expect("connect");
    conn.send(&TransportMsg::Bye).expect("bye");
    drop(conn);
    // Bye before any Tick: the consumer returns cleanly with no run.
    let served = consumer.join().expect("thread").expect("consumer ok");
    assert!(served.is_none());
}
