//! Integration tests for motion-gated detection end to end: the gated
//! wire log's replay contract in the virtual-time engine, and exact
//! gate-verdict parity between the in-process sharded co-simulation and
//! its tcp/uds socket twins.

use eva::control::{ControlOrigin, EventLog, WirePayload};
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::fleet::{run_fleet_with, AdmissionPolicy, Scenario, StreamSpec};
use eva::gate::{GateConfig, GateVerdict, MotionDynamics};
use eva::shard::{
    run_sharded, run_sharded_remote, RemoteTransport, ShardControl, ShardReport, ShardScenario,
};

fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
    (0..n)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
        .collect()
}

fn quiet_streams(n: usize, fps: f64, frames: u64) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("lobby{i}"), fps, frames).with_window(4))
        .collect()
}

fn gate_events(r: &ShardReport) -> Vec<ShardControl> {
    r.control_log
        .iter()
        .filter(|c| c.event.origin == ControlOrigin::Gate)
        .cloned()
        .collect()
}

/// A gated virtual-time fleet run is deterministic, its wire log
/// carries the gate verdicts, and the log survives encode → decode
/// verbatim (the EventLog replay contract).
#[test]
fn gated_fleet_wire_log_replays_verbatim() {
    let scenario = || {
        Scenario::new(
            pool(1, 18.0),
            vec![StreamSpec::new("lobby", 15.0, 450).with_window(4)],
        )
        .with_admission(AdmissionPolicy::admit_all())
        .with_seed(7)
        .with_gate(GateConfig::for_dynamics(MotionDynamics::lobby()))
    };
    let a = run_fleet_with(&scenario(), None);
    let b = run_fleet_with(&scenario(), None);
    let log = a.wire_log();
    assert_eq!(log, b.wire_log(), "gated runs must be deterministic");

    let verdicts = log
        .events
        .iter()
        .filter(|e| e.origin == ControlOrigin::Gate)
        .count();
    assert!(verdicts > 100, "expected a skip-heavy lobby log, got {verdicts}");
    let skips = log
        .events
        .iter()
        .filter(|e| {
            matches!(
                e.payload,
                WirePayload::Gate { verdict: GateVerdict::Skip, .. }
            )
        })
        .count();
    let caps = log
        .events
        .iter()
        .filter(|e| {
            matches!(
                e.payload,
                WirePayload::Gate { verdict: GateVerdict::SkipCap, .. }
            )
        })
        .count();
    assert!(skips > 0 && caps > 0, "skips {skips}, caps {caps}");

    let decoded = EventLog::decode(&log.encode()).expect("gated wire log must decode");
    assert_eq!(decoded, log, "encode -> decode must be verbatim");
}

/// Acceptance: a gated sharded run's control log — gate verdicts
/// included, remapped to global stream ids and shard-shifted times —
/// is identical event for event between the in-process co-simulation
/// and the socket runners over tcp and uds, and the audit log replays
/// verbatim on both sides. Seed comes from `EVA_SOAK_SEED` when set.
#[test]
fn gated_shard_parity_is_exact_over_tcp_and_uds() {
    let seed = std::env::var("EVA_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(53);
    let scenario = ShardScenario::builder(
        vec![pool(3, 2.5), pool(3, 2.5)],
        quiet_streams(4, 5.0, 100),
    )
    .gossip(10.0)
    .epochs(6)
    .seed(seed)
    .gate(GateConfig::for_dynamics(MotionDynamics::lobby()))
    .build();

    let inproc = run_sharded(&scenario);
    let local = gate_events(&inproc);
    assert!(local.len() > 50, "seed {seed}: only {} gate events", local.len());
    let audit = inproc.audit_log();
    assert_eq!(
        EventLog::decode(&audit.encode()).expect("inproc audit log must decode"),
        audit,
        "seed {seed}"
    );

    for transport in [RemoteTransport::Tcp, RemoteTransport::Uds] {
        let label = transport.label();
        let remote = run_sharded_remote(&scenario, transport).expect("remote gated run");
        assert_eq!(remote.total_frames(), inproc.total_frames(), "{label} seed {seed}");
        assert_eq!(
            remote.total_processed(),
            inproc.total_processed(),
            "{label} seed {seed}"
        );
        assert_eq!(remote.epochs_run, inproc.epochs_run, "{label} seed {seed}");
        // The gate-verdict sequence — shard attribution, times, stream
        // ids, payloads — crossed the wire unchanged.
        assert_eq!(gate_events(&remote), local, "{label} seed {seed}");
        let remote_audit = remote.audit_log();
        assert_eq!(
            EventLog::decode(&remote_audit.encode()).expect("remote audit log must decode"),
            remote_audit,
            "{label} seed {seed}"
        );
    }
}

/// Gating quiet content frees device capacity without shrinking frame
/// accounting: same offered frames, fewer detector runs.
#[test]
fn gated_shard_run_detects_fewer_frames_at_equal_coverage() {
    let base = ShardScenario::builder(
        vec![pool(3, 2.5), pool(3, 2.5)],
        quiet_streams(4, 5.0, 100),
    )
    .gossip(10.0)
    .epochs(6)
    .seed(23);
    let plain = base.clone().build();
    let gated = base
        .gate(GateConfig::for_dynamics(MotionDynamics::lobby()))
        .build();
    let plain_report = run_sharded(&plain);
    let gated_report = run_sharded(&gated);
    assert_eq!(plain_report.total_frames(), gated_report.total_frames());
    assert!(
        gated_report.total_processed() < plain_report.total_processed(),
        "gated {} vs plain {}",
        gated_report.total_processed(),
        plain_report.total_processed()
    );
    assert!(gate_events(&gated_report).len() > 50);
    assert!(gate_events(&plain_report).is_empty());
}
