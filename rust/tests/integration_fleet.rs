//! Integration tests for the fleet subsystem: work-conserving dispatch,
//! admission-bounded latency under overload, cross-stream fairness, and
//! record conservation across randomized scenarios.

use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::fleet::{run_fleet, AdmissionPolicy, Scenario, StreamSpec};
use eva::util::prop::{check, Config};

fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
    rates
        .iter()
        .enumerate()
        .map(|(i, &r)| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r))
        .collect()
}

fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
        .collect()
}

#[test]
fn work_conserving_dispatch_approaches_aggregate_rate() {
    // Heterogeneous pool Σμ = 2.5 + 2.5 + 13.5 + 0.4 = 18.9 FPS, fed by
    // 6 × 10-FPS streams (offered 60 ≫ Σμ) with deep windows: aggregate
    // throughput must approach Σμ — the defining property of
    // work-conserving dispatch (no barrier, no idle device while any
    // stream has backlog).
    let rates = [2.5, 2.5, 13.5, 0.4];
    let ideal: f64 = rates.iter().sum();
    let scenario = Scenario::new(
        devices(&rates),
        uniform_streams(6, 10.0, 300, 16),
    )
    .with_admission(AdmissionPolicy::admit_all())
    .with_seed(101);
    let report = run_fleet(&scenario);
    let sigma = report.aggregate_fps();
    assert!(
        (sigma - ideal).abs() / ideal < 0.1,
        "aggregate σ {sigma:.2} vs Σμ {ideal:.2}"
    );
    // The fast device does most of the work; the straggler is not a
    // bottleneck (that would be the round-robin failure mode).
    assert!(report.device_frames[2] > report.device_frames[3] * 10);
}

#[test]
fn admission_bounds_p99_latency_under_2x_overload() {
    // Pool Σμ = 10 (4 × 2.5), offered 8 × 2.5 = 20 FPS: 2× overload.
    //
    // With admission enforced, re-levelled shares throttle every stream
    // (stride 3 → admitted effective load ≈ 6.7 FPS < capacity), so
    // admitted streams' p99 output latency stays small. With admission
    // off the same overload is absorbed by window evictions, whose
    // latency is pinned near window/λ = 1.6 s — measurably worse.
    let pool = [2.5, 2.5, 2.5, 2.5];
    let offered = uniform_streams(8, 2.5, 250, 4);

    let enforced = run_fleet(
        &Scenario::new(devices(&pool), offered.clone())
            .with_admission(AdmissionPolicy::default())
            .with_seed(7),
    );
    let admit_all = run_fleet(
        &Scenario::new(devices(&pool), offered)
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(7),
    );

    let mut enforced_p99 = Vec::new();
    for s in enforced.streams.iter() {
        assert!(
            s.decision.is_admitted(),
            "with fair shares ≥ min_rate every stream stays admitted: {:?}",
            s.decision
        );
    }
    for s in enforced.streams.iter() {
        enforced_p99.push(s.metrics.latency.p99());
    }
    let mut admit_all_p99 = Vec::new();
    for s in admit_all.streams.iter() {
        admit_all_p99.push(s.metrics.latency.p99());
    }

    let worst_enforced = enforced_p99.iter().cloned().fold(0.0, f64::max);
    let mean_admit_all = admit_all_p99.iter().sum::<f64>() / admit_all_p99.len() as f64;
    assert!(
        worst_enforced < 1.5,
        "admitted p99 must stay bounded under overload: {worst_enforced:.2} s"
    );
    assert!(
        worst_enforced + 0.2 < mean_admit_all,
        "admission must beat admit-all on tail latency: {worst_enforced:.2} vs {mean_admit_all:.2}"
    );
    // Admission keeps the admitted effective load within capacity, so
    // drops beyond the mandated stride are rare.
    for s in enforced.streams.iter() {
        let stride = s.decision.stride();
        let kept = (0..s.metrics.frames_total).filter(|f| f % stride == 0).count() as u64;
        assert!(
            s.metrics.frames_processed * 10 >= kept * 8,
            "stream {} processed {} of {} kept frames",
            s.name,
            s.metrics.frames_processed,
            kept
        );
    }
}

#[test]
fn weighted_fairness_under_saturation() {
    // Two saturated streams, weights 3:1, homogeneous pool: processed
    // throughput splits ≈ 3:1 and the weight-normalised Jain index is
    // near 1.
    let streams = vec![
        StreamSpec::new("heavy", 20.0, 600).with_window(16).with_weight(3.0),
        StreamSpec::new("light", 20.0, 600).with_window(16).with_weight(1.0),
    ];
    let scenario = Scenario::new(devices(&[2.5, 2.5]), streams)
        .with_admission(AdmissionPolicy::admit_all())
        .with_seed(23);
    let report = run_fleet(&scenario);
    let heavy = report.streams[0].metrics.frames_processed as f64;
    let light = report.streams[1].metrics.frames_processed as f64;
    let ratio = heavy / light.max(1.0);
    assert!(ratio > 2.3 && ratio < 3.7, "weighted split ratio {ratio:.2}");
    let fairness = report.fairness();
    assert!(fairness > 0.9, "weight-normalised Jain {fairness:.3}");
}

#[test]
fn prop_record_conservation_across_random_scenarios() {
    // For any pool/stream mix: every stream's record log covers exactly
    // its arrived frames, in order, and processed + dropped = total.
    check(
        "fleet record conservation",
        Config { cases: 24, base_seed: 0xF1EE7 },
        |rng| {
            let n_devices = rng.int_in(1, 5) as usize;
            let rates: Vec<f64> = (0..n_devices).map(|_| rng.range(0.5, 15.0)).collect();
            let n_streams = rng.int_in(1, 6) as usize;
            let streams: Vec<StreamSpec> = (0..n_streams)
                .map(|i| {
                    StreamSpec::new(
                        &format!("s{i}"),
                        rng.range(2.0, 20.0),
                        rng.int_in(20, 120) as u64,
                    )
                    .with_window(rng.int_in(1, 8) as usize)
                    .with_weight(rng.range(0.5, 4.0))
                })
                .collect();
            let enforce = rng.chance(0.5);
            let scenario = Scenario::new(devices(&rates), streams.clone())
                .with_admission(if enforce {
                    AdmissionPolicy::default()
                } else {
                    AdmissionPolicy::admit_all()
                })
                .with_seed(rng.next_u64());
            let report = run_fleet(&scenario);
            for (spec, s) in streams.iter().zip(&report.streams) {
                if s.records.len() as u64 != spec.num_frames {
                    return Err(format!(
                        "stream {} has {} records for {} frames",
                        s.name,
                        s.records.len(),
                        spec.num_frames
                    ));
                }
                for (i, r) in s.records.iter().enumerate() {
                    if r.frame_id != i as u64 {
                        return Err(format!(
                            "stream {} record {i} has frame id {}",
                            s.name, r.frame_id
                        ));
                    }
                    if i > 0 && s.records[i].emit_ts < s.records[i - 1].emit_ts - 1e-9 {
                        return Err(format!("stream {} emit times not monotone", s.name));
                    }
                }
                let total = s.metrics.frames_processed + s.metrics.frames_dropped;
                if total != s.metrics.frames_total {
                    return Err(format!("stream {} fate conservation broken", s.name));
                }
            }
            Ok(())
        },
    );
}

/// Schema lock: `FleetReport::to_json` output parses back through
/// `util::json` and the key fields — per-stream p99, drop rate, Jain
/// fairness — survive the round trip exactly. Guards the machine-
/// readable surface that sweep bundles and `eva fleet --json` publish.
#[test]
fn fleet_report_json_schema_locks_key_fields() {
    use eva::util::json::Json;

    // A run with real contention so drop rates and latencies are
    // non-trivial: 6 × 5-FPS streams against Σμ = 10.
    let scenario = Scenario::new(
        devices(&[2.5, 2.5, 2.5, 2.5]),
        uniform_streams(6, 5.0, 200, 4),
    )
    .with_seed(71);
    let report = run_fleet(&scenario);

    // Ground truth from the in-memory report (percentile queries are
    // read-only: they sort a local copy).
    let expected: Vec<(String, f64, f64)> = report
        .streams
        .iter()
        .map(|s| (s.name.clone(), s.metrics.latency.p99(), s.metrics.drop_rate()))
        .collect();
    let expected_fairness = report.fairness();
    let expected_drop = report.drop_rate();

    let text = report.to_json().to_string();
    let back = Json::parse(&text).expect("report JSON must reparse");

    let fairness = back.get("fairness").and_then(Json::as_f64).expect("fairness");
    assert!((fairness - expected_fairness).abs() < 1e-9, "fairness {fairness}");
    let drop = back.get("drop_rate").and_then(Json::as_f64).expect("drop_rate");
    assert!((drop - expected_drop).abs() < 1e-9, "drop {drop}");

    let streams = back.get("streams").and_then(Json::as_arr).expect("streams");
    assert_eq!(streams.len(), expected.len());
    for (j, (name, p99, drop_rate)) in streams.iter().zip(&expected) {
        assert_eq!(j.get("name").and_then(Json::as_str), Some(name.as_str()));
        let jp99 = j.get("p99_latency").and_then(Json::as_f64).expect("p99_latency");
        assert!((jp99 - p99).abs() < 1e-9, "{name}: p99 {jp99} vs {p99}");
        let jdrop = j.get("drop_rate").and_then(Json::as_f64).expect("drop_rate");
        assert!((jdrop - drop_rate).abs() < 1e-9, "{name}: drop {jdrop} vs {drop_rate}");
        // The decision / rung / stride triple is also part of the locked
        // schema (the autoscale bundles read it).
        assert!(j.get("decision").and_then(Json::as_str).is_some());
        assert!(j.get("rung").and_then(Json::as_i64).is_some());
        assert!(j.get("stride").and_then(Json::as_i64).is_some());
    }
}
