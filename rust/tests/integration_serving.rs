//! Integration tests for the real-time serving pipeline, including the
//! full PJRT path when artifacts are present.

use std::path::PathBuf;
use std::time::Duration;

use eva::detector::pjrt::PjrtDetectorFactory;
use eva::detector::Detector;
use eva::experiments::common::map_against;
use eva::runtime::{load_manifest, ModelSpec};
use eva::server::{serve, ServeConfig};
use eva::types::{Detection, Frame};
use eva::video::{generate, presets};

/// Ground-truth echo with configurable delay.
struct EchoDetector {
    delay: Duration,
}

impl Detector for EchoDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        std::thread::sleep(self.delay);
        frame
            .ground_truth
            .iter()
            .map(|gt| Detection {
                bbox: gt.bbox,
                class_id: gt.class_id,
                score: 0.95,
            })
            .collect()
    }
    fn label(&self) -> String {
        "echo".into()
    }
}

#[test]
fn parallel_workers_reduce_drops_like_the_paper() {
    // 25 ms service vs 60 FPS stream: 1 worker is 1.5x oversubscribed,
    // 3 workers have headroom. Mirrors Table IV's mechanism in real time.
    let clip = generate(&presets::tiny_clip(32, 90, 60.0, 5), None);
    let mut drops = Vec::new();
    for workers in [1usize, 3] {
        let cfg = ServeConfig {
            workers,
            window: Some(workers),
            paced: true,
        };
        let report = serve(&clip, &cfg, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(25),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(report.records.len(), clip.len());
        drops.push(report.metrics.frames_dropped);
    }
    assert!(
        drops[0] > drops[1] + 15,
        "1-worker drops {} vs 3-worker drops {}",
        drops[0],
        drops[1]
    );
}

#[test]
fn serving_map_recovers_with_workers() {
    // Fast-moving objects at 25 FPS with 160 ms service: one worker keeps
    // only ~25% of frames and their stale fills misalign; five workers
    // keep nearly everything.
    let mut spec = presets::tiny_clip(32, 100, 25.0, 6);
    spec.min_speed = 0.5;
    spec.max_speed = 1.0;
    let clip = generate(&spec, None);
    let mut maps = Vec::new();
    for workers in [1usize, 5] {
        let cfg = ServeConfig {
            workers,
            window: Some(workers),
            paced: true,
        };
        let report = serve(&clip, &cfg, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(160),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        let dets: Vec<Vec<Detection>> =
            report.records.iter().map(|r| r.detections.clone()).collect();
        maps.push(map_against(&clip, &dets));
    }
    assert!(
        maps[1] > maps[0] + 0.05,
        "mAP 1w {:.3} vs 5w {:.3}",
        maps[0],
        maps[1]
    );
}

fn pjrt_factory(model: &str) -> Option<PjrtDetectorFactory> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = load_manifest(&dir).unwrap();
    Some(PjrtDetectorFactory::new(ModelSpec::new(
        manifest.get(model)?.clone(),
    )))
}

#[test]
fn pjrt_end_to_end_serving() {
    // The full stack: rust-rastered pixels -> PJRT TinyDet (Pallas conv
    // inside the artifact) -> NMS -> synchronizer -> mAP.
    let Some(factory) = pjrt_factory("essd") else { return };
    let size = factory.spec.meta.input_size;
    let clip = generate(&presets::tiny_clip(size, 30, 8.0, 11), Some(size));
    let cfg = ServeConfig {
        workers: 2,
        window: None,
        paced: true,
    };
    let report = serve(&clip, &cfg, |_| {
        Ok(Box::new(factory.build()?) as Box<dyn Detector>)
    })
    .unwrap();
    assert_eq!(report.records.len(), 30);
    // Plenty of capacity at 8 FPS: nothing should drop.
    assert_eq!(report.metrics.frames_dropped, 0, "dropped frames");
    let dets: Vec<Vec<Detection>> =
        report.records.iter().map(|r| r.detections.clone()).collect();
    let map = map_against(&clip, &dets);
    assert!(map > 0.25, "pjrt e2e mAP {map:.3}");
    // All workers participated.
    assert!(report.worker_stats.iter().all(|(frames, _)| *frames > 0));
}

#[test]
fn pjrt_detector_consistent_across_replicas() {
    // Two independently-compiled replicas of the same artifact must agree
    // exactly (deterministic CPU execution).
    let Some(factory) = pjrt_factory("essd") else { return };
    let size = factory.spec.meta.input_size;
    let clip = generate(&presets::tiny_clip(size, 3, 10.0, 13), Some(size));
    let mut a = factory.build().unwrap();
    let mut b = factory.build().unwrap();
    for f in &clip.frames {
        assert_eq!(a.detect(f), b.detect(f));
    }
}
