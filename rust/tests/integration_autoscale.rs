//! Integration tests for the autoscale subsystem: band convergence,
//! anti-flapping, ladder restore after load subsides, and the headline
//! quality claim — ladder + autoscale beats stride-only degradation on
//! delivered mAP at 2× overload while holding the p99 bound.

use eva::autoscale::{device_band, run_autoscale_sim, AutoscaleConfig, ModelLadder};
use eva::experiments::autoscale::{step_load, STEP_T_OFF};
use eva::experiments::fleet::pool_of;
use eva::fleet::{Scenario, StreamSpec};

fn uniform_streams(n: usize, fps: f64, frames: u64) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(4))
        .collect()
}

#[test]
fn controller_converges_into_the_nselect_band() {
    // 4 × 5-FPS streams (Σλ = 20) starting on 2 × 2.5-FPS devices. Slow
    // streams (λ ≤ 12) collapse the generalised band to the conservative
    // point: ⌈20 / (2.5 · 0.95)⌉ = 9 devices. The controller must climb
    // there — one attach per cooldown — and stay.
    let cfg = AutoscaleConfig {
        cooldown: 5.0,
        max_devices: 12,
        ..AutoscaleConfig::default()
    };
    let band = device_band(&[5.0; 4], cfg.device_rate, cfg.target_utilization);
    assert_eq!((band.lo, band.hi), (9, 9));

    let scenario = Scenario::new(pool_of(2, 2.5), uniform_streams(4, 5.0, 600))
        .with_admission(cfg.admission())
        .with_seed(41);
    let out = run_autoscale_sim(&scenario, &cfg);
    let final_devices = out.final_devices();
    assert!(
        band.contains(final_devices),
        "final {final_devices} devices outside band [{}, {}]",
        band.lo,
        band.hi
    );
    // Monotone climb: attaches only, no churn on the way up.
    assert_eq!(out.device_actions, final_devices - 2);
    for w in out.device_timeline.windows(2) {
        assert!(w[1].1 == w[0].1 + 1, "non-monotone timeline {:?}", out.device_timeline);
    }
}

#[test]
fn no_flapping_under_stationary_load() {
    // The same load already provisioned at the band point: a correct
    // controller holds the pool exactly where it is for the whole run.
    let cfg = AutoscaleConfig {
        cooldown: 5.0,
        max_devices: 12,
        ..AutoscaleConfig::default()
    };
    let scenario = Scenario::new(pool_of(9, 2.5), uniform_streams(4, 5.0, 600))
        .with_admission(cfg.admission())
        .with_seed(43);
    let out = run_autoscale_sim(&scenario, &cfg);
    assert_eq!(
        out.device_actions, 0,
        "stationary fit load must cause no device actions: {:?}",
        out.control_log
    );
    assert_eq!(out.rung_actions, 0);
    // And the provisioned pool actually serves the load at full rate.
    for s in &out.report.streams {
        assert!(
            s.metrics.drop_rate() < 0.05,
            "stream {} drop rate {}",
            s.name,
            s.metrics.drop_rate()
        );
    }
}

#[test]
fn ladder_restores_full_quality_after_load_subsides() {
    let (_, outcomes) = step_load(45);
    let auto = &outcomes[2];
    // During the overload the fleet really was on lower rungs (the
    // control/rung machinery engaged)...
    assert!(
        auto.overload_map < 0.85,
        "overload window should show reduced quality, got {:.3}",
        auto.overload_map
    );
    // ...and within one cooldown of the burst leaving, every surviving
    // stream is back on the full-quality model.
    assert!(
        auto.recovery_seconds <= 5.0 + 1e-9,
        "recovery took {:.1}s after t={STEP_T_OFF}",
        auto.recovery_seconds
    );
    // The ladder-only baseline also restores (via re-level on stream
    // detach), instantly.
    assert!(outcomes[1].recovery_seconds <= 5.0 + 1e-9);
}

#[test]
fn ladder_autoscale_beats_stride_only_at_2x_overload() {
    // The acceptance criterion, end to end: strictly higher delivered
    // mAP than stride-only degradation at 2× overload, p99 within the
    // configured bound, convergence back to full quality within one
    // cooldown window.
    let (_, outcomes) = step_load(47);
    let stride = &outcomes[0];
    let auto = &outcomes[2];
    assert!(
        auto.overload_map > stride.overload_map + 0.15,
        "autoscale {:.3} vs stride-only {:.3}",
        auto.overload_map,
        stride.overload_map
    );
    let cfg = AutoscaleConfig::default();
    assert!(
        auto.overload_p99 <= cfg.p99_bound,
        "p99 {:.2}s breaches the {:.2}s bound",
        auto.overload_p99,
        cfg.p99_bound
    );
    assert!(auto.recovery_seconds <= cfg.cooldown + 1e-9);
    // The win comes from real scaling: the pool grew past its static 4.
    assert!(auto.peak_devices >= 8, "peak devices {}", auto.peak_devices);
}

#[test]
fn ladder_frontier_is_usable_for_both_paper_videos() {
    for video in ["eth_sunnyday", "adl_rundle6"] {
        let ladder = ModelLadder::from_profiles(video);
        assert!(ladder.len() >= 2, "{video}: ladder {:?}", ladder.rungs);
        let speedups = ladder.speedups();
        assert!((speedups[0] - 1.0).abs() < 1e-9);
        for w in speedups.windows(2) {
            assert!(w[1] > w[0], "{video}: speedups {speedups:?}");
        }
    }
}
