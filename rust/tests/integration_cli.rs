//! Integration tests for the `eva` binary's command-line contract:
//! malformed invocations — unknown subcommands, unknown flags, stray
//! positional arguments — must exit non-zero *with a usage pointer*
//! instead of being silently ignored, and well-formed invocations must
//! keep exiting zero.

use std::process::{Command, Output};

fn eva(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_eva"))
        .args(args)
        .output()
        .expect("run eva binary")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn unknown_subcommand_exits_2_with_usage() {
    let out = eva(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("unknown subcommand"), "{err}");
    assert!(err.contains("usage: eva"), "{err}");
    assert!(err.contains("--help"), "{err}");
}

#[test]
fn unknown_flag_exits_2_with_usage() {
    let out = eva(&["fleet", "--bogus-flag"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("unknown option --bogus-flag"), "{err}");
    assert!(err.contains("usage: eva"), "{err}");
}

#[test]
fn stray_positional_exits_2_instead_of_being_ignored() {
    // `eva nselect extra` used to run as if `extra` were never typed.
    let out = eva(&["nselect", "extra"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("unexpected argument \"extra\""), "{err}");
    assert!(err.contains("usage: eva"), "{err}");
}

#[test]
fn flag_missing_its_value_exits_2() {
    let out = eva(&["fleet", "--streams"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--streams needs a value"), "{}", stderr(&out));
}

#[test]
fn help_exits_0_and_lists_subcommands_and_options() {
    let out = eva(&["--help"]);
    assert_eq!(out.status.code(), Some(0));
    let text = stdout(&out);
    assert!(text.contains("subcommands:"), "{text}");
    assert!(text.contains("shard"), "{text}");
    assert!(text.contains("--transport"), "{text}");
}

#[test]
fn wellformed_invocation_still_exits_0() {
    let out = eva(&["nselect", "--lambda", "14", "--mu", "2.5"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("recommended band"), "{}", stdout(&out));
}

#[test]
fn json_mode_emits_exactly_one_parseable_document() {
    // CI uploads these stdouts as BENCH_*.json artifacts: a human banner
    // in front of the JSON would corrupt every downstream consumer.
    let out = eva(&["fleet", "--json", "--streams", "2", "--frames", "30"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    eva::util::json::Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("fleet --json stdout is not pure JSON ({e}): {text}"));
}

#[test]
fn gate_subcommand_honours_the_usage_contract() {
    // Malformed invocations of the gate subcommand follow the same
    // exit-2 usage contract as every other subcommand.
    let out = eva(&["gate", "--bogus-flag"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown option --bogus-flag"), "{}", stderr(&out));

    let out = eva(&["gate", "extra"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unexpected argument \"extra\""), "{}", stderr(&out));

    let out = eva(&["gate", "--scenario"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--scenario needs a value"), "{}", stderr(&out));

    // A parsed-but-unknown preset is a runtime failure: exit 1, not 2 —
    // on the table path and the --json path alike.
    let out = eva(&["gate", "--scenario", "mall"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown gate preset"), "{}", stderr(&out));
    let out = eva(&["gate", "--scenario", "mall", "--json"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown gate preset"), "{}", stderr(&out));
}

#[test]
fn gate_json_mode_emits_exactly_one_parseable_document() {
    // CI uploads this stdout as BENCH_gate.json: it must be pure JSON.
    let out = eva(&["gate", "--json", "--scenario", "lobby"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    let json = eva::util::json::Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("gate --json stdout is not pure JSON ({e}): {text}"));
    assert!(json.get("lobby").is_some(), "{text}");
    assert!(json.get("sports").is_none(), "{text}");
}

#[test]
fn trace_subcommand_honours_the_usage_contract() {
    // Malformed invocations of the trace subcommand follow the same
    // exit-2 usage contract as every other subcommand.
    let out = eva(&["trace", "--bogus-flag"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown option --bogus-flag"), "{}", stderr(&out));

    let out = eva(&["trace", "extra"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unexpected argument \"extra\""), "{}", stderr(&out));

    let out = eva(&["trace", "--metrics-out"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--metrics-out needs a value"), "{}", stderr(&out));
}

#[test]
fn telemetry_flags_are_rejected_where_they_cannot_apply() {
    // `--metrics-out`/`--trace-out` on a subcommand that never produces
    // a registry / span traces is a usage error (exit 2), not a flag
    // that silently does nothing.
    let out = eva(&["nselect", "--metrics-out", "/tmp/eva_m.prom"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--metrics-out does not apply"), "{}", stderr(&out));

    let out = eva(&["autoscale", "--trace-out", "/tmp/eva_t.jsonl"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--trace-out does not apply"), "{}", stderr(&out));

    // Shards aggregate per-shard registries but have no single trace
    // stream: `--trace-out` is a usage error there.
    let out = eva(&["shard", "--trace-out", "/tmp/eva_t.jsonl"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--trace-out does not apply"), "{}", stderr(&out));

    // Understood subcommand, but a sub-scenario with no single run to
    // dump: runtime failure (exit 1), not usage (exit 2).
    let out = eva(&["shard", "--scenario", "split", "--metrics-out", "/tmp/eva_m.prom"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--metrics-out applies only to --scenario run"), "{}", stderr(&out));

    let out = eva(&["gate", "--metrics-out", "/tmp/eva_m.prom"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("single gate preset"), "{}", stderr(&out));
}

#[test]
fn trace_json_mode_emits_exactly_one_parseable_document() {
    // CI uploads this stdout as BENCH_telemetry.json: it must be pure
    // JSON with every section present.
    let out = eva(&["trace", "--json"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    let json = eva::util::json::Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("trace --json stdout is not pure JSON ({e}): {text}"));
    for section in ["stage_budget", "attribution", "overhead", "registry"] {
        assert!(json.get(section).is_some(), "missing {section}: {text}");
    }
}

#[test]
fn trace_writes_metrics_and_span_trace_artifacts() {
    let dir = std::env::temp_dir();
    let metrics_path = dir.join(format!("eva_cli_metrics_{}.prom", std::process::id()));
    let traces_path = dir.join(format!("eva_cli_traces_{}.jsonl", std::process::id()));
    let out = eva(&[
        "trace",
        "--metrics-out",
        metrics_path.to_str().unwrap(),
        "--trace-out",
        traces_path.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));

    let metrics = std::fs::read_to_string(&metrics_path).expect("metrics file written");
    assert!(metrics.contains("eva_frames_total"), "{metrics}");
    let traces = std::fs::read_to_string(&traces_path).expect("trace file written");
    let first = traces.lines().next().expect("at least one span trace");
    let line = eva::util::json::Json::parse(first)
        .unwrap_or_else(|e| panic!("trace line is not JSON ({e}): {first}"));
    assert!(line.get("stream").is_some(), "{first}");
    assert!(line.get("outcome").is_some(), "{first}");

    let _ = std::fs::remove_file(&metrics_path);
    let _ = std::fs::remove_file(&traces_path);
}

#[test]
fn codec_flag_is_rejected_where_it_cannot_apply() {
    // `--codec` steers the sharded control plane only: any other
    // subcommand is a usage error (exit 2), not a silent no-op.
    let out = eva(&["fleet", "--codec", "binary"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--codec does not apply"), "{}", stderr(&out));

    // On `eva shard` but outside `--scenario run`: the sweeps fix their
    // own codecs, so the flag is a usage error there too.
    let out = eva(&["shard", "--scenario", "split", "--codec", "binary"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--codec applies only to --scenario run"), "{}", stderr(&out));

    // An unparseable codec name is malformed command line: exit 2.
    let out = eva(&["shard", "--scenario", "run", "--codec", "protobuf"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown codec"), "{}", stderr(&out));

    // Same contract for `--groups` (two-level planning).
    let out = eva(&["nselect", "--groups", "4"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--groups does not apply"), "{}", stderr(&out));
    let out = eva(&["shard", "--scenario", "skew", "--groups", "4"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--groups applies only to --scenario run"), "{}", stderr(&out));
}

#[test]
fn binary_codec_run_emits_the_same_report_as_json_codec() {
    // The codec changes the wire encoding, never the outcome: the
    // one-off run's JSON report must be byte-identical across codecs
    // (the EventLog parity pin, end to end through the real binary).
    let base = [
        "shard", "--scenario", "run", "--shards", "2", "--streams", "4",
        "--stream-fps", "3", "--frames", "30", "--json",
    ];
    let with = |extra: &[&str]| {
        let mut args = base.to_vec();
        args.extend_from_slice(extra);
        eva(&args)
    };
    let json_run = with(&["--codec", "json"]);
    assert_eq!(json_run.status.code(), Some(0), "stderr: {}", stderr(&json_run));
    let binary_run = with(&["--codec", "binary"]);
    assert_eq!(binary_run.status.code(), Some(0), "stderr: {}", stderr(&binary_run));
    assert_eq!(stdout(&json_run), stdout(&binary_run), "codec must not change the run");
    // And with grouped planning on: still a clean exit + parseable doc.
    let grouped = with(&["--codec", "binary", "--groups", "2"]);
    assert_eq!(grouped.status.code(), Some(0), "stderr: {}", stderr(&grouped));
    let text = stdout(&grouped);
    let json = eva::util::json::Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("shard run --json stdout is not pure JSON ({e}): {text}"));
    assert!(json.get("plan_stats").is_some(), "{text}");
}

#[test]
fn scale_json_mode_emits_exactly_one_parseable_document() {
    // CI uploads this stdout as BENCH_coordinator_scale.json: it must
    // be pure JSON with the sweep rows present.
    let out = eva(&["shard", "--scenario", "scale", "--json"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    let json = eva::util::json::Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("scale --json stdout is not pure JSON ({e}): {text}"));
    let rows = json
        .get("coordinator_scale")
        .and_then(|j| j.as_arr())
        .unwrap_or_else(|| panic!("missing coordinator_scale rows: {text}"));
    assert!(!rows.is_empty(), "{text}");
    assert!(rows.iter().all(|r| r.get("grouped_reads").is_some()), "{text}");
}

#[test]
fn session_flags_are_rejected_where_they_cannot_apply() {
    // `--listen`/`--sessions`/`--probe` are the shard-server surface;
    // anywhere else they are usage errors (exit 2), not silent no-ops.
    let out = eva(&["fleet", "--listen", "127.0.0.1:0"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--listen does not apply"), "{}", stderr(&out));

    let out = eva(&["shard", "--probe"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--probe does not apply"), "{}", stderr(&out));

    let out = eva(&["nselect", "--sessions", "2"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--sessions does not apply"), "{}", stderr(&out));

    // `--token` also rides `eva shard` (the coordinator dial side), so
    // its applicability set is wider — but not universal.
    let out = eva(&["nselect", "--token", "fleet-key"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--token does not apply"), "{}", stderr(&out));
}

#[test]
fn spec_defaults_never_trip_the_applicability_gate() {
    // Regression pin: `--sessions` carries a spec default, and a default
    // filled into the parsed args must not register as "the user passed
    // --sessions" — that once made every non-shard-server subcommand
    // exit 2. Any defaulted flag added later rides the same contract.
    let out = eva(&["nselect", "--lambda", "14", "--mu", "2.5"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    assert!(
        !stderr(&out).contains("does not apply"),
        "default-valued flag tripped the applicability gate: {}",
        stderr(&out)
    );
}

#[test]
fn session_flags_runtime_contract_keeps_exit_1_distinct() {
    // `shard-server` without a bind address is understood-but-failed:
    // exit 1 with the missing flag named, not a usage error.
    let out = eva(&["shard-server"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("--listen required"), "{}", stderr(&out));

    // `--token` on an in-process run has no session to authenticate:
    // runtime failure naming the transports that do.
    let out = eva(&["shard", "--token", "fleet-key"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(
        stderr(&out).contains("--token applies to --scenario run with --transport tcp|uds"),
        "{}",
        stderr(&out)
    );
}

#[test]
fn shard_server_serves_a_probe_handshake_over_a_unix_socket() {
    // The multi-machine smoke path, end to end through the real binary:
    // a backgrounded `shard-server` on a Unix socket, a `--probe` dial
    // with the matching token, and a clean exit on both sides.
    let sock = std::env::temp_dir().join(format!("eva_cli_srv_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let addr = format!("unix:{}", sock.display());
    let mut server = Command::new(env!("CARGO_BIN_EXE_eva"))
        .args(["shard-server", "--listen", addr.as_str(), "--sessions", "1", "--token", "k1"])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn shard-server");
    // Wait for the bind (the probe's own dial backoff covers the rest).
    for _ in 0..100 {
        if sock.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let probe = eva(&["shard-server", "--listen", addr.as_str(), "--probe", "--token", "k1"]);
    assert_eq!(probe.status.code(), Some(0), "stderr: {}", stderr(&probe));
    assert!(stdout(&probe).contains("probe ok"), "{}", stdout(&probe));
    // One session served: the server exits on its own, successfully.
    let status = server.wait().expect("server exits");
    assert!(status.success(), "server exit: {status:?}");
    let _ = std::fs::remove_file(&sock);
}

#[test]
fn churn_json_mode_emits_exactly_one_parseable_document() {
    // CI uploads this stdout as BENCH_churn.json: it must be pure JSON
    // with both chaos cells present.
    let out = eva(&["shard", "--scenario", "churn", "--json"]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    let json = eva::util::json::Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("churn --json stdout is not pure JSON ({e}): {text}"));
    let rows = json
        .get("churn_chaos")
        .and_then(|j| j.as_arr())
        .unwrap_or_else(|| panic!("missing churn_chaos rows: {text}"));
    assert_eq!(rows.len(), 2, "{text}");
    assert!(rows.iter().all(|r| r.get("holds_floor").is_some()), "{text}");
}

#[test]
fn runtime_failure_keeps_exit_1_distinct_from_usage_errors() {
    // A known subcommand with a semantically invalid value: parsed fine,
    // fails at run time — exit 1, not the usage exit 2.
    let out = eva(&["table", "--id", "999"]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert!(stderr(&out).contains("unknown table id"), "{}", stderr(&out));
}
