//! Integration tests for operational hardening under churn: the
//! rolling-restart chaos sweep's pinned delivered-FPS floor and orphan
//! re-placement deadline, reconnect edge cases (a coordinator crash
//! mid-slice, an auth failure mid-backoff), frame conservation when a
//! rejoin races shard-loss detection, and version skew proven on raw
//! bytes — a hand-built PR 4/5/7-era `Hello` frame handshaking against
//! a new shard. Seeds come from `EVA_SOAK_SEED` when set.

use std::io::{Read, Write};

use eva::autoscale::AutoscaleConfig;
use eva::control::wire::autoscale_config_to_json;
use eva::control::{admission_to_json, ControlAction, ControlOrigin, SessionCaps, WireEvent};
use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::experiments::churn::{churn_chaos, churn_scenario, CHURN_GOSSIP};
use eva::fleet::{AdmissionPolicy, StreamSpec};
use eva::shard::{
    run_sharded, run_sharded_remote, serve_shard, serve_shard_sessions, RemoteShard,
    RemoteTransport, ShardScenario,
};
use eva::transport::{
    connect_with_backoff, Endpoint, FrameDecoder, Listener, TransportMsg, TRANSPORT_VERSION,
};

fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
    (0..n)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
        .collect()
}

fn soak_seed(default: u64) -> u64 {
    std::env::var("EVA_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(default)
}

fn hello(roster: Vec<String>, token: Option<&str>) -> TransportMsg {
    TransportMsg::Hello {
        shard: 0,
        protocol: TRANSPORT_VERSION,
        admission: AdmissionPolicy::default(),
        roster,
        caps: SessionCaps {
            token: token.map(str::to_string),
            ..SessionCaps::default()
        },
    }
}

/// Acceptance: rolling restarts of every shard at 2× load — in-process
/// and with each shard behind a loopback TCP socket — hold the pinned
/// delivered-FPS floor, re-place every orphan within one gossip
/// interval, and end with all three shards back in gossip.
#[test]
fn churn_chaos_holds_the_pinned_floor_in_both_runners() {
    let seed = soak_seed(151);
    let (_, outcomes) = churn_chaos(seed);
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        assert!(o.holds_floor(), "seed {seed}: {o:?}");
        assert!(o.orphans > 0, "seed {seed}: the restarts must orphan streams: {o:?}");
        assert!(o.replaced_within_deadline, "seed {seed}: {o:?}");
        assert!(o.worst_gap <= CHURN_GOSSIP + 1e-9, "seed {seed}: {o:?}");
        assert_eq!(o.shards_alive, 3, "seed {seed}: every restart must rejoin: {o:?}");
    }
}

/// Reconnect edge case: a rejoin racing shard-loss detection must never
/// double-place a stream. Frame conservation is the tell — every cam is
/// charged exactly its 600 arrivals in both runners, and no orphan is
/// left unplaced at the end.
#[test]
fn rejoin_racing_loss_detection_never_double_places_a_stream() {
    let seed = soak_seed(193);
    let scenario = churn_scenario(seed);
    let inproc = run_sharded(&scenario);
    let remote = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("tcp churn run");
    for (mode, report) in [("inproc", &inproc), ("tcp", &remote)] {
        for s in &report.streams {
            assert_eq!(s.frames_total, 600, "{mode} seed {seed}: stream {}", s.name);
        }
        assert!(
            report.streams.iter().all(|s| s.orphaned_for != Some(f64::INFINITY)),
            "{mode} seed {seed}: an orphan was never re-placed"
        );
    }
}

/// Reconnect edge case: the coordinator crashes with an epoch slice in
/// flight (Tick sent, Slice never read). The listener must survive the
/// broken session and hand the redial a fresh one that serves end to
/// end.
#[test]
fn redial_during_an_inflight_epoch_slice_gets_a_fresh_session() {
    let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
    let endpoint = listener.local_endpoint().expect("endpoint");
    let shard = RemoteShard::new(0, pool(2, 2.5));
    let server = std::thread::spawn(move || serve_shard_sessions(listener, shard, 2));

    let roster = vec!["cam0".to_string()];
    let spec = StreamSpec::new("cam0", 5.0, 100).with_window(4);
    let attach = TransportMsg::Control(WireEvent::action(
        0.0,
        ControlOrigin::Placement,
        ControlAction::AttachStream(spec),
    ));
    let tick = TransportMsg::Tick {
        epoch: 0,
        at: 0.0,
        seed: 11,
        quotas: vec![(0, 10)],
    };
    let dial = || {
        connect_with_backoff(&endpoint, 20, std::time::Duration::from_millis(10)).expect("dial")
    };

    // Session 1: handshake, put a slice in flight, crash without
    // reading the answer.
    let mut conn = dial();
    conn.send(&hello(roster.clone(), None)).expect("hello 1");
    assert!(matches!(conn.recv().expect("welcome 1"), TransportMsg::Welcome { .. }));
    conn.send(&attach).expect("attach 1");
    conn.send(&tick).expect("tick 1");
    drop(conn);

    // Session 2: the redial starts from a fresh resident set (the
    // attach must be re-sent) and serves the slice to completion.
    let mut conn = dial();
    conn.send(&hello(roster, None)).expect("hello 2");
    assert!(matches!(conn.recv().expect("welcome 2"), TransportMsg::Welcome { .. }));
    conn.send(&attach).expect("attach 2");
    conn.send(&tick).expect("tick 2");
    let slice = loop {
        match conn.recv().expect("recv after tick") {
            TransportMsg::Slice { streams, .. } => break streams,
            TransportMsg::Control(_) => continue,
            other => panic!("unexpected reply {}", other.label()),
        }
    };
    assert_eq!(slice.len(), 1);
    assert_eq!(slice[0].total, 10);
    assert!(slice[0].processed > 0);
    conn.send(&TransportMsg::Bye).expect("bye");
    drop(conn);
    server
        .join()
        .expect("server thread")
        .expect("listener must survive the crashed session");
}

/// Reconnect edge case: an auth failure during the redial-with-backoff
/// loop gets a typed refusal and consumes only its own session — the
/// next dial with the right credential completes the handshake.
#[test]
fn auth_failure_mid_backoff_leaves_the_listener_serving() {
    let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
    let endpoint = listener.local_endpoint().expect("endpoint");
    let shard = RemoteShard::new(0, pool(2, 2.5)).with_token("fleet-key");
    let server = std::thread::spawn(move || serve_shard_sessions(listener, shard, 2));
    let dial = || {
        connect_with_backoff(&endpoint, 20, std::time::Duration::from_millis(10)).expect("dial")
    };

    let mut conn = dial();
    conn.send(&hello(Vec::new(), Some("stale-key"))).expect("bad hello");
    match conn.recv().expect("typed refusal, not a hang") {
        TransportMsg::Reject { code, detail } => {
            assert_eq!(code, "auth");
            assert!(detail.contains("mismatch"), "{detail}");
        }
        other => panic!("expected reject, got {}", other.label()),
    }
    drop(conn);

    let mut conn = dial();
    conn.send(&hello(Vec::new(), Some("fleet-key"))).expect("good hello");
    assert!(matches!(conn.recv().expect("welcome"), TransportMsg::Welcome { .. }));
    conn.send(&TransportMsg::Bye).expect("bye");
    drop(conn);
    server.join().expect("server thread").expect("server ok");
}

/// Warm rejoin vs cold join under sustained overload: the scaler
/// snapshot carried across a restart must shorten the breach transient.
///
/// While an autoscaled shard's pool is short of the offered load, its
/// p99 sits out of bound and the controller attaches one device per
/// cooldown — so the duration of that attach ramp *is* the p99
/// transient, measured here as the time from (re)join to the shard's
/// last breach-driven attach. A cold join at 2.5× load replays the full
/// cooldown-spaced ramp; a warm rejoin restores the scaled pool and
/// cooldown clock ([`ScalerState`] carry), so its transient must be
/// strictly shorter, with strictly fewer repair attaches.
#[test]
fn warm_rejoin_transient_is_strictly_shorter_than_the_cold_join_ramp() {
    let seed = soak_seed(239);
    const GOSSIP: f64 = 10.0;
    const FAIL_EPOCH: usize = 4;
    const REJOIN_EPOCH: usize = 6;
    // 10 × 2.5-FPS cams vs two 2-device seed pools (Σμ 10): every shard
    // must roughly triple its pool, so the cold ramp spans several
    // cooldowns and a carried snapshot has real state to save.
    let streams: Vec<StreamSpec> = (0..10)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 300).with_window(4))
        .collect();
    let scenario = ShardScenario::builder(vec![pool(2, 2.5), pool(2, 2.5)], streams)
        .gossip(GOSSIP)
        .epochs(12)
        .seed(seed)
        .autoscale(AutoscaleConfig {
            device_rate: 2.5,
            max_devices: 8,
            cooldown: 5.0,
            ..AutoscaleConfig::default()
        })
        .restart(0, FAIL_EPOCH, REJOIN_EPOCH)
        .build();
    let report = run_sharded(&scenario);
    let t_fail = FAIL_EPOCH as f64 * GOSSIP;
    let t_rejoin = REJOIN_EPOCH as f64 * GOSSIP;
    // Shard 0's controller attach times, absolute shard-clock seconds.
    let attaches: Vec<f64> = report
        .control_log
        .iter()
        .filter(|c| c.shard == 0 && c.event.origin == ControlOrigin::Controller)
        .filter(|c| matches!(c.event.as_action(), Some(ControlAction::AttachDevice(_))))
        .map(|c| c.event.at)
        .collect();
    let cold: Vec<f64> = attaches.iter().copied().filter(|&t| t < t_fail).collect();
    let warm: Vec<f64> = attaches.iter().copied().filter(|&t| t >= t_rejoin).collect();
    // The cold join must pay a real cooldown-spaced ramp...
    assert!(
        cold.len() >= 2,
        "seed {seed}: cold join must ramp over several attaches: {attaches:?}"
    );
    let cold_transient = cold.iter().cloned().fold(0.0, f64::max);
    assert!(cold_transient > 0.0, "seed {seed}: {cold:?}");
    // ...and the warm rejoin must not replay it: strictly fewer repair
    // attaches, strictly shorter breach window.
    let warm_transient = warm.iter().cloned().fold(0.0, f64::max).max(t_rejoin) - t_rejoin;
    assert!(
        warm.len() < cold.len(),
        "seed {seed}: warm rejoin replayed the ramp: cold {cold:?} vs warm {warm:?}"
    );
    assert!(
        warm_transient < cold_transient,
        "seed {seed}: post-rejoin transient {warm_transient:.1}s must be strictly shorter than the cold-join ramp {cold_transient:.1}s"
    );
    // The restart actually happened and the shard came back.
    assert!(report.shard_alive[0], "seed {seed}: shard 0 must rejoin");
    assert!(report.orphan_count() > 0, "seed {seed}: the failure must orphan streams");
}

/// The 8-byte frame header + JSON payload a pre-caps encoder wrote,
/// byte for byte: magic "EV", JSON codec version 1, reserved 0,
/// big-endian u32 payload length.
fn era_frame(payload: &str) -> Vec<u8> {
    let mut f = vec![0x45, 0x56, 1, 0];
    f.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    f.extend_from_slice(payload.as_bytes());
    f
}

fn read_raw_msg(sock: &mut std::net::TcpStream, dec: &mut FrameDecoder) -> TransportMsg {
    let mut buf = [0u8; 4096];
    loop {
        if let Some(msg) = dec.try_next().expect("answer frame decodes") {
            return msg;
        }
        let n = sock.read(&mut buf).expect("read answer");
        assert!(n > 0, "shard closed before answering");
        dec.feed(&buf[..n]);
    }
}

/// Version-skew matrix, old → new, proven on raw bytes: hellos written
/// in each pre-caps dialect — PR 4 (no optional keys), PR 5 (flat
/// `autoscale`), PR 7 (flat `telemetry`) — are hand-framed and written
/// straight to the socket; a new shard must answer every one with a
/// `Welcome`.
#[test]
fn legacy_era_hello_bytes_handshake_against_a_new_shard() {
    let adm = admission_to_json(&AdmissionPolicy::default()).to_string();
    let auto = autoscale_config_to_json(&AutoscaleConfig::default()).to_string();
    let dialects = [
        ("pr4", String::new()),
        ("pr5", format!(r#""autoscale":{auto},"#)),
        ("pr7", r#""telemetry":true,"#.to_string()),
    ];
    for (era, extra) in &dialects {
        let payload = format!(
            r#"{{"admission":{adm},{extra}"msg":"hello","protocol":1,"roster":["cam0"],"shard":5}}"#
        );
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let shard = RemoteShard::new(5, pool(2, 2.5));
        let server = std::thread::spawn(move || serve_shard(listener, shard));
        let Endpoint::Tcp(addr) = &endpoint else {
            panic!("loopback endpoint must be tcp")
        };
        let mut sock = std::net::TcpStream::connect(addr.as_str()).expect("raw dial");
        sock.write_all(&era_frame(&payload)).expect("send era hello");
        let mut dec = FrameDecoder::new();
        match read_raw_msg(&mut sock, &mut dec) {
            TransportMsg::Welcome { shard, capacity } => {
                assert_eq!(shard, 5, "{era}");
                assert!(capacity > 0.0, "{era}");
            }
            other => panic!("{era}: expected welcome, got {}", other.label()),
        }
        sock.write_all(&era_frame(r#"{"msg":"bye"}"#)).expect("send era bye");
        drop(sock);
        server.join().expect("server thread").expect("server ok");
    }
}
