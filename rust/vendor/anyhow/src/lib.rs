//! Offline vendored subset of the `anyhow` error-handling crate.
//!
//! The build environment has no network access and no crates.io mirror,
//! so this path dependency provides exactly the API surface EVA-RS uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait. Semantics match upstream `anyhow` for
//! that subset (context wraps the message; `?` converts any
//! `std::error::Error`); swap in the real crate by pointing the
//! workspace dependency back at crates.io.

use std::fmt;

/// A string-backed error value, convertible from any `std::error::Error`.
///
/// Like upstream `anyhow::Error`, this type deliberately does NOT
/// implement `std::error::Error` itself — that is what keeps the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable (the `anyhow!` macro's
    /// single-expression form).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, matching upstream's `{context}: {cause}` chain
    /// rendering in `Display`.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` with a defaulted error type, as upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a single displayable
/// expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error {
            msg: format!("{ctx}: {e}"),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("value {n} and {}", 4);
        assert_eq!(b.to_string(), "value 3 and 4");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while rendering").unwrap_err();
        assert!(e.to_string().starts_with("while rendering: "));
        let r2: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e2 = r2.with_context(|| format!("pass {}", 2)).unwrap_err();
        assert!(e2.to_string().starts_with("pass 2: "));
    }
}
