//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate links `libxla_extension` and is unavailable in
//! this offline build environment, so this stub provides the exact type
//! and method surface `eva::runtime` compiles against. Every entry point
//! that would touch PJRT returns an "unavailable" error at runtime;
//! client construction fails first, so the downstream methods are never
//! reached in practice. All PJRT-dependent tests and examples already
//! gate on `artifacts/manifest.json` existing and skip cleanly.
//!
//! To run real TinyDet inference, repoint the workspace `xla` dependency
//! at the actual bindings; no `eva` source changes are needed.

use std::path::Path;

/// Stub error: carries the message shown when PJRT paths are exercised.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: XLA PJRT runtime unavailable (offline stub crate \
         rust/vendor/xla-stub; link the real `xla` bindings to enable \
         PJRT inference)"
    )))
}

/// PJRT client handle (construction always fails in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; returns per-device output buffers.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[1, 2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }
}
