//! Cross-host sharded serving: each fleet instance behind a socket.
//!
//! [`crate::shard::sim`] proved the control plane serialises — every
//! placement decision crosses an encode→decode hop — but shards were
//! still function calls in one address space. This module puts a real
//! transport in the seam: each shard runs a blocking
//! [`serve_shard`] loop behind a TCP or Unix-domain socket
//! ([`crate::transport::net`]), and the coordinator
//! ([`run_sharded_remote`]) drives the same gossip-epoch co-simulation
//! by shipping length-prefixed frames instead of calling functions.
//!
//! Per epoch, per shard, the coordinator:
//!
//! 1. sends [`TransportMsg::Poll`] and waits for the shard's
//!    [`TransportMsg::Digest`] — the capacity gossip, computed
//!    **shard-side** from its resident set;
//! 2. routes placement / migration / re-placement as
//!    [`TransportMsg::Control`] frames (the same
//!    [`crate::control::WireEvent`]s the in-process runner encodes);
//! 3. sends [`TransportMsg::Tick`] with the epoch's arrival quotas and
//!    seed, and folds the returned [`TransportMsg::Slice`] into the
//!    [`ShardReport`].
//!
//! **Peer loss is shard loss.** Any send/recv failure — connection
//! reset, mid-frame close, framing lost, read deadline — kills the
//! shard in the coordinator's view: its digest stops arriving, its
//! residents are orphaned, and the next placement pass re-places them
//! exactly as the gossip planner re-places orphans of a missed
//! heartbeat. A socket dying is therefore *faster* to detect than a
//! silent shard (the error is synchronous), and never slower than the
//! one-gossip-interval bound the in-process co-sim guarantees.
//!
//! **Sessions, auth, rejoin.** A listening shard serves a configurable
//! number of coordinator sessions back to back
//! ([`serve_shard_sessions`]); every session starts from a fresh
//! resident set and device pool, so a coordinator redialling after a
//! crash talks to a fresh shard, never a haunted one. The handshake
//! carries a versioned [`SessionCaps`]: a shard started with a session
//! token answers a mismatched or missing one with a typed
//! [`TransportMsg::Reject`] frame — never a hang — and protocol skew is
//! refused the same way. Scenario `rejoins` redial a dead shard at a
//! scheduled epoch ahead of that epoch's gossip round: it re-enters the
//! table as a fresh shard (full capacity, zero committed) and the
//! planner re-levels onto it. With `handover` enabled, migrated and
//! re-placed streams charge a warm-up toll — their first
//! window's worth of frames carries the detach→attach (or orphan-gap)
//! delay — so churn sweeps price what a real handover costs instead of
//! teleporting state for free.
//!
//! The epoch arithmetic (arrival credit, quota clipping, sub-scenario
//! seeds) mirrors [`crate::shard::sim::run_sharded`] term for term, so
//! a loopback run is comparable to the in-process co-simulation — the
//! `experiments::transport` parity sweep holds them within 5%. The
//! mirror is pinned by tests, not convention: on a failure-free run the
//! two runners must agree on frame counts *exactly*
//! (`remote_matches_inproc_cosim_exactly_on_a_balanced_run`), so a
//! change to the in-process arithmetic that is not re-mirrored here
//! fails tier-1. Folding both epoch loops over one shared driver (a
//! per-shard digest/route/tick trait) is the natural follow-on once a
//! second transport family needs it; see ROADMAP §multi-machine.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::autoscale::policy::AutoscaleConfig;
use crate::control::{ControlAction, ControlOrigin, SessionCaps, WireEvent, WirePayload};
use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::sim::{run_fleet_with, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::forecast::{should_hold, ForecastConfig, ShardForecast};
use crate::gate::GateConfig;
use crate::shard::autoscale::{ScalerState, ShardAutoscaler};
use crate::shard::gossip::GossipTable;
use crate::shard::placement::ShardView;
use crate::shard::plan::{plan, PlanStats};
use crate::shard::sim::{
    record_coordinator_telemetry, record_slice_telemetry, EpochPhases, ShardControl, ShardReport,
    ShardScenario, ShardStreamReport,
};
use crate::telemetry::Registry;
use crate::transport::frame::Codec;
use crate::transport::msg::{SliceStream, TransportMsg, TRANSPORT_VERSION};
use crate::transport::net::{connect_with_backoff, Endpoint, FrameConn, Listener, TransportError};
use crate::util::stats::Percentiles;

/// Which socket family the remote co-simulation runs over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemoteTransport {
    /// Loopback TCP (`127.0.0.1`, ephemeral ports).
    Tcp,
    /// Unix-domain sockets under the system temp dir.
    Uds,
}

impl RemoteTransport {
    pub fn label(&self) -> &'static str {
        match self {
            RemoteTransport::Tcp => "tcp",
            RemoteTransport::Uds => "uds",
        }
    }

    /// A fresh endpoint of this family for shard `id`.
    pub fn endpoint(&self, id: usize) -> Endpoint {
        match self {
            RemoteTransport::Tcp => Endpoint::loopback(),
            RemoteTransport::Uds => Endpoint::temp_uds(&format!("shard{id}")),
        }
    }
}

/// One shard instance as a socket server: its device pool and an
/// optional scripted death.
#[derive(Debug, Clone)]
pub struct RemoteShard {
    pub id: usize,
    pub devices: Vec<DeviceInstance>,
    /// Drop the coordinator connection — without a goodbye — when a
    /// `Poll` for an epoch `>= fail_at_epoch` arrives. Stands in for a
    /// process crash in tests and experiments.
    pub fail_at_epoch: Option<usize>,
    /// Standing local-capacity-control config. The coordinator's
    /// `Hello` may carry its own [`AutoscaleConfig`], which overrides
    /// this one for the session — the closed loop always runs with the
    /// parameters the session was opened with.
    pub autoscale: Option<AutoscaleConfig>,
    /// Standing per-frame motion gate; like `autoscale`, a gate config
    /// carried in the coordinator's `Hello` overrides it for the
    /// session.
    pub gate: Option<GateConfig>,
    /// Standing arrival-forecast config ([`crate::forecast`]); same
    /// session-override rule — a forecast config in the coordinator's
    /// `Hello` wins. When armed, every digest this shard sends carries
    /// its predicted Σλ and its serve loop fuses the prediction into
    /// the autoscaler hint and the admission burst-hold.
    pub forecast: Option<ForecastConfig>,
    /// Shared-secret session auth. When set, a `Hello` whose
    /// [`SessionCaps`] does not carry the identical token is answered
    /// with a typed `Reject("auth")` frame and the session ends — the
    /// dialler gets a decodable refusal, never a hang.
    pub token: Option<String>,
}

impl RemoteShard {
    pub fn new(id: usize, devices: Vec<DeviceInstance>) -> RemoteShard {
        RemoteShard {
            id,
            devices,
            fail_at_epoch: None,
            autoscale: None,
            gate: None,
            forecast: None,
            token: None,
        }
    }

    pub fn with_failure(mut self, epoch: usize) -> RemoteShard {
        self.fail_at_epoch = Some(epoch);
        self
    }

    pub fn with_autoscale(mut self, cfg: AutoscaleConfig) -> RemoteShard {
        self.autoscale = Some(cfg);
        self
    }

    pub fn with_gate(mut self, gate: GateConfig) -> RemoteShard {
        self.gate = Some(gate);
        self
    }

    pub fn with_forecast(mut self, cfg: ForecastConfig) -> RemoteShard {
        self.forecast = Some(cfg);
        self
    }

    pub fn with_token(mut self, token: &str) -> RemoteShard {
        self.token = Some(token.to_string());
        self
    }
}

/// Serve one shard behind `listener`: accept a single coordinator
/// session and run its control loop to completion (Bye / peer loss /
/// scripted death). The shard owns its device pool; admission policy
/// and the stream-id roster arrive in the `Hello`, stream membership
/// arrives as decoded control frames, and every epoch slice runs
/// through the same virtual-time fleet engine the in-process runner
/// uses.
pub fn serve_shard(listener: Listener, shard: RemoteShard) -> Result<(), TransportError> {
    serve_shard_sessions(listener, shard, 1)
}

/// Serve `sessions` coordinator sessions back to back on one listener.
///
/// Each accepted connection gets a *fresh* session — empty resident
/// set, the shard's original device pool, standing autoscale/gate
/// configs — so a coordinator that redials after a crash rejoins a
/// shard with no stale state. A scripted death
/// ([`RemoteShard::fail_at_epoch`]) fires at most once across the
/// whole run: the session it kills consumes it, and the rejoin session
/// serves to completion. A session that dies mid-flight — coordinator
/// crash, broken pipe, framing lost, read deadline — ends *that*
/// session only: the listener survives to serve the redial, which is
/// the whole point of running more than one session.
pub fn serve_shard_sessions(
    listener: Listener,
    shard: RemoteShard,
    sessions: usize,
) -> Result<(), TransportError> {
    let mut fail_at = shard.fail_at_epoch;
    // Autoscaler state snapshotted at a scripted death, restored into
    // the next session's scaler at its handshake: a rejoin dial resumes
    // the pool, cooldown clock and replica numbering the shard had
    // already learned (warm rejoin) instead of replaying the attach
    // ramp — mirroring the in-process runner's saved-scaler snapshot.
    let mut carry: Option<ScalerState> = None;
    for _ in 0..sessions {
        let conn = listener.accept()?;
        let _ = serve_session(&shard, conn, &mut fail_at, &mut carry);
    }
    Ok(())
}

/// One coordinator session against a fresh copy of the shard's state.
fn serve_session(
    shard: &RemoteShard,
    mut conn: FrameConn,
    fail_at: &mut Option<usize>,
    carry: &mut Option<ScalerState>,
) -> Result<(), TransportError> {
    let mut admission = AdmissionPolicy::default();
    let mut roster: Vec<String> = Vec::new();
    // Residents keyed by global stream id (assigned by the roster).
    let mut residents: BTreeMap<usize, StreamSpec> = BTreeMap::new();
    // The live pool: local capacity control grows/shrinks it in place.
    let mut pool: Vec<DeviceInstance> = shard.devices.clone();
    let mut gate: Option<GateConfig> = shard.gate.clone();
    let mut scaler: Option<ShardAutoscaler> = shard.autoscale.clone().map(|cfg| {
        let mut s = ShardAutoscaler::new(cfg);
        s.set_gate(gate.clone());
        s
    });
    // Shard-local arrival forecasting, armed by the handshake (or the
    // shard's standing config). Served slices buffer their realised
    // arrivals raw; the buffer flushes at the next poll — the first
    // moment the server can recover the epoch interval (`at / epoch`)
    // — so the forecast visible at digest and hint time matches the
    // in-process runner's exactly.
    let mut forecaster: Option<ShardForecast> = shard.forecast.clone().map(ShardForecast::new);
    let mut pending_obs: Vec<(usize, f64)> = Vec::new();
    // Cumulative metric snapshot, armed by the coordinator's Hello: a
    // fresh copy ships home ahead of every Slice (cumulative counters,
    // not deltas, so the latest snapshot supersedes the rest).
    let mut telemetry: Option<Registry> = None;
    // Flipped by a token-checked Hello. A token-requiring shard answers
    // any pre-handshake traffic with the same typed refusal a bad token
    // gets — capability probes don't leak behaviour past auth.
    let mut authed = false;

    loop {
        let msg = match conn.recv() {
            Ok(m) => m,
            // Coordinator gone: the session is over either way.
            Err(TransportError::PeerClosed { .. }) => return Ok(()),
            Err(e) => return Err(e),
        };
        // Codec mirroring: answer in whatever codec the coordinator
        // last spoke, so a coordinator switching to the compact binary
        // frames after the handshake gets binary digests and slices
        // back without any negotiation message.
        conn.set_codec(conn.last_recv_codec());
        let handshake_msg = matches!(msg, TransportMsg::Hello { .. } | TransportMsg::Bye);
        if shard.token.is_some() && !authed && !handshake_msg {
            let _ = conn.send(&TransportMsg::Reject {
                code: "auth".to_string(),
                detail: "handshake required before traffic".to_string(),
            });
            return Ok(());
        }
        match msg {
            TransportMsg::Hello {
                protocol,
                admission: adm,
                roster: r,
                caps,
                ..
            } => {
                // Both refusal paths send a typed frame and end the
                // session cleanly: the dialler always gets a decodable
                // answer, and the listener survives to serve the next
                // session (a redial with the right credentials).
                if protocol != TRANSPORT_VERSION {
                    let _ = conn.send(&TransportMsg::Reject {
                        code: "protocol".to_string(),
                        detail: format!(
                            "peer speaks protocol {protocol}, shard speaks {TRANSPORT_VERSION}"
                        ),
                    });
                    return Ok(());
                }
                if let Some(required) = &shard.token {
                    if caps.token.as_deref() != Some(required.as_str()) {
                        let detail = match &caps.token {
                            None => "session token required".to_string(),
                            Some(_) => "session token mismatch".to_string(),
                        };
                        let _ = conn.send(&TransportMsg::Reject {
                            code: "auth".to_string(),
                            detail,
                        });
                        return Ok(());
                    }
                }
                authed = true;
                admission = adm;
                roster = r;
                // A session-scoped autoscale config overrides the
                // shard's standing one: the coordinator decides whether
                // (and how) this shard scales itself.
                if let Some(cfg) = caps.autoscale {
                    scaler = Some(ShardAutoscaler::new(cfg));
                }
                // Same session-override rule for the gate; whichever
                // config wins, the (possibly fresh) scaler runs with it.
                if let Some(cfg) = caps.gate {
                    gate = Some(cfg);
                }
                if let Some(s) = scaler.as_mut() {
                    s.set_gate(gate.clone());
                }
                if let Some(cfg) = caps.forecast {
                    forecaster = Some(ShardForecast::new(cfg));
                }
                // Warm rejoin: a scaler snapshot carried from a
                // scripted death on this listener resumes the pool,
                // cooldown clock and replica numbering (the same
                // tuple-take the in-process restore uses — the snapshot
                // is consumed even when this session runs no scaler).
                if let (Some(s), Some(state)) = (scaler.as_mut(), carry.take()) {
                    pool = s.restore_state(&state);
                }
                telemetry = caps.telemetry.then(Registry::new);
                // Welcome advertises the seed pool — the pre-scale
                // baseline the in-process report pins as
                // `shard_capacity` — never the live pool a warm restore
                // may have grown.
                let capacity = shard.devices.iter().map(|d| d.rate()).sum::<f64>()
                    * admission.target_utilization;
                conn.send(&TransportMsg::Welcome {
                    shard: shard.id,
                    capacity,
                })?;
            }
            TransportMsg::Control(event) => match event.as_action() {
                Some(ControlAction::AttachStream(spec)) => {
                    if let Some(id) = roster.iter().position(|n| n == &spec.name) {
                        residents.insert(id, spec.clone());
                    }
                }
                Some(ControlAction::DetachStream(id)) => {
                    residents.remove(id);
                }
                _ => {}
            },
            TransportMsg::Poll { epoch, at } => {
                if fail_at.is_some_and(|e| epoch >= e) {
                    // Scripted death: vanish mid-session, no goodbye.
                    // Taking the trigger consumes it, so a rejoin
                    // session on the same listener serves normally.
                    *fail_at = None;
                    // Snapshot the autoscaler for a warm rejoin: the
                    // state it had after the last slice it served.
                    *carry = scaler.as_ref().map(|s| s.export_state(&pool));
                    return Ok(());
                }
                // Settle forecast state for the round at exactly the
                // in-process sweep/observe visibility: drop state for
                // streams no longer resident — unless this flush is
                // about to re-observe them, so a stream that played out
                // last epoch still backs this digest, exactly once —
                // then flush the buffered arrivals over the recovered
                // epoch interval.
                if let Some(fc) = forecaster.as_mut() {
                    fc.retain_streams(|id| {
                        residents.contains_key(&id)
                            || pending_obs.iter().any(|&(o, _)| o == id)
                    });
                    if epoch >= 1 {
                        let interval = at / epoch as f64;
                        for (id, frames) in pending_obs.drain(..) {
                            fc.observe(id, frames / interval);
                        }
                    }
                }
                pending_obs.clear();
                // Post-scale headroom: an autoscaling shard advertises
                // what it can reach locally, so the coordinator's
                // planner migrates only when local scaling is exhausted.
                let util = admission.target_utilization;
                let capacity = match &scaler {
                    Some(s) => s.projected_capacity(&pool, util),
                    None => pool.iter().map(|d| d.rate()).sum::<f64>() * util,
                };
                // Offered load at the epoch base: `demand_at` follows a
                // stream's rate profile (equal to the flat demand for
                // unprofiled streams).
                let committed: f64 = residents.values().map(|s| s.demand_at(at)).sum();
                let forecast = forecaster.as_ref().and_then(|f| f.digest_rate());
                conn.send(&TransportMsg::Digest {
                    shard: shard.id,
                    at,
                    capacity,
                    committed,
                    forecast,
                })?;
            }
            TransportMsg::Tick {
                epoch,
                at,
                seed,
                quotas,
            } => {
                // Build the epoch slice: resident specs clipped to their
                // arrival quotas, in the quota (= global id) order the
                // coordinator sent.
                let mut specs: Vec<StreamSpec> = Vec::new();
                let mut ids: Vec<usize> = Vec::new();
                for &(id, frames) in &quotas {
                    let Some(spec) = residents.get(&id) else {
                        continue;
                    };
                    if frames == 0 {
                        continue;
                    }
                    let mut s = spec.clone();
                    s.num_frames = frames;
                    // The slice serves this epoch's quota at the
                    // profiled instantaneous rate, so a ramp phase
                    // arrives as a genuinely faster process (unchanged
                    // for flat streams).
                    s.fps = spec.rate_at(at);
                    specs.push(s);
                    ids.push(id);
                }
                let (busy, frames, streams) = if specs.is_empty() {
                    (0.0, 0, Vec::new())
                } else {
                    // Forecast fusion at the serve boundary — the same
                    // couplings, at the same visibility, as the
                    // in-process runner: prune to the settled resident
                    // set, arm the admission burst-hold when a tight
                    // prediction says the overload clears, and hand the
                    // autoscaler the predicted Σλ as its demand hint.
                    let mut admission = admission.clone();
                    if let Some(fc) = forecaster.as_mut() {
                        fc.retain_streams(|id| residents.contains_key(&id));
                        let offered: f64 = ids
                            .iter()
                            .filter_map(|id| residents.get(id))
                            .map(|s| s.demand_at(at))
                            .sum();
                        let cap_now = pool.iter().map(|d| d.rate()).sum::<f64>()
                            * admission.target_utilization;
                        admission.hold =
                            should_hold(fc.cfg(), offered, cap_now, fc.predict().as_ref());
                        if let Some(s) = scaler.as_mut() {
                            s.set_forecast_demand(fc.digest_rate());
                        }
                    }
                    let (report, scale_events) = match scaler.as_mut() {
                        Some(s) => {
                            // Closed-loop slice: the local controller
                            // scales the pool in place; its actions ride
                            // home as Control frames ahead of the Slice.
                            s.run_slice(&mut pool, &admission, specs, &ids, at, seed)
                        }
                        None => {
                            let mut sub = Scenario::new(pool.clone(), specs)
                                .with_admission(admission.clone())
                                .with_seed(seed);
                            if let Some(cfg) = &gate {
                                sub = sub.with_gate(cfg.clone());
                            }
                            let out = run_fleet_with(&sub, None);
                            // Gate verdicts ride home as Control frames
                            // ahead of the Slice, in shard time with
                            // global stream ids — mirroring what the
                            // in-process runner pushes into its log.
                            let mut events = Vec::new();
                            for ev in &out.gate_log {
                                if let WirePayload::Gate { stream, frame, verdict } = ev.payload {
                                    let Some(&global) = ids.get(stream) else { continue };
                                    events.push(WireEvent::gate(at + ev.at, global, frame, verdict));
                                }
                            }
                            (out.report, events)
                        }
                    };
                    for event in scale_events {
                        conn.send(&TransportMsg::Control(event))?;
                    }
                    // Buffer the slice's realised arrivals for the
                    // forecaster — learned from what was served, never
                    // peeked from the declared profile; flushed over
                    // the epoch interval at the next poll.
                    if forecaster.is_some() {
                        for (&id, sr) in ids.iter().zip(&report.streams) {
                            pending_obs.push((id, sr.metrics.frames_total as f64));
                        }
                    }
                    let streams: Vec<SliceStream> = ids
                        .iter()
                        .zip(&report.streams)
                        .map(|(&id, sr)| SliceStream {
                            id,
                            total: sr.metrics.frames_total,
                            processed: sr.metrics.frames_processed,
                            latencies: sr
                                .records
                                .iter()
                                .map(|rec| (rec.emit_ts - rec.capture_ts).max(0.0))
                                .collect(),
                        })
                        .collect();
                    (
                        report.device_busy.iter().sum::<f64>(),
                        report.device_frames.iter().sum::<u64>(),
                        streams,
                    )
                };
                if let Some(reg) = telemetry.as_mut() {
                    // Mirror the in-process lowering exactly: an empty
                    // slice records nothing there (the coordinator never
                    // ticks one), so it must record nothing here either.
                    if !streams.is_empty() {
                        record_slice_telemetry(
                            reg,
                            shard.id,
                            busy,
                            frames,
                            streams
                                .iter()
                                .map(|s| (s.total, s.processed, s.latencies.as_slice())),
                        );
                    }
                    conn.send(&TransportMsg::Telemetry {
                        shard: shard.id,
                        epoch,
                        snapshot: reg.clone(),
                    })?;
                }
                conn.send(&TransportMsg::Slice {
                    epoch,
                    busy,
                    frames,
                    streams,
                })?;
            }
            TransportMsg::Bye => return Ok(()),
            // Peer-role messages (Welcome/Digest/Slice) are protocol
            // violations from a coordinator; ignore rather than die so a
            // confused peer cannot wedge the shard into an error loop.
            _ => {}
        }
    }
}

/// Coordinator-side bookkeeping for one stream (mirrors the private
/// `StreamRun` of [`crate::shard::sim`]).
struct RemoteStream {
    spec: StreamSpec,
    next_frame: u64,
    frames_total: u64,
    frames_processed: u64,
    latency: Percentiles,
    shard: Option<usize>,
    /// Last shard the stream was resident on (reporting only).
    last_shard: Option<usize>,
    migrations: usize,
    arrival_credit: f64,
    orphaned_at: Option<f64>,
    worst_gap: f64,
    ever_orphaned: bool,
    /// Frames still carrying the handover toll: a migrated or
    /// re-placed stream's first window of frames lands late by
    /// `handover_lag` (scenario `handover` mode only).
    carried_backlog: u64,
    handover_lag: f64,
}

impl RemoteStream {
    fn remaining(&self) -> u64 {
        self.spec.num_frames.saturating_sub(self.next_frame)
    }

    fn active(&self) -> bool {
        self.remaining() > 0
    }
}

/// Dial `endpoint` and run the capability handshake for shard `sh`,
/// returning the live connection (already switched to the scenario
/// codec) and the capacity the shard advertised.
///
/// One path for the initial connect *and* a scheduled rejoin: the
/// coordinator's asks (autoscale / gate / telemetry / auth token) ride
/// the versioned [`SessionCaps`], and a typed `Reject` answer becomes a
/// typed error here — an auth or protocol refusal can fail a dial, but
/// can never hang one.
fn open_session(
    endpoint: &Endpoint,
    sh: usize,
    scenario: &ShardScenario,
    roster: &[String],
) -> Result<(FrameConn, f64)> {
    let conn = connect_with_backoff(endpoint, 10, std::time::Duration::from_millis(5))
        .map_err(|e| anyhow!("shard {sh}: dial {} failed: {e}", endpoint.label()))?;
    handshake_session(conn, sh, scenario, roster)
}

/// The post-connect half of [`open_session`]: Hello with the session
/// capabilities, await Welcome/Reject. Split out so a rejoin dial can
/// account the accepted connection (which consumed one of the
/// listener's session slots) separately from handshake success.
fn handshake_session(
    mut conn: FrameConn,
    sh: usize,
    scenario: &ShardScenario,
    roster: &[String],
) -> Result<(FrameConn, f64)> {
    let caps = SessionCaps {
        autoscale: scenario.autoscale.clone(),
        gate: scenario.gate.clone(),
        telemetry: scenario.telemetry,
        token: scenario.token.clone(),
        forecast: scenario.forecast.clone(),
        ..SessionCaps::default()
    };
    conn.send(&TransportMsg::Hello {
        shard: sh,
        protocol: TRANSPORT_VERSION,
        admission: scenario.admission.clone(),
        roster: roster.to_vec(),
        caps,
    })
    .map_err(|e| anyhow!("shard {sh}: hello failed: {e}"))?;
    match conn.recv() {
        Ok(TransportMsg::Welcome { capacity, .. }) => {
            // The handshake always rides JSON frames; everything after
            // it uses the scenario codec, which the shard mirrors per
            // frame.
            conn.set_codec(scenario.codec);
            Ok((conn, capacity))
        }
        Ok(TransportMsg::Reject { code, detail }) => {
            Err(anyhow!("shard {sh}: session rejected ({code}): {detail}"))
        }
        Ok(other) => Err(anyhow!("shard {sh}: expected welcome, got {}", other.label())),
        Err(e) => Err(anyhow!("shard {sh}: handshake failed: {e}")),
    }
}

/// Run a [`ShardScenario`] with every shard behind a real socket.
///
/// Shard servers are spawned on local threads (the transport neither
/// knows nor cares; a different host would change only the endpoint),
/// the coordinator dials them with backoff, and the whole co-simulation
/// — handshake, gossip, placement, migration, epoch slices — crosses
/// the wire as frames. Scenario `failures` become scripted connection
/// drops ([`RemoteShard::fail_at_epoch`]); killing a connection orphans
/// the shard's streams and the next placement pass re-places them, so
/// the report's orphan-gap accounting is comparable to the in-process
/// runner's. Scenario `rejoins` redial the dead shard's listener at
/// the scheduled epoch (a fresh session against the original pool),
/// scenario `token` arms shared-secret auth on every shard and
/// presents the matching credential on every dial, and `handover`
/// prices migrations and re-placements realistically instead of
/// teleporting window state.
pub fn run_sharded_remote(
    scenario: &ShardScenario,
    transport: RemoteTransport,
) -> Result<ShardReport> {
    let m = scenario.shards.len();
    if m == 0 {
        return Err(anyhow!("need at least one shard"));
    }
    let tick = scenario.gossip_interval.max(1e-3);

    // Bind every listener first (endpoints must be known before the
    // coordinator dials), then spawn the shard servers. A shard with
    // scheduled rejoins serves one extra session per rejoin: each
    // redial gets a fresh accept.
    let mut endpoints = Vec::with_capacity(m);
    let mut handles = Vec::with_capacity(m);
    let mut sessions_expected = vec![0usize; m];
    // Session slots this coordinator consumed per shard: every accepted
    // connection counts, handshake-rejected rejoin dials included, so
    // teardown drains exactly the slots the listener still holds open.
    let mut sessions_used = vec![0usize; m];
    for (sh, pool) in scenario.shards.iter().enumerate() {
        let listener = Listener::bind(&transport.endpoint(sh))
            .map_err(|e| anyhow!("shard {sh}: bind failed: {e}"))?;
        endpoints.push(listener.local_endpoint()?);
        let mut shard = RemoteShard::new(sh, pool.clone());
        if let Some(token) = &scenario.token {
            shard = shard.with_token(token);
        }
        // Earliest scheduled death wins, matching the in-process runner
        // (which applies whichever failure entry's epoch comes first).
        if let Some(epoch) = scenario
            .failures
            .iter()
            .filter(|&&(_, s)| s == sh)
            .map(|&(e, _)| e)
            .min()
        {
            shard = shard.with_failure(epoch);
        }
        let sessions = 1 + scenario.rejoins.iter().filter(|&&(_, s)| s == sh).count();
        sessions_expected[sh] = sessions;
        handles.push(std::thread::spawn(move || {
            serve_shard_sessions(listener, shard, sessions)
        }));
    }

    let roster: Vec<String> = scenario.streams.iter().map(|s| s.name.clone()).collect();
    let mut conns: Vec<Option<FrameConn>> = Vec::with_capacity(m);
    let mut capacity = vec![0.0f64; m];
    for (sh, endpoint) in endpoints.iter().enumerate() {
        let (conn, cap) = open_session(endpoint, sh, scenario, &roster)?;
        capacity[sh] = cap;
        sessions_used[sh] += 1;
        conns.push(Some(conn));
    }

    let mut alive = vec![true; m];
    let mut shard_busy = vec![0.0f64; m];
    let mut shard_frames = vec![0u64; m];
    let mut streams: Vec<RemoteStream> = scenario
        .streams
        .iter()
        .map(|spec| RemoteStream {
            spec: spec.clone(),
            next_frame: 0,
            frames_total: 0,
            frames_processed: 0,
            latency: Percentiles::new(),
            shard: None,
            last_shard: None,
            migrations: 0,
            arrival_credit: 0.0,
            orphaned_at: None,
            worst_gap: 0.0,
            ever_orphaned: false,
            carried_backlog: 0,
            handover_lag: 0.0,
        })
        .collect();
    let mut log: Vec<ShardControl> = Vec::new();
    let mut table = GossipTable::new(m);
    let mut migrations = 0usize;
    let mut initial_committed = vec![0.0f64; m];
    let mut epochs_run = 0usize;
    // Latest scraped snapshot per shard (each supersedes the previous —
    // shards ship cumulative counters, not deltas).
    let mut snapshots: Vec<Option<Registry>> = vec![None; m];
    let mut phase_timings: Vec<EpochPhases> = Vec::new();
    let mut plan_stats = PlanStats::default();
    // Forecast-Σλ slots scraped off the received digests, in poll order
    // — the same publish order the in-process runner traces, so the two
    // traces compare bit for bit on a failure-free run.
    let mut forecast_trace: Vec<(usize, usize, f64)> = Vec::new();

    // Kill a shard in the coordinator's view: drop the connection,
    // orphan its residents (they re-place at the next placement pass).
    fn kill(
        sh: usize,
        at: f64,
        alive: &mut [bool],
        conns: &mut [Option<FrameConn>],
        streams: &mut [RemoteStream],
    ) {
        if !alive[sh] {
            return;
        }
        alive[sh] = false;
        conns[sh] = None;
        for s in streams.iter_mut() {
            if s.shard == Some(sh) {
                s.shard = None;
                s.orphaned_at = Some(at);
                s.ever_orphaned = true;
            }
        }
    }

    // Route one control action to `sh` over the wire; mirror its effect
    // on the coordinator's residency map. Returns false on peer loss.
    fn route(
        sh: usize,
        at: f64,
        action: ControlAction,
        alive: &mut [bool],
        conns: &mut [Option<FrameConn>],
        streams: &mut [RemoteStream],
        log: &mut Vec<ShardControl>,
    ) -> bool {
        let event = WireEvent::action(at, ControlOrigin::Placement, action);
        let sent = match conns[sh].as_mut() {
            Some(conn) => conn.send(&TransportMsg::Control(event.clone())).is_ok(),
            None => false,
        };
        if !sent {
            kill(sh, at, alive, conns, streams);
            return false;
        }
        match event.as_action() {
            Some(ControlAction::AttachStream(spec)) => {
                if let Some(i) = streams.iter().position(|s| s.spec.name == spec.name) {
                    streams[i].shard = Some(sh);
                    streams[i].last_shard = Some(sh);
                }
            }
            Some(ControlAction::DetachStream(idx)) => {
                if let Some(s) = streams.get_mut(*idx) {
                    if s.shard == Some(sh) {
                        s.shard = None;
                    }
                }
            }
            _ => {}
        }
        log.push(ShardControl { shard: sh, event });
        true
    }

    for epoch in 0..scenario.epochs {
        let t0 = epoch as f64 * tick;
        let epoch_clock = scenario.telemetry.then(std::time::Instant::now);

        // 0. Scheduled rejoins, ahead of the gossip round so a shard
        //    that comes back this epoch publishes a digest this epoch.
        //    The redial runs the same capability handshake as the
        //    initial dial; the listener hands it a fresh session, so
        //    the shard re-enters the table at full capacity with zero
        //    committed and the next plan pass re-levels onto it. A
        //    refused or failed redial leaves the shard dead — churn
        //    must never wedge the run.
        for &(re, sh) in &scenario.rejoins {
            // `sh >= m` mirrors the in-process runner's guard: a rejoin
            // entry naming a nonexistent shard is ignored, not a panic.
            if re != epoch || sh >= m || alive[sh] {
                continue;
            }
            // An accepted connection consumes one of the listener's
            // session slots even when the handshake is then rejected
            // (bad token, version skew), so the slot is accounted on
            // connect — otherwise teardown would dial for it again.
            let Ok(conn) =
                connect_with_backoff(&endpoints[sh], 10, std::time::Duration::from_millis(5))
            else {
                continue;
            };
            sessions_used[sh] += 1;
            if let Ok((conn, cap)) = handshake_session(conn, sh, scenario, &roster) {
                conns[sh] = Some(conn);
                alive[sh] = true;
                capacity[sh] = cap;
            }
        }

        // 1. Gossip round over the wire: poll every live shard for its
        //    digest; a peer that cannot answer is a lost shard.
        for sh in 0..m {
            if !alive[sh] {
                continue;
            }
            let polled = {
                let conn = conns[sh].as_mut().expect("alive shard has a connection");
                conn.send(&TransportMsg::Poll { epoch, at: t0 })
                    .and_then(|()| conn.recv())
            };
            match polled {
                Ok(msg) => match msg.as_digest() {
                    Some(digest) => {
                        if let Some(rate) = digest.forecast {
                            forecast_trace.push((epoch, sh, rate));
                        }
                        table.publish(digest);
                    }
                    None => kill(sh, t0, &mut alive, &mut conns, &mut streams),
                },
                Err(_) => kill(sh, t0, &mut alive, &mut conns, &mut streams),
            }
        }
        table.sweep(t0, 0.5 * tick);
        let mut views: Vec<ShardView> = table.views();
        let after_gossip = scenario.telemetry.then(std::time::Instant::now);

        // 2. Place unplaced streams (initial placement + orphans).
        for i in 0..streams.len() {
            if streams[i].shard.is_some() || !streams[i].active() {
                continue;
            }
            let name = streams[i].spec.name.clone();
            let Some(dst) = scenario.policy.place(&name, i, &views) else {
                continue;
            };
            let attach = ControlAction::AttachStream(streams[i].spec.clone());
            if !route(dst, t0, attach, &mut alive, &mut conns, &mut streams, &mut log) {
                continue;
            }
            views[dst].committed += streams[i].spec.demand_at(t0);
            if let Some(lost_at) = streams[i].orphaned_at.take() {
                let gap = (t0 - lost_at).max(0.0);
                if gap > streams[i].worst_gap {
                    streams[i].worst_gap = gap;
                }
                if scenario.handover {
                    // A re-placed orphan re-buffers on its new shard:
                    // its first window of frames carries the outage gap
                    // plus the window refill time.
                    let s = &mut streams[i];
                    s.carried_backlog = s.spec.window as u64;
                    s.handover_lag = gap + s.spec.window as f64 / s.spec.fps.max(1e-9);
                }
            }
        }

        if epoch == 0 {
            for s in streams.iter() {
                if let Some(sh) = s.shard {
                    if s.active() {
                        initial_committed[sh] += s.spec.demand();
                    }
                }
            }
        }

        // 3. Band rebalance: serialised detach→attach migrations.
        if epoch > 0 {
            let residents: Vec<(usize, f64, usize)> = streams
                .iter()
                .enumerate()
                .filter_map(|(i, s)| {
                    if s.active() {
                        s.shard.map(|sh| (i, s.spec.demand_at(t0), sh))
                    } else {
                        None
                    }
                })
                .collect();
            let (moves, stats) = plan(&views, &residents, scenario.groups);
            plan_stats.absorb(&stats);
            for mv in moves {
                if !route(
                    mv.from,
                    t0,
                    ControlAction::DetachStream(mv.stream),
                    &mut alive,
                    &mut conns,
                    &mut streams,
                    &mut log,
                ) {
                    continue;
                }
                let attach = ControlAction::AttachStream(streams[mv.stream].spec.clone());
                if route(mv.to, t0, attach, &mut alive, &mut conns, &mut streams, &mut log) {
                    streams[mv.stream].migrations += 1;
                    migrations += 1;
                    if scenario.handover {
                        // Planned detach→attach: the stream's window
                        // backlog and synchronizer state rebuild on the
                        // target, so its first window of frames lands a
                        // refill time late.
                        let s = &mut streams[mv.stream];
                        s.carried_backlog = s.spec.window as u64;
                        s.handover_lag = s.spec.window as f64 / s.spec.fps.max(1e-9);
                    }
                }
            }
        }

        let after_plan = scenario.telemetry.then(std::time::Instant::now);

        // 4. Serve the epoch: ship per-shard quotas, fold slices back.
        //    (Same arrival-credit arithmetic as the in-process runner.)
        let mut quotas: Vec<u64> = vec![0; streams.len()];
        for (i, s) in streams.iter_mut().enumerate() {
            if !s.active() {
                continue;
            }
            s.arrival_credit += s.spec.rate_at(t0) * tick;
            let q = (s.arrival_credit.floor().max(0.0) as u64).min(s.remaining());
            s.arrival_credit -= q as f64;
            quotas[i] = q;
        }
        for sh in 0..m {
            if !alive[sh] {
                continue;
            }
            let shard_quotas: Vec<(usize, u64)> = streams
                .iter()
                .enumerate()
                .filter(|(i, s)| s.shard == Some(sh) && s.active() && quotas[*i] > 0)
                .map(|(i, _)| (i, quotas[i]))
                .collect();
            if shard_quotas.is_empty() {
                continue;
            }
            let seed = scenario
                .seed
                .wrapping_add((epoch as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                ^ ((sh as u64) << 17);
            // An autoscaling shard answers a Tick with its scale actions
            // as Control frames, then the Slice. Fold the frames into
            // the audit log in arrival order; anything else mid-tick is
            // peer loss.
            let mut scale_events: Vec<WireEvent> = Vec::new();
            let mut slice: Option<(f64, u64, Vec<SliceStream>)> = None;
            let ticked = {
                let conn = conns[sh].as_mut().expect("alive shard has a connection");
                match conn.send(&TransportMsg::Tick {
                    epoch,
                    at: t0,
                    seed,
                    quotas: shard_quotas.clone(),
                }) {
                    Err(_) => false,
                    Ok(()) => loop {
                        match conn.recv() {
                            Ok(TransportMsg::Control(ev)) => scale_events.push(ev),
                            Ok(TransportMsg::Telemetry { snapshot, .. }) => {
                                snapshots[sh] = Some(snapshot);
                            }
                            Ok(TransportMsg::Slice {
                                busy,
                                frames,
                                streams: slice_streams,
                                ..
                            }) => {
                                slice = Some((busy, frames, slice_streams));
                                break true;
                            }
                            _ => break false,
                        }
                    },
                }
            };
            if ticked {
                for event in scale_events {
                    log.push(ShardControl { shard: sh, event });
                }
                if let Some((busy, frames, slice_streams)) = slice {
                    shard_busy[sh] += busy;
                    shard_frames[sh] += frames;
                    for ss in slice_streams {
                        let Some(s) = streams.get_mut(ss.id) else {
                            continue;
                        };
                        s.frames_total += ss.total;
                        s.frames_processed += ss.processed;
                        s.next_frame += ss.total;
                        for lat in ss.latencies {
                            // Handover toll: the first carried-backlog
                            // frames after a migration or re-placement
                            // land late by the rebuild time.
                            if s.carried_backlog > 0 {
                                s.carried_backlog -= 1;
                                s.latency.push(lat + s.handover_lag);
                            } else {
                                s.latency.push(lat);
                            }
                        }
                    }
                }
            } else {
                // Tick lost mid-epoch: the shard is gone and this
                // epoch's arrivals with it. kill() unplaces its
                // residents, so the unplaced-streams pass below
                // accounts their quotas as dropped arrivals (exactly
                // once).
                kill(sh, t0, &mut alive, &mut conns, &mut streams);
            }
        }
        // Unplaced streams' arrivals drop on the floor.
        for (i, s) in streams.iter_mut().enumerate() {
            if s.shard.is_none() && s.active() && quotas[i] > 0 {
                s.frames_total += quotas[i];
                s.next_frame += quotas[i];
            }
        }
        // Streams that just played out detach over the wire, so the
        // shard-side digests stop counting their demand.
        for i in 0..streams.len() {
            if streams[i].active() {
                continue;
            }
            if let Some(sh) = streams[i].shard {
                route(
                    sh,
                    t0,
                    ControlAction::DetachStream(i),
                    &mut alive,
                    &mut conns,
                    &mut streams,
                    &mut log,
                );
            }
        }

        epochs_run = epoch + 1;
        if let (Some(t_start), Some(t_gossip), Some(t_plan)) =
            (epoch_clock, after_gossip, after_plan)
        {
            phase_timings.push(EpochPhases {
                epoch,
                gossip: (t_gossip - t_start).as_secs_f64(),
                plan: (t_plan - t_gossip).as_secs_f64(),
                serve: t_plan.elapsed().as_secs_f64(),
            });
        }
        if streams.iter().all(|s| !s.active()) {
            break;
        }
    }

    // Orderly teardown: goodbye to every survivor, then drain session
    // slots the run never used (a rejoin scheduled past the last epoch,
    // or a shard that never died) with dial-and-Bye so no server thread
    // is left blocking in accept(), then join the shard threads.
    for conn in conns.iter_mut().flatten() {
        let _ = conn.send(&TransportMsg::Bye);
    }
    drop(conns);
    for sh in 0..m {
        for _ in sessions_used[sh]..sessions_expected[sh] {
            if let Ok(mut conn) =
                connect_with_backoff(&endpoints[sh], 3, std::time::Duration::from_millis(5))
            {
                let _ = conn.send(&TransportMsg::Bye);
            }
        }
    }
    for handle in handles {
        let _ = handle.join();
    }

    // Assemble the run snapshot from the shards' latest scraped
    // registries (shard-labelled series merge disjointly) plus the
    // coordinator's own control counters — the same lowering the
    // in-process runner applies, so the registries match exactly.
    let mut telemetry = Registry::new();
    if scenario.telemetry {
        for snap in snapshots.iter().flatten() {
            telemetry.merge(snap);
        }
        record_coordinator_telemetry(&mut telemetry, epochs_run, migrations, &log);
    }

    let stream_reports: Vec<ShardStreamReport> = streams
        .iter()
        .map(|s| ShardStreamReport {
            name: s.spec.name.clone(),
            demand: s.spec.demand(),
            frames_total: s.frames_total,
            frames_processed: s.frames_processed,
            migrations: s.migrations,
            final_shard: s.shard.or(s.last_shard),
            p99_latency: s.latency.p99(),
            orphaned_for: if s.orphaned_at.is_some() {
                Some(f64::INFINITY)
            } else if s.ever_orphaned {
                Some(s.worst_gap)
            } else {
                None
            },
        })
        .collect();

    Ok(ShardReport {
        streams: stream_reports,
        shard_capacity: capacity,
        shard_alive: alive,
        shard_busy,
        shard_frames,
        initial_committed,
        control_log: log,
        migrations,
        policy: scenario.policy,
        gossip_interval: tick,
        epochs_run,
        telemetry,
        phase_timings,
        plan_stats,
        forecast_trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};

    fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
        (0..n)
            .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
            .collect()
    }

    fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
        (0..n)
            .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
            .collect()
    }

    #[test]
    fn remote_run_over_uds_serves_everything_and_logs_placements() {
        let scenario = ShardScenario::builder(
            vec![pool(3, 2.5), pool(3, 2.5)],
            uniform_streams(4, 2.5, 100, 4),
        )
        .gossip(10.0)
        .epochs(6)
        .seed(61)
        .build();
        let report = run_sharded_remote(&scenario, RemoteTransport::Uds).expect("remote run");
        assert_eq!(report.orphan_count(), 0);
        assert!(report.shard_alive.iter().all(|&a| a));
        for s in &report.streams {
            assert_eq!(s.frames_total, 100, "stream {}", s.name);
            assert!(
                s.frames_processed as f64 > 0.9 * s.frames_total as f64,
                "stream {} processed {}/{}",
                s.name,
                s.frames_processed,
                s.frames_total
            );
            assert!(s.final_shard.is_some());
        }
        let attaches = report
            .control_log
            .iter()
            .filter(|c| matches!(c.event.as_action(), Some(ControlAction::AttachStream(_))))
            .count();
        assert_eq!(attaches, 4);
    }

    #[test]
    fn remote_matches_inproc_cosim_exactly_on_a_balanced_run() {
        // Same scenario, same seeds, same epoch arithmetic: the remote
        // run is not just "within tolerance" — frame counts match the
        // in-process co-simulation exactly on a failure-free run.
        let scenario = ShardScenario::builder(
            vec![pool(4, 2.5), pool(4, 2.5)],
            uniform_streams(8, 10.0, 300, 4),
        )
        .admission(AdmissionPolicy::admit_all())
        .gossip(10.0)
        .epochs(5)
        .seed(47)
        .telemetry()
        .build();
        let inproc = crate::shard::sim::run_sharded(&scenario);
        let remote = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");
        assert_eq!(remote.total_frames(), inproc.total_frames());
        assert_eq!(remote.total_processed(), inproc.total_processed());
        assert_eq!(remote.epochs_run, inproc.epochs_run);
        assert_eq!(remote.initial_committed, inproc.initial_committed);
        // The wire-scraped metric snapshot is the in-process registry,
        // bit for bit: every counter, gauge and histogram sample crossed
        // the frame codec unchanged.
        assert_eq!(remote.telemetry, inproc.telemetry);
        assert_eq!(remote.phase_timings.len(), remote.epochs_run);
    }

    #[test]
    fn connection_drop_orphans_and_replaces_within_one_interval() {
        let scenario = ShardScenario::builder(
            vec![pool(4, 2.5), pool(4, 2.5), pool(4, 2.5)],
            uniform_streams(9, 2.5, 200, 4),
        )
        .gossip(10.0)
        .epochs(10)
        .seed(67)
        .failure(2, 0)
        .build();
        let report = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");
        assert!(!report.shard_alive[0]);
        assert_eq!(report.orphan_count(), 3);
        assert!(
            report.orphans_replaced_within(report.gossip_interval),
            "worst gap {} vs interval {}",
            report.worst_orphan_gap(),
            report.gossip_interval
        );
        for s in report.streams.iter().filter(|s| s.orphaned_for.is_some()) {
            assert!(matches!(s.final_shard, Some(1) | Some(2)), "{:?}", s.final_shard);
            assert!(s.frames_processed > 0);
        }
    }

    #[test]
    fn binary_codec_remote_run_matches_the_json_run_exactly() {
        // Everything after the handshake — polls, digests, control,
        // ticks, slices, telemetry — rides binary frames, with the
        // shard mirroring the coordinator's codec per frame. The run
        // outcome (frames, control log, scraped registry) must be
        // bit-identical to the JSON-framed run.
        let mk = || {
            ShardScenario::builder(
                vec![pool(3, 2.5), pool(3, 2.5)],
                uniform_streams(6, 2.5, 120, 4),
            )
            .gossip(10.0)
            .epochs(6)
            .seed(83)
            .telemetry()
        };
        let json_run = run_sharded_remote(&mk().build(), RemoteTransport::Uds).expect("json run");
        let bin_run = run_sharded_remote(&mk().codec(Codec::Binary).build(), RemoteTransport::Uds)
            .expect("binary run");
        assert_eq!(bin_run.total_frames(), json_run.total_frames());
        assert_eq!(bin_run.total_processed(), json_run.total_processed());
        assert_eq!(bin_run.control_log, json_run.control_log);
        assert_eq!(bin_run.telemetry, json_run.telemetry);
        assert_eq!(bin_run.plan_stats, json_run.plan_stats);
    }

    #[test]
    fn grouped_remote_planner_matches_the_inproc_counters() {
        // The remote coordinator runs the same grouped planner over the
        // same shard-computed digests, so the deterministic work
        // counters land identically in both modes.
        let mk = || {
            ShardScenario::builder(
                vec![pool(3, 2.5), pool(3, 2.5), pool(3, 2.5), pool(3, 2.5)],
                uniform_streams(8, 2.0, 160, 4),
            )
            .gossip(10.0)
            .epochs(6)
            .seed(9)
            .groups(2)
            .build()
        };
        let inproc = crate::shard::sim::run_sharded(&mk());
        let remote = run_sharded_remote(&mk(), RemoteTransport::Tcp).expect("remote run");
        assert_eq!(remote.plan_stats, inproc.plan_stats);
        assert_eq!(remote.plan_stats.shards_examined, 0);
        assert!(remote.plan_stats.groups_total > 0);
        assert_eq!(remote.migrations, 0);
    }

    #[test]
    fn remote_run_is_deterministic_given_seed() {
        let scenario = ShardScenario::builder(
            vec![pool(2, 2.5), pool(2, 2.5)],
            uniform_streams(4, 5.0, 100, 4),
        )
        .gossip(5.0)
        .epochs(8)
        .seed(71)
        .build();
        let a = run_sharded_remote(&scenario, RemoteTransport::Uds).expect("run a");
        let b = run_sharded_remote(&scenario, RemoteTransport::Uds).expect("run b");
        assert_eq!(a.total_processed(), b.total_processed());
        assert_eq!(a.control_log, b.control_log);
    }

    #[test]
    fn rejoined_shard_serves_again_and_planner_relevels_onto_it() {
        // Shard 0 dies at epoch 2 and redials at epoch 4. Its orphans
        // re-place onto shard 1 (overloading it), and once shard 0 is
        // back as a fresh shard the band rebalancer must move load
        // onto it again.
        let scenario = ShardScenario::builder(
            vec![pool(3, 2.5), pool(3, 2.5)],
            uniform_streams(6, 2.5, 300, 4),
        )
        .gossip(10.0)
        .epochs(14)
        .seed(29)
        .restart(0, 2, 4)
        .build();
        let report = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");
        assert!(report.shard_alive[0], "rejoined shard must finish alive");
        assert!(report.shard_alive[1]);
        assert!(report.orphan_count() > 0, "the failure must orphan streams");
        assert!(
            report.streams.iter().all(|s| s.orphaned_for != Some(f64::INFINITY)),
            "every orphan must be re-placed"
        );
        assert!(
            report.streams.iter().any(|s| s.final_shard == Some(0)),
            "planner must re-level streams onto the rejoined shard"
        );
        for s in &report.streams {
            assert_eq!(s.frames_total, 300, "stream {}", s.name);
            assert!(s.frames_processed > 0, "stream {}", s.name);
        }
    }

    #[test]
    fn handover_mode_charges_the_rebuild_toll_without_changing_frame_counts() {
        // Survivors keep plenty of headroom, so served latencies stay
        // well under the 1.6 s window-refill toll — the toll, not
        // queueing, must own the p99 tail of the re-placed streams.
        let mk = || {
            ShardScenario::builder(
                vec![pool(6, 2.5), pool(6, 2.5), pool(6, 2.5)],
                uniform_streams(9, 2.5, 200, 4),
            )
            .gossip(10.0)
            .epochs(10)
            .seed(67)
            .failure(2, 0)
        };
        let free = run_sharded_remote(&mk().build(), RemoteTransport::Tcp).expect("free run");
        let tolled =
            run_sharded_remote(&mk().handover().build(), RemoteTransport::Tcp).expect("tolled");
        // Frame accounting is identical — the toll prices latency, not
        // throughput.
        assert_eq!(tolled.total_frames(), free.total_frames());
        assert_eq!(tolled.total_processed(), free.total_processed());
        // Every re-placed stream's p99 is at least as bad under the
        // toll, and strictly worse for at least one (its first window
        // lands a full outage gap late).
        let mut strictly_worse = 0;
        for (t, f) in tolled.streams.iter().zip(&free.streams) {
            if f.orphaned_for.is_some() {
                assert!(t.p99_latency >= f.p99_latency - 1e-9, "stream {}", t.name);
                if t.p99_latency > f.p99_latency + 1e-9 {
                    strictly_worse += 1;
                }
            }
        }
        assert!(strictly_worse > 0, "the toll must show up in some orphan's p99");
    }

    #[test]
    fn token_protected_run_succeeds_end_to_end() {
        let scenario = ShardScenario::builder(
            vec![pool(3, 2.5), pool(3, 2.5)],
            uniform_streams(4, 2.5, 100, 4),
        )
        .gossip(10.0)
        .epochs(6)
        .seed(61)
        .token("edge-fleet-key")
        .build();
        let report = run_sharded_remote(&scenario, RemoteTransport::Uds).expect("authed run");
        assert_eq!(report.orphan_count(), 0);
        assert!(report.total_processed() > 0);
    }

    #[test]
    fn bad_token_gets_a_typed_reject_and_a_redial_with_the_right_one_serves() {
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let shard = RemoteShard::new(0, pool(2, 2.5)).with_token("right");
        let server = std::thread::spawn(move || serve_shard_sessions(listener, shard, 3));

        let hello = |token: Option<&str>| TransportMsg::Hello {
            shard: 0,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::default(),
            roster: vec!["s0".to_string()],
            caps: SessionCaps {
                token: token.map(str::to_string),
                ..SessionCaps::default()
            },
        };
        let dial = || {
            connect_with_backoff(&endpoint, 10, std::time::Duration::from_millis(5))
                .expect("dial")
        };

        // Wrong token: typed reject, not a hang and not a bare close.
        let mut conn = dial();
        conn.send(&hello(Some("wrong"))).expect("send hello");
        match conn.recv().expect("recv answer") {
            TransportMsg::Reject { code, detail } => {
                assert_eq!(code, "auth");
                assert!(detail.contains("mismatch"), "{detail}");
            }
            other => panic!("expected reject, got {}", other.label()),
        }
        drop(conn);

        // Missing token: same typed refusal, different detail.
        let mut conn = dial();
        conn.send(&hello(None)).expect("send hello");
        match conn.recv().expect("recv answer") {
            TransportMsg::Reject { code, detail } => {
                assert_eq!(code, "auth");
                assert!(detail.contains("required"), "{detail}");
            }
            other => panic!("expected reject, got {}", other.label()),
        }
        drop(conn);

        // The listener survived both refusals: a redial presenting the
        // right credential completes the handshake.
        let mut conn = dial();
        conn.send(&hello(Some("right"))).expect("send hello");
        match conn.recv().expect("recv answer") {
            TransportMsg::Welcome { shard, .. } => assert_eq!(shard, 0),
            other => panic!("expected welcome, got {}", other.label()),
        }
        conn.send(&TransportMsg::Bye).expect("bye");
        drop(conn);
        server.join().expect("server thread").expect("server ok");
    }

    #[test]
    fn protocol_skew_gets_a_typed_reject_not_a_hang() {
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let shard = RemoteShard::new(3, pool(1, 2.5));
        let server = std::thread::spawn(move || serve_shard(listener, shard));
        let mut conn = connect_with_backoff(&endpoint, 10, std::time::Duration::from_millis(5))
            .expect("dial");
        conn.send(&TransportMsg::Hello {
            shard: 3,
            protocol: TRANSPORT_VERSION + 40,
            admission: AdmissionPolicy::default(),
            roster: Vec::new(),
            caps: SessionCaps::default(),
        })
        .expect("send hello");
        match conn.recv().expect("recv answer") {
            TransportMsg::Reject { code, detail } => {
                assert_eq!(code, "protocol");
                assert!(detail.contains(&format!("{TRANSPORT_VERSION}")), "{detail}");
            }
            other => panic!("expected reject, got {}", other.label()),
        }
        drop(conn);
        server.join().expect("server thread").expect("server ok");
    }

    #[test]
    fn forecast_digests_are_bit_identical_across_transports() {
        // Forecast-armed run: the shard-side forecasters must observe,
        // predict and publish exactly what the in-process runner's do —
        // the traced forecast-Σλ slots, and the run they steered,
        // compare bit for bit.
        let scenario = ShardScenario::builder(
            vec![pool(4, 2.5), pool(4, 2.5)],
            uniform_streams(6, 2.5, 200, 4),
        )
        .gossip(10.0)
        .epochs(10)
        .seed(53)
        .forecast(crate::forecast::ForecastConfig::default())
        .build();
        let inproc = crate::shard::sim::run_sharded(&scenario);
        let remote = run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("remote run");
        assert!(
            !inproc.forecast_trace.is_empty(),
            "steady streams must tighten the band into published slots"
        );
        assert_eq!(remote.forecast_trace, inproc.forecast_trace);
        assert_eq!(remote.total_frames(), inproc.total_frames());
        assert_eq!(remote.total_processed(), inproc.total_processed());
        assert_eq!(remote.control_log, inproc.control_log);
    }

    #[test]
    fn profiled_arrivals_mirror_exactly_across_transports() {
        // A diurnal rate profile drives quotas, digests and slice rates
        // through `rate_at`/`demand_at` on both runners; with
        // forecasting armed on top, outcomes must still match exactly.
        let profile = crate::fleet::stream::RateProfile::new(40.0, vec![1.0, 2.0]);
        let streams: Vec<StreamSpec> = (0..6)
            .map(|i| {
                let spec = StreamSpec::new(&format!("s{i}"), 2.5, 160).with_window(4);
                if i % 2 == 0 {
                    spec.with_profile(profile.clone())
                } else {
                    spec
                }
            })
            .collect();
        let scenario = ShardScenario::builder(vec![pool(4, 2.5), pool(4, 2.5)], streams)
            .gossip(10.0)
            .epochs(8)
            .seed(17)
            .forecast(crate::forecast::ForecastConfig::default())
            .build();
        let inproc = crate::shard::sim::run_sharded(&scenario);
        let remote = run_sharded_remote(&scenario, RemoteTransport::Uds).expect("remote run");
        assert_eq!(remote.forecast_trace, inproc.forecast_trace);
        assert_eq!(remote.total_frames(), inproc.total_frames());
        assert_eq!(remote.total_processed(), inproc.total_processed());
        assert_eq!(remote.control_log, inproc.control_log);
        assert_eq!(remote.initial_committed, inproc.initial_committed);
    }

    #[test]
    fn scripted_death_carries_the_scaler_snapshot_into_the_rejoin_session() {
        // Session 1 scales the one-device seed pool up under overload,
        // then the scripted death eats a poll. The redial session must
        // resume *warm*: Welcome still advertises the seed pool, but any
        // device the restored scaler attaches continues the replica
        // numbering past the pre-failure pool instead of replaying the
        // ramp from the seed ids.
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let cfg = AutoscaleConfig {
            max_devices: 10,
            device_rate: 2.5,
            cooldown: 1.0,
            ..AutoscaleConfig::default()
        };
        let shard = RemoteShard::new(0, pool(1, 2.5))
            .with_autoscale(cfg)
            .with_failure(1);
        let server = std::thread::spawn(move || serve_shard_sessions(listener, shard, 2));
        let dial = || {
            connect_with_backoff(&endpoint, 10, std::time::Duration::from_millis(5))
                .expect("dial")
        };
        let hello = || TransportMsg::Hello {
            shard: 0,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::default(),
            roster: vec!["s0".to_string()],
            caps: SessionCaps::default(),
        };
        let attach = |fps: f64| {
            TransportMsg::Control(WireEvent::action(
                0.0,
                ControlOrigin::Placement,
                ControlAction::AttachStream(StreamSpec::new("s0", fps, 600).with_window(4)),
            ))
        };
        // Drain one tick's answer, collecting attached replica ids.
        let drain = |conn: &mut FrameConn| {
            let mut replicas = Vec::new();
            loop {
                match conn.recv().expect("tick answer") {
                    TransportMsg::Control(ev) => {
                        if let Some(ControlAction::AttachDevice(d)) = ev.as_action() {
                            replicas.push(d.replica);
                        }
                    }
                    TransportMsg::Slice { .. } => return replicas,
                    other => panic!("unexpected {}", other.label()),
                }
            }
        };

        // Session 1: overload the seed device so the scaler ramps up,
        // then hit the scripted death.
        let mut conn = dial();
        conn.send(&hello()).expect("hello");
        match conn.recv().expect("welcome") {
            TransportMsg::Welcome { .. } => {}
            other => panic!("expected welcome, got {}", other.label()),
        }
        conn.send(&attach(7.5)).expect("attach stream");
        conn.send(&TransportMsg::Tick {
            epoch: 0,
            at: 0.0,
            seed: 11,
            quotas: vec![(0, 75)],
        })
        .expect("tick");
        let pre = drain(&mut conn);
        assert!(!pre.is_empty(), "overloaded seed pool must scale up");
        conn.send(&TransportMsg::Poll { epoch: 1, at: 10.0 }).expect("poll");
        assert!(conn.recv().is_err(), "scripted death must drop the connection");
        drop(conn);

        // Session 2 (the rejoin): seed-pool Welcome, then a heavier
        // overload forces another attach — numbered past the snapshot.
        let mut conn = dial();
        conn.send(&hello()).expect("rejoin hello");
        match conn.recv().expect("rejoin welcome") {
            TransportMsg::Welcome { capacity, .. } => {
                let util = AdmissionPolicy::default().target_utilization;
                assert!(
                    (capacity - 2.5 * util).abs() < 1e-9,
                    "welcome must advertise the seed pool, got {capacity}"
                );
            }
            other => panic!("expected welcome, got {}", other.label()),
        }
        conn.send(&attach(30.0)).expect("re-attach stream");
        conn.send(&TransportMsg::Tick {
            epoch: 2,
            at: 20.0,
            seed: 13,
            quotas: vec![(0, 300)],
        })
        .expect("rejoin tick");
        let post = drain(&mut conn);
        assert!(!post.is_empty(), "the heavier overload must force an attach");
        let high_water = *pre.iter().max().expect("pre replicas");
        assert!(
            post.iter().all(|&r| r > high_water),
            "warm rejoin must continue replica numbering: pre {pre:?}, post {post:?}"
        );
        conn.send(&TransportMsg::Bye).expect("bye");
        drop(conn);
        server.join().expect("server thread").expect("server ok");
    }

    #[test]
    fn token_requiring_shard_rejects_pre_handshake_traffic() {
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let shard = RemoteShard::new(0, pool(1, 2.5)).with_token("k");
        let server = std::thread::spawn(move || serve_shard(listener, shard));
        let mut conn = connect_with_backoff(&endpoint, 10, std::time::Duration::from_millis(5))
            .expect("dial");
        conn.send(&TransportMsg::Poll { epoch: 0, at: 0.0 }).expect("send poll");
        match conn.recv().expect("recv answer") {
            TransportMsg::Reject { code, .. } => assert_eq!(code, "auth"),
            other => panic!("expected reject, got {}", other.label()),
        }
        drop(conn);
        server.join().expect("server thread").expect("server ok");
    }
}
