//! Shard-local capacity control: an [`AutoscaleController`] embedded in
//! a shard, driven one gossip-epoch slice at a time.
//!
//! The paper's §III-B band says detection parallelism should track the
//! gap between offered rate Σλ and processing rate Σμ. In a sharded
//! deployment that decision is cheapest *locally* — inside the shard,
//! before the coordinator's gossip migrates load across hosts — so this
//! module runs the closed loop from [`crate::autoscale`] against a
//! shard's own fleet instance:
//!
//! * Each epoch slice runs through
//!   [`crate::fleet::sim::run_fleet_with`] with the shard's
//!   [`AutoscaleController`] plugged into the
//!   [`FleetController`] seam — the same
//!   controller `run_autoscale_sim` drives, observing every emitted
//!   record and acting at its tick interval *inside* the slice.
//! * Slices run in slice-local virtual time starting at 0; a
//!   time-shifting adapter offsets the controller's clock by the epoch
//!   base `t0`, so hysteresis and cooldown span gossip epochs exactly
//!   as they would in one continuous run
//!   ([`AutoscaleController::begin_slice`] keeps the cooldown clock and
//!   replica counter while resetting slice-local stream state).
//! * Device attach/detach actions are mirrored onto the shard's
//!   persistent pool with registry slot semantics (attach appends a
//!   slot, detach clears one), so the next epoch serves — and the next
//!   gossip digest reports — the scaled pool.
//! * Every scale action is returned as a [`WireEvent`] in shard time,
//!   with ladder-rung (`SwapModel`) stream ids remapped from slice-local
//!   to global ids, ready to ride [`crate::transport::msg`] frames back
//!   to the coordinator's audit [`crate::control::EventLog`].
//!
//! The gossip digest of an autoscaling shard reports **post-scale
//! headroom**: [`projected_capacity`] extends the current pool rate by
//! what the controller may still attach (up to `max_devices` template
//! replicas). The coordinator's migration planner therefore keeps its
//! hands off a shard that can still absorb its committed load by
//! scaling locally, and starts shedding streams only when local scaling
//! is exhausted — shards scale devices and shed streams coherently.

use crate::autoscale::policy::{AutoscaleConfig, AutoscaleController};
use crate::control::{ControlAction, ControlOrigin, WireEvent};
use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::registry::FleetRegistry;
use crate::fleet::sim::{run_fleet_with, FleetController, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::fleet::FleetReport;
use crate::gate::GateConfig;
use crate::types::OutputRecord;

/// Capacity a shard can reach by scaling locally: the util-adjusted sum
/// of its current pool rate plus the template replicas the controller
/// may still attach (`max_devices − |pool|`, at `device_rate` each).
/// This is what an autoscaling shard advertises in its gossip digest —
/// post-scale headroom — so migrations start only once local scaling is
/// exhausted (at `max_devices` the projection collapses to the actual
/// pool rate).
pub fn projected_capacity(cfg: &AutoscaleConfig, pool: &[DeviceInstance], util: f64) -> f64 {
    let current: f64 = pool.iter().map(|d| d.rate()).sum();
    let slots = cfg.max_devices.saturating_sub(pool.len());
    (current + slots as f64 * cfg.device_rate.max(0.0)) * util
}

/// Time-shifting [`FleetController`] adapter: the slice engine runs in
/// slice-local time, the wrapped controller's cooldown clock must see
/// continuous shard time.
struct Shifted<'a> {
    ctl: &'a mut AutoscaleController,
    base: f64,
}

impl FleetController for Shifted<'_> {
    fn interval(&self) -> f64 {
        FleetController::interval(self.ctl)
    }

    fn observe(&mut self, now: f64, sid: usize, record: &OutputRecord) {
        FleetController::observe(self.ctl, self.base + now, sid, record);
    }

    fn act(&mut self, now: f64, reg: &FleetRegistry) -> Vec<ControlAction> {
        FleetController::act(self.ctl, self.base + now, reg)
    }
}

/// Warm-rejoin snapshot of a shard's scaling state: the device pool the
/// shard had scaled to, plus the controller's continuity state (cooldown
/// clock, replica counter). A shard that restarts and rejoins with this
/// state resumes serving at its scaled capacity immediately; a cold
/// join restarts from the seed pool and pays the whole scale-up ramp
/// again — `rust/tests/integration_churn.rs` pins the difference.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalerState {
    /// Shard time of the controller's last device action.
    pub last_device_action: f64,
    /// Next template replica id (keeps replica ids unique across the
    /// restart).
    pub next_replica: usize,
    /// The scaled device pool at capture time.
    pub pool: Vec<DeviceInstance>,
}

/// One shard's local capacity controller, persistent across the gossip
/// epochs of a sharded run.
pub struct ShardAutoscaler {
    ctl: AutoscaleController,
    /// Per-frame motion gate applied to every slice this shard runs
    /// (`None` detects every admitted frame). Gate policy state is
    /// slice-local — the motion model is keyed by stream *name*, so the
    /// same stream gates identically on whichever shard hosts it.
    gate: Option<GateConfig>,
}

impl ShardAutoscaler {
    pub fn new(cfg: AutoscaleConfig) -> ShardAutoscaler {
        ShardAutoscaler {
            ctl: AutoscaleController::new(cfg),
            gate: None,
        }
    }

    /// Arm (or disarm) the per-frame motion gate for subsequent slices.
    pub fn set_gate(&mut self, gate: Option<GateConfig>) {
        self.gate = gate;
    }

    /// Arm (or clear) the forecast Σλ hint on the embedded controller
    /// for subsequent slices (see
    /// [`AutoscaleController::set_forecast_demand`]).
    pub fn set_forecast_demand(&mut self, hint: Option<f64>) {
        self.ctl.set_forecast_demand(hint);
    }

    /// Capture the warm-rejoin snapshot: `pool` is the shard's current
    /// scaled pool (kept outside the scaler by the runners).
    pub fn export_state(&self, pool: &[DeviceInstance]) -> ScalerState {
        let (last_device_action, next_replica) = self.ctl.device_state();
        ScalerState {
            last_device_action,
            next_replica,
            pool: pool.to_vec(),
        }
    }

    /// Restore a [`ScalerState`] captured before a restart; returns the
    /// pool the shard should resume serving with.
    pub fn restore_state(&mut self, state: &ScalerState) -> Vec<DeviceInstance> {
        self.ctl
            .restore_device_state(state.last_device_action, state.next_replica);
        state.pool.clone()
    }

    /// The configuration the embedded controller runs with.
    pub fn cfg(&self) -> &AutoscaleConfig {
        &self.ctl.cfg
    }

    /// The shard's digest capacity (see [`projected_capacity`]).
    pub fn projected_capacity(&self, pool: &[DeviceInstance], util: f64) -> f64 {
        projected_capacity(&self.ctl.cfg, pool, util)
    }

    /// Run one epoch slice under the closed loop.
    ///
    /// `specs` are the shard's resident streams clipped to this epoch's
    /// arrival quotas, `ids[k]` the global stream id of `specs[k]`, `t0`
    /// the epoch base time and `seed` the slice seed (both exactly as
    /// the plain runners use them). The shard's persistent `pool` is
    /// updated in place with the slice's device actions; the returned
    /// events are the slice's scale actions in shard time, with global
    /// stream ids — the shard's contribution to the coordinator's audit
    /// log.
    ///
    /// Id scoping in the returned events: `SwapModel` stream ids are
    /// remapped to **global** ids, but `DetachDevice` ids are the
    /// registry slot indices of the slice they were taken in — the pool
    /// compacts between slices, so device slots renumber per epoch.
    /// The audit log therefore identifies *which slice took which
    /// action on which slot*, not a run-global device identity (attach
    /// events carry the full [`DeviceInstance`], whose replica id *is*
    /// stable across the shard's whole run).
    pub fn run_slice(
        &mut self,
        pool: &mut Vec<DeviceInstance>,
        admission: &AdmissionPolicy,
        specs: Vec<StreamSpec>,
        ids: &[usize],
        t0: f64,
        seed: u64,
    ) -> (FleetReport, Vec<WireEvent>) {
        self.ctl.begin_slice();
        let mut sub = Scenario::new(pool.clone(), specs)
            .with_admission(admission.clone())
            .with_seed(seed);
        if let Some(gate) = &self.gate {
            sub = sub.with_gate(gate.clone());
        }
        let out = {
            let mut shifted = Shifted { ctl: &mut self.ctl, base: t0 };
            run_fleet_with(&sub, Some(&mut shifted))
        };

        // Mirror the slice's device actions onto the persistent pool
        // with the registry's slot semantics — attach appends a slot,
        // detach clears one (slot ids stay stable within the slice) —
        // then compact to the attached instances for the next epoch.
        let mut slots: Vec<(DeviceInstance, bool)> =
            pool.iter().cloned().map(|d| (d, true)).collect();
        let mut events = Vec::new();
        for r in &out.control_log {
            if r.origin != ControlOrigin::Controller {
                continue;
            }
            match &r.action {
                ControlAction::AttachDevice(d) => slots.push((d.clone(), true)),
                ControlAction::DetachDevice(dev) => {
                    if let Some(s) = slots.get_mut(*dev) {
                        s.1 = false;
                    }
                }
                _ => {}
            }
            let action = match &r.action {
                ControlAction::SwapModel { stream, rung } => match ids.get(*stream) {
                    Some(&global) => ControlAction::SwapModel { stream: global, rung: *rung },
                    // A swap for a stream outside the slice roster cannot
                    // be attributed globally; don't mis-audit it.
                    None => continue,
                },
                other => other.clone(),
            };
            events.push(WireEvent::action(
                t0 + r.at,
                ControlOrigin::Controller,
                action,
            ));
        }
        *pool = slots
            .into_iter()
            .filter(|(_, attached)| *attached)
            .map(|(d, _)| d)
            .collect();

        // Gate verdicts ride the same channel, shifted into shard time
        // and remapped to global stream ids (a verdict for a stream
        // outside the slice roster cannot be attributed and is skipped).
        for ev in &out.gate_log {
            if let crate::control::WirePayload::Gate { stream, frame, verdict } = ev.payload {
                let Some(&global) = ids.get(stream) else { continue };
                events.push(WireEvent::gate(t0 + ev.at, global, frame, verdict));
            }
        }
        (out.report, events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};

    fn dev(replica: usize, rate: f64) -> DeviceInstance {
        DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, replica, rate)
    }

    #[test]
    fn projected_capacity_extends_to_max_devices_then_collapses() {
        let cfg = AutoscaleConfig {
            max_devices: 6,
            device_rate: 2.5,
            ..AutoscaleConfig::default()
        };
        let pool = vec![dev(0, 2.5), dev(1, 2.5)];
        // 2 × 2.5 current + 4 more template slots × 2.5, at util 1.0.
        assert!((projected_capacity(&cfg, &pool, 1.0) - 15.0).abs() < 1e-9);
        // At the cap the projection is just the actual pool rate.
        let full: Vec<DeviceInstance> = (0..6).map(|i| dev(i, 2.5)).collect();
        assert!((projected_capacity(&cfg, &full, 1.0) - 15.0).abs() < 1e-9);
        let over: Vec<DeviceInstance> = (0..8).map(|i| dev(i, 2.5)).collect();
        assert!((projected_capacity(&cfg, &over, 1.0) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn underprovisioned_slice_scales_the_persistent_pool_up() {
        // λ = 5 FPS against one 2.5-FPS device: the band floor (≈ 5.26
        // at util 0.95) forces an attach inside the first slice, and the
        // attached device must persist into the shard's pool.
        let cfg = AutoscaleConfig {
            max_devices: 8,
            ..AutoscaleConfig::default()
        };
        let mut scaler = ShardAutoscaler::new(cfg);
        let mut pool = vec![dev(0, 2.5)];
        let specs = vec![StreamSpec::new("s0", 5.0, 50).with_window(4)];
        let (report, events) =
            scaler.run_slice(&mut pool, &AdmissionPolicy::default(), specs, &[0], 0.0, 7);
        assert!(report.total_frames() > 0);
        assert!(!events.is_empty(), "expected at least one scale action");
        assert!(
            events
                .iter()
                .all(|e| e.origin == ControlOrigin::Controller),
            "{events:?}"
        );
        let attaches = events
            .iter()
            .filter(|e| matches!(e.as_action(), Some(ControlAction::AttachDevice(_))))
            .count();
        assert!(attaches >= 1);
        assert_eq!(pool.len(), 1 + attaches, "pool must mirror the attaches");
    }

    #[test]
    fn restored_scaler_resumes_pool_cooldown_and_replica_ids() {
        // Scale a pool up in epoch 0, snapshot, then "restart" into a
        // fresh scaler. The restored scaler must (a) resume the scaled
        // pool, (b) still honour the pre-restart cooldown, and (c) keep
        // replica ids advancing — while a cold scaler restarts from the
        // seed pool and re-attaches from scratch.
        let cfg = AutoscaleConfig {
            cooldown: 15.0,
            max_devices: 8,
            ..AutoscaleConfig::default()
        };
        let mut scaler = ShardAutoscaler::new(cfg.clone());
        let mut pool = vec![dev(0, 2.5)];
        let specs = vec![StreamSpec::new("s0", 5.0, 50).with_window(4)];
        let (_, events) =
            scaler.run_slice(&mut pool, &AdmissionPolicy::default(), specs, &[0], 0.0, 7);
        assert!(pool.len() > 1, "epoch 0 must scale up");
        let state = scaler.export_state(&pool);
        assert_eq!(state.pool, pool);
        assert!(state.last_device_action >= 0.0 && state.next_replica > 1);
        let first_attach = events
            .iter()
            .find_map(|e| match e.as_action() {
                Some(ControlAction::AttachDevice(_)) => Some(e.at),
                _ => None,
            })
            .expect("an attach in epoch 0");

        // Warm rejoin at t0 = 10: same scaled pool, and with the 15 s
        // cooldown carried over no device action may fire before
        // `first_attach + cooldown`.
        let mut warm = ShardAutoscaler::new(cfg.clone());
        let mut warm_pool = warm.restore_state(&state);
        assert_eq!(warm_pool, pool);
        let specs = vec![StreamSpec::new("s0", 5.0, 50).with_window(4)];
        let (_, warm_events) = warm.run_slice(
            &mut warm_pool,
            &AdmissionPolicy::default(),
            specs,
            &[0],
            10.0,
            9,
        );
        for e in &warm_events {
            if matches!(
                e.as_action(),
                Some(ControlAction::AttachDevice(_) | ControlAction::DetachDevice(_))
            ) {
                assert!(
                    e.at >= first_attach + 15.0 - 1e-9,
                    "warm rejoin broke the cooldown: {warm_events:?}"
                );
            }
        }
        // Any replica the warm scaler does attach has a fresh id.
        for e in &warm_events {
            if let Some(ControlAction::AttachDevice(d)) = e.as_action() {
                assert!(d.replica >= state.next_replica, "{warm_events:?}");
            }
        }

        // A cold join restarts from the seed pool: its first attach
        // fires immediately (no carried cooldown), replaying the ramp.
        let mut cold = ShardAutoscaler::new(cfg);
        let mut cold_pool = vec![dev(0, 2.5)];
        let specs = vec![StreamSpec::new("s0", 5.0, 50).with_window(4)];
        let (_, cold_events) = cold.run_slice(
            &mut cold_pool,
            &AdmissionPolicy::default(),
            specs,
            &[0],
            10.0,
            9,
        );
        let cold_attach = cold_events
            .iter()
            .find_map(|e| match e.as_action() {
                Some(ControlAction::AttachDevice(_)) => Some(e.at),
                _ => None,
            })
            .expect("cold join must re-attach");
        assert!(
            cold_attach < first_attach + 15.0,
            "cold join should act before the warm cooldown expires"
        );
    }

    #[test]
    fn cooldown_spans_a_gossip_epoch() {
        // Cooldown (15 s) longer than the gossip epoch (10 s): the
        // attach taken in epoch 0 must suppress scaling at the start of
        // epoch 1; the next attach happens mid-epoch once the cooldown
        // elapses — i.e. consecutive device actions are at least one
        // cooldown apart *across* the slice boundary.
        let cfg = AutoscaleConfig {
            cooldown: 15.0,
            max_devices: 8,
            ..AutoscaleConfig::default()
        };
        let cooldown = cfg.cooldown;
        let mut scaler = ShardAutoscaler::new(cfg);
        let mut pool = vec![dev(0, 2.5)];
        let mut all_events = Vec::new();
        for epoch in 0..2u64 {
            let specs = vec![StreamSpec::new("s0", 5.0, 50).with_window(4)];
            let (_, events) = scaler.run_slice(
                &mut pool,
                &AdmissionPolicy::default(),
                specs,
                &[0],
                epoch as f64 * 10.0,
                11 + epoch,
            );
            all_events.extend(events);
        }
        let times: Vec<f64> = all_events
            .iter()
            .filter(|e| {
                matches!(
                    e.as_action(),
                    Some(ControlAction::AttachDevice(_) | ControlAction::DetachDevice(_))
                )
            })
            .map(|e| e.at)
            .collect();
        assert!(times.len() >= 2, "expected attaches in both epochs: {times:?}");
        // First action lands inside epoch 0, the next only after the
        // cooldown — which is past the epoch-1 boundary.
        assert!(times[0] < 10.0, "{times:?}");
        for pair in times.windows(2) {
            assert!(
                pair[1] - pair[0] >= cooldown - 1e-9,
                "device actions closer than the cooldown: {times:?}"
            );
        }
        assert!(times[1] >= 10.0, "second attach must fall in epoch 1: {times:?}");
    }
}
