//! Capacity gossip: the periodic headroom exchange between shards.
//!
//! Every gossip round each alive shard publishes a [`Headroom`] digest —
//! its util-adjusted pool rate Σμ and committed offered load Σλ (the
//! §III-B band, aggregated per shard). The [`GossipTable`] keeps the
//! freshest digest per shard and expires entries that miss a round:
//! **shard loss is detected as a missed heartbeat**, not by any explicit
//! failure message, which is why orphan re-placement takes (at most) one
//! gossip interval.
//!
//! The table also plans load-band rebalancing ([`GossipTable::plan_moves`]):
//! a shard whose committed load exceeds its capacity sheds the largest
//! streams the survivors can absorb — restoring the band in the fewest
//! (costly) migrations — as long as no move pushes a target out of
//! band. Moves are executed by the runner as serialised detach→attach
//! control events.

use crate::shard::placement::ShardView;

/// One shard's published capacity digest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Headroom {
    pub shard: usize,
    /// Gossip time the digest was published.
    pub at: f64,
    /// Util-adjusted pool rate Σμ (admission capacity, FPS).
    pub capacity: f64,
    /// Committed offered load Σλ of resident streams (FPS).
    pub committed: f64,
}

/// A planned stream migration (executed as detach→attach wire events).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Migration {
    /// Global stream index.
    pub stream: usize,
    pub from: usize,
    pub to: usize,
}

/// Freshest per-shard digests, with heartbeat expiry.
#[derive(Debug, Clone)]
pub struct GossipTable {
    entries: Vec<Option<Headroom>>,
}

impl GossipTable {
    pub fn new(num_shards: usize) -> GossipTable {
        GossipTable {
            entries: vec![None; num_shards],
        }
    }

    /// Record a shard's digest for this round.
    pub fn publish(&mut self, digest: Headroom) {
        if digest.shard < self.entries.len() {
            self.entries[digest.shard] = Some(digest);
        }
    }

    /// Expire digests older than `max_age` seconds at gossip time `now` —
    /// a shard that missed a round disappears from every view.
    pub fn sweep(&mut self, now: f64, max_age: f64) {
        for e in self.entries.iter_mut() {
            let stale = matches!(e, Some(h) if now - h.at > max_age + 1e-9);
            if stale {
                *e = None;
            }
        }
    }

    /// Shards with a fresh digest.
    pub fn live_shards(&self) -> Vec<usize> {
        self.entries
            .iter()
            .filter_map(|e| e.map(|h| h.shard))
            .collect()
    }

    /// Placement views: one per shard slot; slots without a fresh digest
    /// read as dead with zero capacity.
    pub fn views(&self) -> Vec<ShardView> {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| match e {
                Some(h) => ShardView {
                    shard: i,
                    alive: true,
                    capacity: h.capacity,
                    committed: h.committed,
                },
                None => ShardView {
                    shard: i,
                    alive: false,
                    capacity: 0.0,
                    committed: 0.0,
                },
            })
            .collect()
    }

    /// Plan band-restoring migrations against this table's views (see
    /// [`plan_moves`]).
    pub fn plan_moves(&self, residents: &[(usize, f64, usize)]) -> Vec<Migration> {
        plan_moves(&self.views(), residents)
    }
}

/// Plan band-restoring migrations. `residents` lists every placed
/// stream as `(global stream index, demand λ, shard)`. Out-of-band
/// shards shed **largest-that-fits** streams first — each migration has
/// real handover cost, so the band is restored in the fewest moves;
/// smaller streams are tried only when no target can absorb a larger
/// one. A move is planned only when the target stays in band after
/// absorbing the stream. Deterministic: ties break to the lowest stream
/// index / shard id.
pub fn plan_moves(views: &[ShardView], residents: &[(usize, f64, usize)]) -> Vec<Migration> {
    let mut views = views.to_vec();
    let mut moves = Vec::new();
    let overloaded: Vec<usize> = views
        .iter()
        .filter(|v| v.alive && !v.in_band())
        .map(|v| v.shard)
        .collect();
    for src in overloaded {
        // Residents of `src`, largest demand first (stable on index).
        let mut local: Vec<(usize, f64)> = residents
            .iter()
            .filter(|&&(_, _, sh)| sh == src)
            .map(|&(idx, d, _)| (idx, d))
            .collect();
        local.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        for (idx, demand) in local {
            if views[src].in_band() {
                break;
            }
            // Best target: alive, not src, max headroom, stays in
            // band after the move.
            let mut target: Option<usize> = None;
            for v in &views {
                if !v.alive || v.shard == src {
                    continue;
                }
                if v.committed + demand > v.capacity + 1e-9 {
                    continue;
                }
                let better = match target {
                    None => true,
                    Some(t) => v.headroom() > views[t].headroom() + 1e-9,
                };
                if better {
                    target = Some(v.shard);
                }
            }
            let Some(dst) = target else { continue };
            views[src].committed -= demand;
            views[dst].committed += demand;
            moves.push(Migration {
                stream: idx,
                from: src,
                to: dst,
            });
        }
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    fn digest(shard: usize, at: f64, capacity: f64, committed: f64) -> Headroom {
        Headroom { shard, at, capacity, committed }
    }

    #[test]
    fn missed_heartbeat_expires_and_kills_the_view() {
        let mut t = GossipTable::new(2);
        t.publish(digest(0, 0.0, 10.0, 5.0));
        t.publish(digest(1, 0.0, 10.0, 2.0));
        assert_eq!(t.live_shards(), vec![0, 1]);
        // Next round: only shard 0 publishes; shard 1's digest ages out.
        t.publish(digest(0, 10.0, 10.0, 5.0));
        t.sweep(10.0, 5.0);
        assert_eq!(t.live_shards(), vec![0]);
        let views = t.views();
        assert!(views[0].alive);
        assert!(!views[1].alive);
        assert_eq!(views[1].capacity, 0.0);
    }

    #[test]
    fn plan_moves_sheds_largest_fitting_stream_in_fewest_moves() {
        let mut t = GossipTable::new(2);
        // Shard 0 is 5.75 FPS over its band; shard 1 has 8.25 headroom.
        t.publish(digest(0, 0.0, 14.25, 20.0));
        t.publish(digest(1, 0.0, 14.25, 6.0));
        // Streams 0..3 on shard 0 (demands 6, 6, 2), stream 3 on shard 1.
        let residents = [(0, 6.0, 0), (1, 6.0, 0), (2, 2.0, 0), (3, 6.0, 1)];
        let moves = t.plan_moves(&residents);
        // Largest-that-fits: one 6-FPS move restores the band
        // (20 - 6 = 14 ≤ 14.25) — migrations are costly, so the planner
        // never moves two streams where one suffices.
        assert_eq!(moves, vec![Migration { stream: 0, from: 0, to: 1 }]);
    }

    #[test]
    fn plan_moves_falls_back_to_smaller_streams_when_large_ones_do_not_fit() {
        let mut t = GossipTable::new(2);
        // Shard 0 overloaded by 2; the 6-FPS streams do not fit shard 1
        // (10 + 6 > 14.25), but the 2-FPS one does.
        t.publish(digest(0, 0.0, 14.25, 16.0));
        t.publish(digest(1, 0.0, 14.25, 10.0));
        let residents = [(0, 6.0, 0), (1, 6.0, 0), (2, 2.0, 0), (3, 10.0, 1)];
        let moves = t.plan_moves(&residents);
        assert_eq!(moves, vec![Migration { stream: 2, from: 0, to: 1 }]);
    }

    #[test]
    fn plan_moves_never_pushes_target_out_of_band() {
        let mut t = GossipTable::new(2);
        t.publish(digest(0, 0.0, 9.5, 12.0));
        t.publish(digest(1, 0.0, 9.5, 8.0));
        // The only candidate move (2.5 FPS) would push shard 1 to 10.5 >
        // 9.5: nothing moves, shard 0 stays (admission-degraded) rather
        // than overloading the survivor.
        let residents = [(0, 2.5, 0), (1, 9.5, 0)];
        assert!(t.plan_moves(&residents).is_empty());
    }

    #[test]
    fn in_band_shards_plan_nothing() {
        let mut t = GossipTable::new(2);
        t.publish(digest(0, 0.0, 10.0, 9.0));
        t.publish(digest(1, 0.0, 10.0, 1.0));
        assert!(t.plan_moves(&[(0, 9.0, 0), (1, 1.0, 1)]).is_empty());
    }
}
