//! Capacity gossip: the periodic headroom exchange between shards.
//!
//! Every gossip round each alive shard publishes a [`Headroom`] digest —
//! its util-adjusted pool rate Σμ and committed offered load Σλ (the
//! §III-B band, aggregated per shard). The [`GossipTable`] keeps the
//! freshest digest per shard and expires entries that miss a round:
//! **shard loss is detected as a missed heartbeat**, not by any explicit
//! failure message, which is why orphan re-placement takes (at most) one
//! gossip interval.
//!
//! Digests may also carry a forecast-Σλ slot ([`Headroom::forecast`],
//! ROADMAP item 4): the shard's confidence-gated prediction of its
//! offered load one horizon ahead. Planning then works against
//! `max(committed, forecast)` ([`ShardView::load`]), so load sheds
//! *ahead* of predicted ramps; digests without the slot (legacy peers,
//! forecast disabled) behave exactly as before.
//!
//! The table also plans load-band rebalancing ([`GossipTable::plan_moves`]):
//! a shard whose committed load exceeds its capacity sheds the largest
//! streams the survivors can absorb — restoring the band in the fewest
//! (costly) migrations — as long as no move pushes a target out of
//! band. Moves are executed by the runner as serialised detach→attach
//! control events. Both sides of the plan carry real hysteresis margins,
//! not float epsilons: a shard sheds only when overloaded by more than
//! [`SHED_HYSTERESIS`] (sub-margin digest jitter is left to admission),
//! and targets whose headroom differs by less than [`TARGET_HYSTERESIS`]
//! are treated as tied, breaking deterministically to the lowest shard
//! id — so jittering views of near-equal shards can never ping-pong a
//! stream between them.

use crate::shard::placement::ShardView;

/// One shard's published capacity digest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Headroom {
    pub shard: usize,
    /// Gossip time the digest was published.
    pub at: f64,
    /// Util-adjusted pool rate Σμ (admission capacity, FPS).
    pub capacity: f64,
    /// Committed offered load Σλ of resident streams (FPS).
    pub committed: f64,
    /// Forecast-Σλ: the shard's predicted offered load one horizon
    /// ahead, published only when its confidence band is tight. `None`
    /// on legacy digests and forecast-free runs.
    pub forecast: Option<f64>,
}

/// A planned stream migration (executed as detach→attach wire events).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Migration {
    /// Global stream index.
    pub stream: usize,
    pub from: usize,
    pub to: usize,
}

/// Migration-target hysteresis (FPS): a candidate target must beat the
/// incumbent's headroom by at least this margin to displace it. Within
/// the margin the two are considered tied and the lowest shard id wins —
/// deterministically, and robustly against per-epoch view jitter that a
/// bare float epsilon would amplify into stream ping-pong.
pub const TARGET_HYSTERESIS: f64 = 0.25;

/// Shed hysteresis (FPS): a shard plans migrations away only when its
/// projected load exceeds capacity by more than this margin. Published
/// digests jitter (autoscale capacity moves, quota quantisation); with
/// the old bare `1e-9` band check, sub-margin noise alternately tipped
/// two symmetric shards "out of band" and bounced a stream between them
/// every epoch. Sub-margin overloads are left to admission degradation,
/// which is free to undo.
pub const SHED_HYSTERESIS: f64 = 0.25;

/// Freshest per-shard digests, with heartbeat expiry.
#[derive(Debug, Clone)]
pub struct GossipTable {
    entries: Vec<Option<Headroom>>,
}

impl GossipTable {
    pub fn new(num_shards: usize) -> GossipTable {
        GossipTable {
            entries: vec![None; num_shards],
        }
    }

    /// Record a shard's digest for this round.
    pub fn publish(&mut self, digest: Headroom) {
        if digest.shard < self.entries.len() {
            self.entries[digest.shard] = Some(digest);
        }
    }

    /// Expire digests older than `max_age` seconds at gossip time `now` —
    /// a shard that missed a round disappears from every view.
    pub fn sweep(&mut self, now: f64, max_age: f64) {
        for e in self.entries.iter_mut() {
            let stale = matches!(e, Some(h) if now - h.at > max_age + 1e-9);
            if stale {
                *e = None;
            }
        }
    }

    /// Shards with a fresh digest.
    pub fn live_shards(&self) -> Vec<usize> {
        self.entries
            .iter()
            .filter_map(|e| e.map(|h| h.shard))
            .collect()
    }

    /// Placement views: one per shard slot; slots without a fresh digest
    /// read as dead with zero capacity.
    pub fn views(&self) -> Vec<ShardView> {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| match e {
                Some(h) => ShardView {
                    shard: i,
                    alive: true,
                    capacity: h.capacity,
                    committed: h.committed,
                    forecast: h.forecast,
                },
                None => ShardView {
                    shard: i,
                    alive: false,
                    capacity: 0.0,
                    committed: 0.0,
                    forecast: None,
                },
            })
            .collect()
    }

    /// Plan band-restoring migrations against this table's views (see
    /// [`plan_moves`]).
    pub fn plan_moves(&self, residents: &[(usize, f64, usize)]) -> Vec<Migration> {
        plan_moves(&self.views(), residents)
    }
}

/// Plan band-restoring migrations. `residents` lists every placed
/// stream as `(global stream index, demand λ, shard)`. Shards overloaded
/// by more than [`SHED_HYSTERESIS`] — on projected load, so a tight
/// forecast sheds ahead of the ramp — shed **largest-that-fits** streams
/// first: each migration has real handover cost, so the band is restored
/// in the fewest moves; smaller streams are tried only when no target
/// can absorb a larger one. A move is planned only when the target stays
/// in band after absorbing the stream. Deterministic: ties break to the
/// lowest stream index / shard id, with targets within
/// [`TARGET_HYSTERESIS`] of each other's headroom counting as tied.
pub fn plan_moves(views: &[ShardView], residents: &[(usize, f64, usize)]) -> Vec<Migration> {
    let mut views = views.to_vec();
    let mut moves = Vec::new();
    let overloaded: Vec<usize> = views
        .iter()
        .filter(|v| v.alive && v.load() > v.capacity + SHED_HYSTERESIS)
        .map(|v| v.shard)
        .collect();
    for src in overloaded {
        // Residents of `src`, largest demand first (stable on index).
        let mut local: Vec<(usize, f64)> = residents
            .iter()
            .filter(|&&(_, _, sh)| sh == src)
            .map(|&(idx, d, _)| (idx, d))
            .collect();
        local.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        for (idx, demand) in local {
            if views[src].in_band() {
                break;
            }
            // Best target: alive, not src, max headroom (with hysteresis
            // — near-ties go to the lowest shard id), stays in band
            // after the move. Fit and headroom are judged on projected
            // load, so a target about to ramp is not overfilled.
            let mut target: Option<usize> = None;
            for v in &views {
                if !v.alive || v.shard == src {
                    continue;
                }
                if v.load() + demand > v.capacity + 1e-9 {
                    continue;
                }
                let better = match target {
                    None => true,
                    // Strictly better only beyond the hysteresis margin;
                    // within it the incumbent (lower shard id, since
                    // views iterate in ascending order) keeps the slot.
                    Some(t) => v.headroom() > views[t].headroom() + TARGET_HYSTERESIS,
                };
                if better {
                    target = Some(v.shard);
                }
            }
            let Some(dst) = target else { continue };
            views[src].committed -= demand;
            views[dst].committed += demand;
            // The stream's predicted contribution moves with it — without
            // this a ramping shard would keep shedding against a stale
            // projection until it was empty.
            if let Some(f) = views[src].forecast.as_mut() {
                *f = (*f - demand).max(0.0);
            }
            if let Some(f) = views[dst].forecast.as_mut() {
                *f += demand;
            }
            moves.push(Migration {
                stream: idx,
                from: src,
                to: dst,
            });
        }
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    fn digest(shard: usize, at: f64, capacity: f64, committed: f64) -> Headroom {
        Headroom { shard, at, capacity, committed, forecast: None }
    }

    #[test]
    fn missed_heartbeat_expires_and_kills_the_view() {
        let mut t = GossipTable::new(2);
        t.publish(digest(0, 0.0, 10.0, 5.0));
        t.publish(digest(1, 0.0, 10.0, 2.0));
        assert_eq!(t.live_shards(), vec![0, 1]);
        // Next round: only shard 0 publishes; shard 1's digest ages out.
        t.publish(digest(0, 10.0, 10.0, 5.0));
        t.sweep(10.0, 5.0);
        assert_eq!(t.live_shards(), vec![0]);
        let views = t.views();
        assert!(views[0].alive);
        assert!(!views[1].alive);
        assert_eq!(views[1].capacity, 0.0);
    }

    #[test]
    fn plan_moves_sheds_largest_fitting_stream_in_fewest_moves() {
        let mut t = GossipTable::new(2);
        // Shard 0 is 5.75 FPS over its band; shard 1 has 8.25 headroom.
        t.publish(digest(0, 0.0, 14.25, 20.0));
        t.publish(digest(1, 0.0, 14.25, 6.0));
        // Streams 0..3 on shard 0 (demands 6, 6, 2), stream 3 on shard 1.
        let residents = [(0, 6.0, 0), (1, 6.0, 0), (2, 2.0, 0), (3, 6.0, 1)];
        let moves = t.plan_moves(&residents);
        // Largest-that-fits: one 6-FPS move restores the band
        // (20 - 6 = 14 ≤ 14.25) — migrations are costly, so the planner
        // never moves two streams where one suffices.
        assert_eq!(moves, vec![Migration { stream: 0, from: 0, to: 1 }]);
    }

    #[test]
    fn plan_moves_falls_back_to_smaller_streams_when_large_ones_do_not_fit() {
        let mut t = GossipTable::new(2);
        // Shard 0 overloaded by 2; the 6-FPS streams do not fit shard 1
        // (10 + 6 > 14.25), but the 2-FPS one does.
        t.publish(digest(0, 0.0, 14.25, 16.0));
        t.publish(digest(1, 0.0, 14.25, 10.0));
        let residents = [(0, 6.0, 0), (1, 6.0, 0), (2, 2.0, 0), (3, 10.0, 1)];
        let moves = t.plan_moves(&residents);
        assert_eq!(moves, vec![Migration { stream: 2, from: 0, to: 1 }]);
    }

    #[test]
    fn plan_moves_never_pushes_target_out_of_band() {
        let mut t = GossipTable::new(2);
        t.publish(digest(0, 0.0, 9.5, 12.0));
        t.publish(digest(1, 0.0, 9.5, 8.0));
        // The only candidate move (2.5 FPS) would push shard 1 to 10.5 >
        // 9.5: nothing moves, shard 0 stays (admission-degraded) rather
        // than overloading the survivor.
        let residents = [(0, 2.5, 0), (1, 9.5, 0)];
        assert!(t.plan_moves(&residents).is_empty());
    }

    #[test]
    fn in_band_shards_plan_nothing() {
        let mut t = GossipTable::new(2);
        t.publish(digest(0, 0.0, 10.0, 9.0));
        t.publish(digest(1, 0.0, 10.0, 1.0));
        assert!(t.plan_moves(&[(0, 9.0, 0), (1, 1.0, 1)]).is_empty());
    }

    #[test]
    fn near_tied_targets_break_deterministically_to_the_lowest_shard() {
        // Shards 1 and 2 differ in headroom by less than the hysteresis
        // margin; whichever order views jitter into, the planned target
        // must be shard 1 (lowest id), never a function of sub-margin
        // float noise.
        let mut t = GossipTable::new(3);
        t.publish(digest(0, 0.0, 10.0, 14.0));
        t.publish(digest(1, 0.0, 10.0, 3.0));
        t.publish(digest(2, 0.0, 10.0, 3.0 - 0.9 * TARGET_HYSTERESIS));
        let residents = [(0, 4.0, 0), (1, 10.0, 0)];
        let moves = t.plan_moves(&residents);
        assert_eq!(moves, vec![Migration { stream: 0, from: 0, to: 1 }]);
        // Beyond the margin, genuine headroom differences still win.
        t.publish(digest(2, 0.0, 10.0, 3.0 - 2.0 * TARGET_HYSTERESIS));
        let moves = t.plan_moves(&residents);
        assert_eq!(moves, vec![Migration { stream: 0, from: 0, to: 2 }]);
    }

    #[test]
    fn symmetric_near_tied_shards_never_ping_pong_a_stream() {
        // Regression for the bare `+1e-9` band check: two symmetric
        // shards each carry 8.0 FPS of pinned load plus one 1.9-FPS
        // stream that fits either side. Published committed estimates
        // jitter by sub-margin noise (quota quantisation), so the
        // resident shard's digest reads 10.15 — "out of band" to the old
        // epsilon comparison, which shed the stream to the peer every
        // epoch, forever. With shed hysteresis the sub-margin overload
        // is left to admission: zero migrations over 20 epochs.
        let mut resident = 0usize;
        let mut migrations = Vec::new();
        for epoch in 0..20 {
            let noise = 0.6 * SHED_HYSTERESIS; // sub-margin view jitter
            let mut t = GossipTable::new(2);
            for shard in 0..2 {
                let committed =
                    8.0 + if shard == resident { 1.9 + noise } else { -noise };
                t.publish(digest(shard, epoch as f64, 10.0, committed));
            }
            let residents = [
                (0, 4.5, 0),
                (1, 3.5, 0),
                (2, 4.5, 1),
                (3, 3.5, 1),
                (4, 1.9, resident),
            ];
            for m in t.plan_moves(&residents) {
                migrations.push((epoch, m));
                if m.stream == 4 {
                    resident = m.to;
                }
            }
        }
        assert!(migrations.is_empty(), "streams ping-ponged: {migrations:?}");
    }

    #[test]
    fn forecast_slot_rides_views_and_sheds_ahead_of_the_ramp() {
        let mut t = GossipTable::new(2);
        // Shard 0 is comfortably in band *now* (6 < 10) but forecasts a
        // ramp to 13; shard 1 is quiet with no forecast.
        t.publish(Headroom {
            shard: 0,
            at: 0.0,
            capacity: 10.0,
            committed: 6.0,
            forecast: Some(13.0),
        });
        t.publish(digest(1, 0.0, 10.0, 2.0));
        let views = t.views();
        assert_eq!(views[0].forecast, Some(13.0));
        assert!((views[0].load() - 13.0).abs() < 1e-12);
        assert!(!views[0].in_band(), "projected overload must plan ahead");
        // The planner sheds ahead of the ramp: a 4-FPS stream moves now,
        // before any frame is dropped.
        let moves = t.plan_moves(&[(0, 4.0, 0), (1, 2.0, 0), (2, 2.0, 1)]);
        assert_eq!(moves, vec![Migration { stream: 0, from: 0, to: 1 }]);
        // Without the slot the same committed load plans nothing.
        t.publish(digest(0, 0.0, 10.0, 6.0));
        assert!(t.plan_moves(&[(0, 4.0, 0), (1, 2.0, 0), (2, 2.0, 1)]).is_empty());
    }
}
