//! The sharded-fleet runner: N streams over M fleet instances, in
//! virtual time, quantised at the gossip interval.
//!
//! Each shard wraps its own device pool and admission policy — a full
//! [`crate::fleet`] instance, as a separate process would run it. The
//! co-simulation advances in **gossip epochs** of `gossip_interval`
//! seconds:
//!
//! 1. shards scheduled to *rejoin* this epoch come back first — fresh
//!    pool, fresh controller state, zero residents — in time to attend
//!    the gossip round, so the planner re-levels onto them;
//! 2. every alive shard publishes its [`Headroom`] digest; digests that
//!    miss a round expire (shard loss = missed heartbeat);
//! 3. the placement layer re-places unplaced streams (initial placement
//!    and orphans from a lost shard) against the fresh views;
//! 4. the gossip rebalancer plans band-restoring migrations, executed
//!    as serialised **detach→attach** control events;
//! 5. scheduled shard failures for this epoch take effect (their
//!    residents are orphaned until the next round — at most one gossip
//!    interval);
//! 6. each alive shard serves its residents' epoch slice through the
//!    virtual-time fleet engine ([`crate::fleet::sim::run_fleet`]).
//!
//! With `handover` set, a migrated or re-placed stream additionally
//! pays a realistic state-rebuild toll: its first window of post-move
//! frames is charged the window refill time (plus the orphan gap, for
//! re-placements) on top of its served latency — detach→attach stops
//! teleporting window backlog and synchronizer state for free.
//!
//! Every control decision the coordinator takes crosses the wire: it is
//! encoded to a [`WireEvent`] JSON string, decoded back, and only the
//! *decoded* action is applied — the in-process run exercises exactly
//! the serialisation surface a cross-process deployment needs (the
//! remaining gap, a real transport, is tracked in ROADMAP.md).
//!
//! With [`ShardScenario::forecast`] set, each shard additionally drives
//! a [`crate::forecast::ShardForecast`]: it learns per-stream arrival
//! rates from the slices it serves, publishes tight predicted-Σλ in its
//! gossip digest (the planner then places ahead of a ramp through
//! `ShardView::load`), hints its autoscaler ahead of a predicted step,
//! and arms the admission burst-hold for transients the forecast says
//! will clear. The remote runner drives the identical container at the
//! identical points, so forecast-carrying digests are bit-equal across
//! transports.
//!
//! Quantisation caveat: each epoch slice runs to completion inside the
//! shard's fleet engine, so window backlog at the tick boundary is
//! drained "into" the next epoch. Keep stream windows shallow relative
//! to `gossip_interval × Σμ` (the experiments do) so the carry-over
//! stays a small, configuration-independent constant.

use std::collections::BTreeMap;

use crate::autoscale::policy::AutoscaleConfig;
use crate::control::{binary, ControlAction, ControlOrigin, EventLog, WireEvent};
use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::sim::{run_fleet_with, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::forecast::{should_hold, ForecastConfig, ShardForecast};
use crate::gate::GateConfig;
use crate::shard::autoscale::{ScalerState, ShardAutoscaler};
use crate::shard::gossip::{GossipTable, Headroom};
use crate::shard::placement::{PlacementPolicy, ShardView};
use crate::shard::plan::{plan, PlanStats};
use crate::transport::frame::Codec;
use crate::telemetry::{origin_class, MetricKey, Registry};
use crate::util::json::Json;
use crate::util::stats::Percentiles;
use crate::util::table::{f, Table};

/// One sharded run's full description.
#[derive(Debug, Clone)]
pub struct ShardScenario {
    /// Device pools, one per shard.
    pub shards: Vec<Vec<DeviceInstance>>,
    /// Streams, placed by `policy` at the first gossip round.
    pub streams: Vec<StreamSpec>,
    pub policy: PlacementPolicy,
    /// Admission policy every shard enforces locally.
    pub admission: AdmissionPolicy,
    /// Gossip period in seconds — also the co-simulation epoch.
    pub gossip_interval: f64,
    /// Maximum gossip epochs to run (the run ends early once every
    /// stream is exhausted).
    pub epochs: usize,
    pub seed: u64,
    /// `(epoch, shard)`: the shard dies at the start of that epoch,
    /// right after the gossip round it last attended.
    pub failures: Vec<(usize, usize)>,
    /// `(epoch, shard)`: a dead shard comes back at the start of that
    /// epoch — fresh pool, fresh controller state, zero residents —
    /// ahead of the gossip round, so it publishes a digest the same
    /// epoch and the rebalancer re-levels onto it. A rejoin for a shard
    /// that is still alive is a no-op. The remote runner implements the
    /// same schedule as a redial-and-rehandshake against the shard's
    /// listener.
    pub rejoins: Vec<(usize, usize)>,
    /// Shard-local capacity control: when set, every shard embeds a
    /// [`crate::shard::autoscale::ShardAutoscaler`] built from this
    /// config — pools scale between epoch slices, digests advertise
    /// post-scale headroom, and scale actions land in the control log
    /// with [`ControlOrigin::Controller`].
    pub autoscale: Option<AutoscaleConfig>,
    /// Per-frame motion gate every shard applies to its epoch slices:
    /// verdicts join the control log as [`ControlOrigin::Gate`] events
    /// (same encode→decode hop as every other routed event). Policy
    /// state is slice-local; the motion signal is keyed by stream name,
    /// so a migrated stream gates identically on its new shard.
    pub gate: Option<GateConfig>,
    /// Collect run telemetry: a deterministic metric snapshot
    /// ([`ShardReport::telemetry`]) lowered from every served slice,
    /// plus wall-clock coordinator phase timings
    /// ([`ShardReport::phase_timings`]).
    pub telemetry: bool,
    /// Wire codec for the encode→decode hop every routed control event
    /// crosses: JSON ([`Codec::Json`], the audit/debug format, default)
    /// or the compact binary codec ([`Codec::Binary`],
    /// [`crate::control::binary`]). The codecs are exact-parity — both
    /// decode to the identical [`WireEvent`], so the run outcome and
    /// audit log are codec-independent (pinned in tests).
    pub codec: Codec,
    /// Two-level coordination: plan rebalances over ⌈M/k⌉ shard groups
    /// of size `k` ([`crate::shard::group`]), descending into member
    /// views only where a group digest shows imbalance. `None` (the
    /// default) plans flat over every shard.
    pub groups: Option<usize>,
    /// Shared-secret session auth for the remote runner: every shard
    /// listener requires this token and the coordinator presents it in
    /// its handshake [`crate::control::SessionCaps`]. Ignored by the
    /// in-process runner (there is no session to authenticate).
    pub token: Option<String>,
    /// Charge migrations and orphan re-placements a state-rebuild toll
    /// (see the module docs) instead of moving window state for free.
    /// Off by default so baseline pins are unchanged.
    pub handover: bool,
    /// Forecast-driven control fusion ([`crate::forecast`]): every shard
    /// learns its residents' arrival rates from the epoch slices it
    /// serves and, when the prediction's confidence band is tight,
    /// (a) publishes predicted Σλ in its gossip digest (so the planner
    /// places ahead of a ramp), (b) feeds the prediction to its
    /// autoscaler as a demand hint (attach ahead of the step), and
    /// (c) arms the admission burst-hold for transients the forecast
    /// says will clear. `None` (the default) runs purely reactive
    /// control and publishes no forecast slot — bit-identical to
    /// pre-forecast builds.
    pub forecast: Option<ForecastConfig>,
}

impl ShardScenario {
    pub fn new(shards: Vec<Vec<DeviceInstance>>, streams: Vec<StreamSpec>) -> ShardScenario {
        ShardScenario {
            shards,
            streams,
            policy: PlacementPolicy::LeastLoaded,
            admission: AdmissionPolicy::default(),
            gossip_interval: 5.0,
            epochs: 12,
            seed: 0,
            failures: Vec::new(),
            rejoins: Vec::new(),
            autoscale: None,
            gate: None,
            telemetry: false,
            codec: Codec::Json,
            groups: None,
            token: None,
            handover: false,
            forecast: None,
        }
    }

    /// Start a [`ScenarioBuilder`] — the one configuration surface for
    /// sharded runs (the per-field `with_*` setters it replaced grew
    /// one-per-PR and each re-invented the same consuming-setter
    /// pattern).
    pub fn builder(shards: Vec<Vec<DeviceInstance>>, streams: Vec<StreamSpec>) -> ScenarioBuilder {
        ScenarioBuilder {
            scenario: ShardScenario::new(shards, streams),
        }
    }
}

/// Fluent builder for [`ShardScenario`]. Every knob a sharded run has
/// lives here; `build()` hands back the plain scenario struct (whose
/// fields stay public, so tests can still tweak a built scenario with
/// struct-update syntax).
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    scenario: ShardScenario,
}

impl ScenarioBuilder {
    pub fn policy(mut self, policy: PlacementPolicy) -> ScenarioBuilder {
        self.scenario.policy = policy;
        self
    }

    pub fn admission(mut self, admission: AdmissionPolicy) -> ScenarioBuilder {
        self.scenario.admission = admission;
        self
    }

    pub fn gossip(mut self, interval: f64) -> ScenarioBuilder {
        self.scenario.gossip_interval = interval;
        self
    }

    pub fn epochs(mut self, epochs: usize) -> ScenarioBuilder {
        self.scenario.epochs = epochs;
        self
    }

    pub fn seed(mut self, seed: u64) -> ScenarioBuilder {
        self.scenario.seed = seed;
        self
    }

    /// Kill `shard` at the start of `epoch`.
    pub fn failure(mut self, epoch: usize, shard: usize) -> ScenarioBuilder {
        self.scenario.failures.push((epoch, shard));
        self
    }

    /// Bring a dead `shard` back at the start of `epoch` (fresh pool,
    /// zero residents), ahead of that epoch's gossip round.
    pub fn rejoin(mut self, epoch: usize, shard: usize) -> ScenarioBuilder {
        self.scenario.rejoins.push((epoch, shard));
        self
    }

    /// Rolling-restart shorthand: kill `shard` at `fail_epoch` and
    /// rejoin it at `rejoin_epoch`.
    pub fn restart(self, shard: usize, fail_epoch: usize, rejoin_epoch: usize) -> ScenarioBuilder {
        self.failure(fail_epoch, shard).rejoin(rejoin_epoch, shard)
    }

    pub fn autoscale(mut self, cfg: AutoscaleConfig) -> ScenarioBuilder {
        self.scenario.autoscale = Some(cfg);
        self
    }

    pub fn gate(mut self, gate: GateConfig) -> ScenarioBuilder {
        self.scenario.gate = Some(gate);
        self
    }

    pub fn telemetry(mut self) -> ScenarioBuilder {
        self.scenario.telemetry = true;
        self
    }

    pub fn codec(mut self, codec: Codec) -> ScenarioBuilder {
        self.scenario.codec = codec;
        self
    }

    pub fn groups(mut self, group_size: usize) -> ScenarioBuilder {
        self.scenario.groups = Some(group_size);
        self
    }

    /// Arm shared-secret session auth on every remote shard listener
    /// and present the same token on every coordinator dial.
    pub fn token(mut self, token: &str) -> ScenarioBuilder {
        self.scenario.token = Some(token.to_string());
        self
    }

    /// Charge migrations and re-placements the state-rebuild toll.
    pub fn handover(mut self) -> ScenarioBuilder {
        self.scenario.handover = true;
        self
    }

    /// Fuse forecast-driven control into every shard (see
    /// [`ShardScenario::forecast`]).
    pub fn forecast(mut self, cfg: ForecastConfig) -> ScenarioBuilder {
        self.scenario.forecast = Some(cfg);
        self
    }

    pub fn build(self) -> ShardScenario {
        self.scenario
    }
}

/// Wall-clock seconds the coordinator spent in each phase of one gossip
/// epoch: ingesting digests (`gossip`), planning placement, rebalance
/// and failures (`plan`), and fanning the epoch slices out to shards
/// (`serve`). Wall-clock, so excluded from cross-mode parity checks —
/// the deterministic run outcome lives everywhere else in the report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochPhases {
    pub epoch: usize,
    pub gossip: f64,
    pub plan: f64,
    pub serve: f64,
}

/// Lower one served epoch slice into a shard's cumulative metric
/// registry. A pure function of the slice outcome — exactly the data a
/// remote shard ships in a `Slice` message — so the in-process
/// coordinator and a remote shard build bit-identical snapshots from
/// the same run: per-stream arrival/processed counters, the pool's
/// busy-seconds gauge and frame counter, and every capture→emit
/// latency observed into the shard's `eva_e2e_seconds` histogram.
pub fn record_slice_telemetry<'a, I>(
    reg: &mut Registry,
    shard: usize,
    busy: f64,
    pool_frames: u64,
    streams: I,
) where
    I: IntoIterator<Item = (u64, u64, &'a [f64])>,
{
    let sh = format!("{shard}");
    reg.inc(
        MetricKey::with_labels("eva_shard_slices_total", &[("shard", &sh)]),
        1,
    );
    reg.inc(
        MetricKey::with_labels("eva_shard_pool_frames_total", &[("shard", &sh)]),
        pool_frames,
    );
    let busy_key = MetricKey::with_labels("eva_shard_busy_seconds", &[("shard", &sh)]);
    let prior = reg.gauge(&busy_key).unwrap_or(0.0);
    reg.set_gauge(busy_key, prior + busy);
    let lat_key = MetricKey::with_labels("eva_e2e_seconds", &[("shard", &sh)]);
    for (total, processed, latencies) in streams {
        reg.inc(
            MetricKey::with_labels(
                "eva_shard_frames_total",
                &[("shard", &sh), ("kind", "arrived")],
            ),
            total,
        );
        reg.inc(
            MetricKey::with_labels(
                "eva_shard_frames_total",
                &[("shard", &sh), ("kind", "processed")],
            ),
            processed,
        );
        for &l in latencies {
            reg.observe(lat_key.clone(), l);
        }
    }
}

/// Coordinator-side metrics lowered from a finished run: epochs,
/// migrations, and every routed control event bucketed by the same
/// attribution class [`crate::telemetry::attribute_latency`] uses.
/// Shared by the in-process and remote coordinators so both modes
/// produce the same snapshot for the same run.
pub fn record_coordinator_telemetry(
    reg: &mut Registry,
    epochs_run: usize,
    migrations: usize,
    log: &[ShardControl],
) {
    reg.inc(MetricKey::new("eva_epochs_total"), epochs_run as u64);
    reg.inc(MetricKey::new("eva_migrations_total"), migrations as u64);
    for c in log {
        reg.inc(
            MetricKey::with_labels(
                "eva_control_events_total",
                &[("class", origin_class(&c.event))],
            ),
            1,
        );
    }
}

/// One wire event as routed to a shard (the coordinator's send log).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardControl {
    pub shard: usize,
    pub event: WireEvent,
}

/// Final per-stream outcome of a sharded run.
#[derive(Debug, Clone)]
pub struct ShardStreamReport {
    pub name: String,
    /// Offered rate λ (FPS).
    pub demand: f64,
    pub frames_total: u64,
    pub frames_processed: u64,
    /// Completed detach→attach migrations.
    pub migrations: usize,
    pub final_shard: Option<usize>,
    /// p99 output latency over every served epoch (seconds).
    pub p99_latency: f64,
    /// Worst observed orphan gap: seconds between losing a shard and
    /// being re-placed. `None` if never orphaned; infinite if still
    /// unplaced at the end of the run.
    pub orphaned_for: Option<f64>,
}

impl ShardStreamReport {
    pub fn drop_rate(&self) -> f64 {
        if self.frames_total == 0 {
            return 0.0;
        }
        (self.frames_total - self.frames_processed) as f64 / self.frames_total as f64
    }
}

/// Aggregates for one sharded run.
pub struct ShardReport {
    pub streams: Vec<ShardStreamReport>,
    /// Util-adjusted admission capacity per shard (FPS).
    pub shard_capacity: Vec<f64>,
    /// Shard alive at the end of the run.
    pub shard_alive: Vec<bool>,
    /// Busy seconds / processed frames summed over each shard's pool.
    pub shard_busy: Vec<f64>,
    pub shard_frames: Vec<u64>,
    /// Committed Σλ per shard right after initial placement.
    pub initial_committed: Vec<f64>,
    /// Every control event the coordinator routed, in order.
    pub control_log: Vec<ShardControl>,
    /// Completed stream migrations (gossip rebalance).
    pub migrations: usize,
    pub policy: PlacementPolicy,
    pub gossip_interval: f64,
    pub epochs_run: usize,
    /// Deterministic metric snapshot of the run (empty unless
    /// [`ShardScenario::telemetry`] was set): per-shard slice counters
    /// and latency histograms plus coordinator-side control counters.
    /// A remote run assembles the identical registry from shipped
    /// [`crate::transport::TransportMsg::Telemetry`] snapshots.
    pub telemetry: Registry,
    /// Wall-clock coordinator phase timings, one entry per epoch run
    /// (empty unless [`ShardScenario::telemetry`] was set). Not part of
    /// any determinism or cross-mode parity contract.
    pub phase_timings: Vec<EpochPhases>,
    /// Deterministic planner work counters accumulated over every
    /// rebalance round: group digests read, groups descended, per-shard
    /// views examined, migrations planned. Identical between the
    /// in-process and remote runners for the same scenario (part of the
    /// cross-mode parity surface); `reads()` is the sub-linearity
    /// witness `benches/coordinator_scale.rs` pins.
    pub plan_stats: PlanStats,
    /// Every forecast-Σλ slot that rode a gossip digest, in publish
    /// order: `(epoch, shard, predicted Σλ)`. Empty unless
    /// [`ShardScenario::forecast`] is set (the slot is only published
    /// when the prediction's band is tight). Part of the deterministic
    /// cross-mode parity surface: the remote runner's digests must carry
    /// the identical sequence.
    pub forecast_trace: Vec<(usize, usize, f64)>,
}

impl ShardReport {
    /// Virtual time covered by the run.
    pub fn makespan(&self) -> f64 {
        self.epochs_run as f64 * self.gossip_interval
    }

    pub fn total_frames(&self) -> u64 {
        self.streams.iter().map(|s| s.frames_total).sum()
    }

    pub fn total_processed(&self) -> u64 {
        self.streams.iter().map(|s| s.frames_processed).sum()
    }

    /// Aggregate delivered detection throughput (FPS).
    pub fn delivered_fps(&self) -> f64 {
        let t = self.makespan();
        if t <= 0.0 {
            return 0.0;
        }
        self.total_processed() as f64 / t
    }

    pub fn drop_rate(&self) -> f64 {
        let total = self.total_frames();
        if total == 0 {
            return 0.0;
        }
        (total - self.total_processed()) as f64 / total as f64
    }

    /// Streams that were orphaned by a shard loss at any point.
    pub fn orphan_count(&self) -> usize {
        self.streams.iter().filter(|s| s.orphaned_for.is_some()).count()
    }

    /// Shard-local scale actions (device attach/detach and ladder-rung
    /// swaps) routed back to the coordinator — every
    /// [`ControlOrigin::Controller`] event in the control log.
    pub fn scale_actions(&self) -> usize {
        self.control_log
            .iter()
            .filter(|c| c.event.origin == ControlOrigin::Controller)
            .count()
    }

    /// Scale actions attributed to shard `sh`.
    pub fn scale_actions_for(&self, sh: usize) -> usize {
        self.control_log
            .iter()
            .filter(|c| c.shard == sh && c.event.origin == ControlOrigin::Controller)
            .count()
    }

    /// Worst per-stream p99 output latency across the run (seconds).
    pub fn worst_p99(&self) -> f64 {
        self.streams
            .iter()
            .map(|s| s.p99_latency)
            .fold(0.0, f64::max)
    }

    /// The coordinator's audit trail: every routed control event
    /// (placement verbs and shard-local scale actions alike) as a
    /// versioned [`EventLog`]. Shard attribution lives in
    /// [`ShardReport::control_log`]; the audit log is the
    /// coordinator-side, wire-clean view of the same sequence.
    ///
    /// "Replayable" here means the sequence itself survives
    /// encode→decode→[`EventLog::scripted_events`] verbatim (times,
    /// actions, order — pinned in `integration_shard`); a sharded log
    /// interleaves events addressed to different shards (and, for scale
    /// actions, device slots scoped to one epoch slice — see
    /// [`crate::shard::autoscale::ShardAutoscaler::run_slice`]), so it
    /// is an audit script, not a single-registry fleet scenario.
    pub fn audit_log(&self) -> EventLog {
        let mut log = EventLog::new();
        for c in &self.control_log {
            log.push(c.event.clone());
        }
        log
    }

    /// Worst orphan gap across streams (0 when nothing was orphaned).
    pub fn worst_orphan_gap(&self) -> f64 {
        self.streams
            .iter()
            .filter_map(|s| s.orphaned_for)
            .fold(0.0, f64::max)
    }

    /// Every orphaned stream was re-placed within `interval` seconds.
    pub fn orphans_replaced_within(&self, interval: f64) -> bool {
        self.streams
            .iter()
            .filter_map(|s| s.orphaned_for)
            .all(|gap| gap <= interval + 1e-9)
    }

    /// Imbalance of the initial placement: max − min committed Σλ.
    pub fn initial_imbalance(&self) -> f64 {
        let max = self.initial_committed.iter().copied().fold(f64::MIN, f64::max);
        let min = self.initial_committed.iter().copied().fold(f64::MAX, f64::min);
        if self.initial_committed.is_empty() {
            0.0
        } else {
            max - min
        }
    }

    /// Per-stream table.
    pub fn stream_table(&self) -> Table {
        let mut t = Table::new(
            "Per-stream results (sharded)",
            &[
                "stream", "λ (FPS)", "frames", "processed", "drop %", "migrations",
                "final shard", "p99 (s)", "orphaned (s)",
            ],
        );
        for s in &self.streams {
            t.row(vec![
                s.name.clone(),
                f(s.demand, 1),
                format!("{}", s.frames_total),
                format!("{}", s.frames_processed),
                f(s.drop_rate() * 100.0, 1),
                format!("{}", s.migrations),
                match s.final_shard {
                    Some(sh) => format!("{sh}"),
                    None => "-".to_string(),
                },
                f(s.p99_latency, 2),
                match s.orphaned_for {
                    Some(gap) if gap.is_finite() => f(gap, 1),
                    Some(_) => "never re-placed".to_string(),
                    None => "-".to_string(),
                },
            ]);
        }
        t
    }

    /// Per-shard table.
    pub fn shard_table(&self) -> Table {
        let mut t = Table::new(
            "Per-shard results",
            &["shard", "capacity (FPS)", "alive", "busy (s)", "frames", "utilisation %"],
        );
        for i in 0..self.shard_capacity.len() {
            t.row(vec![
                format!("{i}"),
                f(self.shard_capacity[i], 1),
                if self.shard_alive[i] { "yes" } else { "no" }.to_string(),
                f(self.shard_busy[i], 1),
                format!("{}", self.shard_frames[i]),
                f(self.utilization(i) * 100.0, 1),
            ]);
        }
        t
    }

    /// Mean pool utilisation of shard `sh` over the run (busy seconds
    /// per device-second; devices inferred from capacity at the nominal
    /// 2.5-FPS replica rate are *not* assumed — this is busy seconds
    /// normalised by makespan only, summed across the pool).
    pub fn utilization(&self, sh: usize) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.shard_busy[sh] / span
    }

    /// Machine-readable summary (the `eva shard --json` surface).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert(
            "policy".to_string(),
            Json::Str(self.policy.label().to_string()),
        );
        root.insert(
            "gossip_interval".to_string(),
            Json::Num(self.gossip_interval),
        );
        root.insert("epochs_run".to_string(), Json::Num(self.epochs_run as f64));
        root.insert("makespan".to_string(), Json::Num(self.makespan()));
        root.insert(
            "delivered_fps".to_string(),
            Json::Num(self.delivered_fps()),
        );
        root.insert("drop_rate".to_string(), Json::Num(self.drop_rate()));
        root.insert(
            "migrations".to_string(),
            Json::Num(self.migrations as f64),
        );
        root.insert(
            "scale_actions".to_string(),
            Json::Num(self.scale_actions() as f64),
        );
        root.insert(
            "frames_total".to_string(),
            Json::Num(self.total_frames() as f64),
        );
        root.insert(
            "frames_processed".to_string(),
            Json::Num(self.total_processed() as f64),
        );
        let shards: Vec<Json> = (0..self.shard_capacity.len())
            .map(|i| {
                let mut o = BTreeMap::new();
                o.insert("shard".to_string(), Json::Num(i as f64));
                o.insert("capacity".to_string(), Json::Num(self.shard_capacity[i]));
                o.insert("alive".to_string(), Json::Bool(self.shard_alive[i]));
                o.insert("busy_seconds".to_string(), Json::Num(self.shard_busy[i]));
                o.insert("frames".to_string(), Json::Num(self.shard_frames[i] as f64));
                o.insert(
                    "initial_committed".to_string(),
                    Json::Num(self.initial_committed[i]),
                );
                Json::Obj(o)
            })
            .collect();
        root.insert("shards".to_string(), Json::Arr(shards));
        let streams: Vec<Json> = self
            .streams
            .iter()
            .map(|s| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(s.name.clone()));
                o.insert("demand".to_string(), Json::Num(s.demand));
                o.insert("frames_total".to_string(), Json::Num(s.frames_total as f64));
                o.insert(
                    "frames_processed".to_string(),
                    Json::Num(s.frames_processed as f64),
                );
                o.insert("drop_rate".to_string(), Json::Num(s.drop_rate()));
                o.insert("migrations".to_string(), Json::Num(s.migrations as f64));
                o.insert(
                    "final_shard".to_string(),
                    match s.final_shard {
                        Some(sh) => Json::Num(sh as f64),
                        None => Json::Null,
                    },
                );
                o.insert("p99_latency".to_string(), Json::Num(s.p99_latency));
                // One stable type per key: `orphaned_for` is a number
                // (seconds) or null; the still-unplaced-at-end case is a
                // separate boolean rather than a string sentinel.
                o.insert(
                    "orphaned_for".to_string(),
                    match s.orphaned_for {
                        Some(gap) if gap.is_finite() => Json::Num(gap),
                        _ => Json::Null,
                    },
                );
                o.insert(
                    "never_replaced".to_string(),
                    Json::Bool(matches!(s.orphaned_for, Some(gap) if gap.is_infinite())),
                );
                Json::Obj(o)
            })
            .collect();
        root.insert("streams".to_string(), Json::Arr(streams));
        let mut plan = BTreeMap::new();
        plan.insert(
            "groups_total".to_string(),
            Json::Num(self.plan_stats.groups_total as f64),
        );
        plan.insert(
            "groups_descended".to_string(),
            Json::Num(self.plan_stats.groups_descended as f64),
        );
        plan.insert(
            "shards_examined".to_string(),
            Json::Num(self.plan_stats.shards_examined as f64),
        );
        plan.insert(
            "reads".to_string(),
            Json::Num(self.plan_stats.reads() as f64),
        );
        root.insert("plan_stats".to_string(), Json::Obj(plan));
        if !self.forecast_trace.is_empty() {
            root.insert(
                "forecast_trace".to_string(),
                Json::Arr(
                    self.forecast_trace
                        .iter()
                        .map(|&(epoch, shard, rate)| {
                            let mut o = BTreeMap::new();
                            o.insert("epoch".to_string(), Json::Num(epoch as f64));
                            o.insert("shard".to_string(), Json::Num(shard as f64));
                            o.insert("rate".to_string(), Json::Num(rate));
                            Json::Obj(o)
                        })
                        .collect(),
                ),
            );
        }
        root.insert(
            "control_log".to_string(),
            Json::Arr(
                self.control_log
                    .iter()
                    .map(|c| {
                        let mut o = BTreeMap::new();
                        o.insert("shard".to_string(), Json::Num(c.shard as f64));
                        o.insert("event".to_string(), c.event.to_json());
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }
}

/// Live per-stream bookkeeping inside the runner.
struct StreamRun {
    spec: StreamSpec,
    next_frame: u64,
    frames_total: u64,
    frames_processed: u64,
    latency: Percentiles,
    shard: Option<usize>,
    migrations: usize,
    /// Fractional arrivals carried across epochs: a stream offering
    /// fps × tick < 1 frames per epoch arrives at its true long-run
    /// rate instead of being rounded up to one frame per epoch.
    arrival_credit: f64,
    /// Time the stream lost its shard (pending re-placement).
    orphaned_at: Option<f64>,
    /// Worst re-placement gap seen so far.
    worst_gap: f64,
    ever_orphaned: bool,
    /// Frames still carrying the handover toll: after a migration or
    /// re-placement (scenario `handover` mode), the stream's first
    /// window of frames lands `handover_lag` late.
    carried_backlog: u64,
    handover_lag: f64,
}

impl StreamRun {
    fn remaining(&self) -> u64 {
        self.spec.num_frames.saturating_sub(self.next_frame)
    }

    fn active(&self) -> bool {
        self.remaining() > 0
    }
}

/// Push one event through the chosen wire codec: encode, decode, return
/// the decoded event — the hop every routed control event crosses. The
/// codecs are exact-parity (property-tested in [`crate::control::binary`]),
/// so the decoded event is identical either way.
pub(crate) fn wire_hop(event: &WireEvent, codec: Codec) -> WireEvent {
    match codec {
        Codec::Json => {
            WireEvent::decode(&event.encode()).expect("control wire must round-trip")
        }
        Codec::Binary => binary::decode_event(&binary::encode_event(event))
            .expect("control wire must round-trip"),
    }
}

/// Route one control action to `shard` **through the wire**: encode in
/// the scenario's codec, decode, apply the decoded action to the
/// residency map, log it.
#[allow(clippy::too_many_arguments)]
fn route(
    log: &mut Vec<ShardControl>,
    streams: &mut [StreamRun],
    codec: Codec,
    shard: usize,
    at: f64,
    origin: ControlOrigin,
    action: ControlAction,
) {
    let decoded = wire_hop(&WireEvent::action(at, origin, action), codec);
    match decoded.as_action() {
        Some(ControlAction::AttachStream(spec)) => {
            if let Some(i) = streams.iter().position(|s| s.spec.name == spec.name) {
                streams[i].shard = Some(shard);
            }
        }
        Some(ControlAction::DetachStream(idx)) => {
            if let Some(s) = streams.get_mut(*idx) {
                if s.shard == Some(shard) {
                    s.shard = None;
                }
            }
        }
        _ => {}
    }
    log.push(ShardControl {
        shard,
        event: decoded,
    });
}

/// Run the sharded scenario to completion (or `epochs`).
pub fn run_sharded(scenario: &ShardScenario) -> ShardReport {
    let m = scenario.shards.len();
    assert!(m > 0, "need at least one shard");
    let tick = scenario.gossip_interval.max(1e-3);
    let util = scenario.admission.target_utilization;
    // Reported capacity is the *initial* util-adjusted pool rate (the
    // pre-scale baseline); an autoscaling shard's growth shows up in the
    // control log and the digests, not here.
    let capacity: Vec<f64> = scenario
        .shards
        .iter()
        .map(|devs| devs.iter().map(|d| d.rate()).sum::<f64>() * util)
        .collect();
    // Live pools: autoscaling shards grow/shrink theirs between epochs.
    let mut pools: Vec<Vec<DeviceInstance>> = scenario.shards.clone();
    let mut scalers: Vec<Option<ShardAutoscaler>> = (0..m)
        .map(|_| {
            scenario.autoscale.clone().map(|cfg| {
                let mut scaler = ShardAutoscaler::new(cfg);
                scaler.set_gate(scenario.gate.clone());
                scaler
            })
        })
        .collect();

    // Per-shard forecast state, driven at exactly the points of the
    // epoch loop the remote shard server drives its own copy, so
    // forecast-carrying digests are bit-identical across transports.
    let mut forecasters: Vec<Option<ShardForecast>> = (0..m)
        .map(|_| scenario.forecast.clone().map(ShardForecast::new))
        .collect();
    // Autoscaler state snapshotted at a scheduled failure, restored on
    // rejoin: a restarted shard resumes its scaled pool and cooldown
    // clock instead of replaying the whole ramp (warm rejoin — the
    // remote runner carries the same snapshot across listener sessions).
    let mut saved_scalers: Vec<Option<ScalerState>> = vec![None; m];

    let mut alive = vec![true; m];
    let mut shard_busy = vec![0.0f64; m];
    let mut shard_frames = vec![0u64; m];
    let mut streams: Vec<StreamRun> = scenario
        .streams
        .iter()
        .map(|spec| StreamRun {
            spec: spec.clone(),
            next_frame: 0,
            frames_total: 0,
            frames_processed: 0,
            latency: Percentiles::new(),
            shard: None,
            migrations: 0,
            arrival_credit: 0.0,
            orphaned_at: None,
            worst_gap: 0.0,
            ever_orphaned: false,
            carried_backlog: 0,
            handover_lag: 0.0,
        })
        .collect();
    let mut log: Vec<ShardControl> = Vec::new();
    let mut table = GossipTable::new(m);
    let mut migrations = 0usize;
    let mut initial_committed = vec![0.0f64; m];
    let mut epochs_run = 0usize;
    let mut telemetry = Registry::new();
    let mut phase_timings: Vec<EpochPhases> = Vec::new();
    let mut plan_stats = PlanStats::default();
    let mut forecast_trace: Vec<(usize, usize, f64)> = Vec::new();

    for epoch in 0..scenario.epochs {
        let t0 = epoch as f64 * tick;
        let epoch_clock = scenario.telemetry.then(std::time::Instant::now);

        // 0. Scheduled rejoins, ahead of the gossip round: the shard
        //    comes back — publishes a digest this very epoch, and the
        //    rebalance pass below re-levels onto it. An autoscaling
        //    shard rejoins *warm*: the pool, cooldown clock and replica
        //    numbering snapshotted at its failure are restored, so it
        //    re-enters at the capacity it had already learned instead
        //    of replaying the attach ramp from the seed pool. Forecast
        //    state restarts cold either way (arrivals were not observed
        //    while down). Mirrors the remote runner's
        //    redial-and-rehandshake term for term.
        for &(re, sh) in &scenario.rejoins {
            if re != epoch || sh >= m || alive[sh] {
                continue;
            }
            alive[sh] = true;
            pools[sh] = scenario.shards[sh].clone();
            scalers[sh] = scenario.autoscale.clone().map(|cfg| {
                let mut scaler = ShardAutoscaler::new(cfg);
                scaler.set_gate(scenario.gate.clone());
                scaler
            });
            if let (Some(scaler), Some(state)) = (scalers[sh].as_mut(), saved_scalers[sh].take())
            {
                pools[sh] = scaler.restore_state(&state);
            }
            forecasters[sh] = scenario.forecast.clone().map(ShardForecast::new);
        }

        // 1. Gossip round: alive shards publish, stale digests expire.
        for sh in 0..m {
            if !alive[sh] {
                continue;
            }
            // Offered load at the epoch base: `demand_at` follows a
            // stream's rate profile (equal to the flat demand for
            // unprofiled streams, so pre-profile digests are unchanged).
            let committed: f64 = streams
                .iter()
                .filter(|s| s.shard == Some(sh) && s.active())
                .map(|s| s.spec.demand_at(t0))
                .sum();
            // An autoscaling shard advertises post-scale headroom: what
            // it can reach locally, so the planner migrates only once
            // local scaling is exhausted.
            let advertised = match &scalers[sh] {
                Some(s) => s.projected_capacity(&pools[sh], util),
                None => capacity[sh],
            };
            // The forecast slot: predicted Σλ, published only when the
            // band is tight (consumers use it unconditionally).
            let forecast = forecasters[sh].as_ref().and_then(|f| f.digest_rate());
            if let Some(rate) = forecast {
                forecast_trace.push((epoch, sh, rate));
            }
            table.publish(Headroom {
                shard: sh,
                at: t0,
                capacity: advertised,
                committed,
                forecast,
            });
        }
        table.sweep(t0, 0.5 * tick);
        let mut views: Vec<ShardView> = table.views();
        let after_gossip = scenario.telemetry.then(std::time::Instant::now);

        // 2. Place unplaced streams (initial placement + orphans from a
        //    lost shard) against the fresh views, updating committed as
        //    we go so multiple placements spread out.
        for i in 0..streams.len() {
            if streams[i].shard.is_some() || !streams[i].active() {
                continue;
            }
            let name = streams[i].spec.name.clone();
            let Some(dst) = scenario.policy.place(&name, i, &views) else {
                continue;
            };
            let attach = ControlAction::AttachStream(streams[i].spec.clone());
            route(
                &mut log,
                &mut streams,
                scenario.codec,
                dst,
                t0,
                ControlOrigin::Placement,
                attach,
            );
            views[dst].committed += streams[i].spec.demand_at(t0);
            if let Some(lost_at) = streams[i].orphaned_at.take() {
                let gap = (t0 - lost_at).max(0.0);
                if gap > streams[i].worst_gap {
                    streams[i].worst_gap = gap;
                }
                if scenario.handover {
                    // A re-placed orphan re-buffers on its new shard:
                    // its first window of frames carries the outage gap
                    // plus the window refill time.
                    let s = &mut streams[i];
                    s.carried_backlog = s.spec.window as u64;
                    s.handover_lag = gap + s.spec.window as f64 / s.spec.fps.max(1e-9);
                }
            }
        }

        if epoch == 0 {
            for s in streams.iter() {
                if let Some(sh) = s.shard {
                    if s.active() {
                        initial_committed[sh] += s.spec.demand();
                    }
                }
            }
        }

        // 3. Band rebalance: serialised detach→attach migrations. The
        //    first rebalance runs one interval after placement — the
        //    gossip exchange is reactive, placement is admission-time.
        if epoch > 0 {
            let residents: Vec<(usize, f64, usize)> = streams
                .iter()
                .enumerate()
                .filter_map(|(i, s)| {
                    if s.active() {
                        s.shard.map(|sh| (i, s.spec.demand_at(t0), sh))
                    } else {
                        None
                    }
                })
                .collect();
            let (moves, stats) = plan(&views, &residents, scenario.groups);
            plan_stats.absorb(&stats);
            for mv in moves {
                route(
                    &mut log,
                    &mut streams,
                    scenario.codec,
                    mv.from,
                    t0,
                    ControlOrigin::Placement,
                    ControlAction::DetachStream(mv.stream),
                );
                let attach = ControlAction::AttachStream(streams[mv.stream].spec.clone());
                route(
                    &mut log,
                    &mut streams,
                    scenario.codec,
                    mv.to,
                    t0,
                    ControlOrigin::Placement,
                    attach,
                );
                streams[mv.stream].migrations += 1;
                migrations += 1;
                if scenario.handover {
                    // Planned detach→attach: window backlog and
                    // synchronizer state rebuild on the target, so the
                    // first post-move window lands a refill time late.
                    let s = &mut streams[mv.stream];
                    s.carried_backlog = s.spec.window as u64;
                    s.handover_lag = s.spec.window as f64 / s.spec.fps.max(1e-9);
                }
            }
        }

        // 4. Scheduled shard failures: the shard dies right after the
        //    round it last attended; its residents wait for the next
        //    gossip round — at most one interval — to be re-placed.
        for &(e, sh) in &scenario.failures {
            if e == epoch && sh < m && alive[sh] {
                alive[sh] = false;
                // Snapshot the autoscaler for a warm rejoin: the state
                // it had after the last slice it served.
                saved_scalers[sh] = scalers[sh]
                    .as_ref()
                    .map(|s| s.export_state(&pools[sh]));
                for s in streams.iter_mut() {
                    if s.shard == Some(sh) {
                        s.shard = None;
                        s.orphaned_at = Some(t0);
                        s.ever_orphaned = true;
                    }
                }
            }
        }

        // Residency settled for the epoch: drop forecast state for
        // streams that migrated away or played out (a moved stream
        // re-learns on its new shard — the remote shard server applies
        // the same retain rule against its decoded resident set, at its
        // tick and poll boundaries).
        for sh in 0..m {
            if let Some(fc) = forecasters[sh].as_mut() {
                fc.retain_streams(|id| {
                    streams
                        .get(id)
                        .is_some_and(|s| s.shard == Some(sh) && s.active())
                });
            }
        }

        let after_plan = scenario.telemetry.then(std::time::Instant::now);

        // 5. Serve the epoch: each alive shard runs its residents' slice
        //    through the virtual-time fleet engine; unplaced streams'
        //    arrivals drop on the floor. Epoch quotas carry fractional
        //    arrival credit so sub-epoch-rate streams (fps × tick < 1)
        //    still arrive at their true long-run rate. A rate profile is
        //    sampled at the epoch base (piecewise-constant over the
        //    epoch): `rate_at` equals `fps` for flat streams.
        let mut quotas: Vec<u64> = vec![0; streams.len()];
        for (i, s) in streams.iter_mut().enumerate() {
            if !s.active() {
                continue;
            }
            s.arrival_credit += s.spec.rate_at(t0) * tick;
            let q = (s.arrival_credit.floor().max(0.0) as u64).min(s.remaining());
            s.arrival_credit -= q as f64;
            quotas[i] = q;
        }
        for sh in 0..m {
            if !alive[sh] {
                continue;
            }
            let mut specs: Vec<StreamSpec> = Vec::new();
            let mut idx_map: Vec<usize> = Vec::new();
            for (i, s) in streams.iter().enumerate() {
                if s.shard != Some(sh) || !s.active() || quotas[i] == 0 {
                    continue;
                }
                let mut spec = s.spec.clone();
                spec.num_frames = quotas[i];
                // The slice serves this epoch's quota at the profiled
                // instantaneous rate, so a ramp phase arrives as a
                // genuinely faster process (unchanged for flat streams).
                spec.fps = s.spec.rate_at(t0);
                specs.push(spec);
                idx_map.push(i);
            }
            if specs.is_empty() {
                continue;
            }
            // Forecast fusion at the serve boundary: arm the admission
            // burst-hold when a tight prediction says the current
            // overload clears, and hand the autoscaler the predicted
            // Σλ as its demand hint. Both are no-ops when forecasting
            // is off or the band is loose.
            let mut admission = scenario.admission.clone();
            if let Some(fc) = forecasters[sh].as_ref() {
                let offered: f64 = idx_map
                    .iter()
                    .map(|&i| streams[i].spec.demand_at(t0))
                    .sum();
                let cap_now = pools[sh].iter().map(|d| d.rate()).sum::<f64>() * util;
                admission.hold = should_hold(fc.cfg(), offered, cap_now, fc.predict().as_ref());
                if let Some(scaler) = scalers[sh].as_mut() {
                    scaler.set_forecast_demand(fc.digest_rate());
                }
            }
            let slice_seed = scenario
                .seed
                .wrapping_add((epoch as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                ^ ((sh as u64) << 17);
            let report = match scalers[sh].as_mut() {
                Some(scaler) => {
                    // Closed-loop slice: the shard's controller observes
                    // and acts inside the epoch; its device actions
                    // persist in the pool and its scale actions join the
                    // control log — through the same encode→decode hop
                    // every placement verb takes.
                    let (report, scale_events) = scaler.run_slice(
                        &mut pools[sh],
                        &admission,
                        specs,
                        &idx_map,
                        t0,
                        slice_seed,
                    );
                    for event in scale_events {
                        let decoded = wire_hop(&event, scenario.codec);
                        log.push(ShardControl { shard: sh, event: decoded });
                    }
                    report
                }
                None => {
                    let mut sub = Scenario::new(pools[sh].clone(), specs)
                        .with_admission(admission.clone())
                        .with_seed(slice_seed);
                    if let Some(gate) = &scenario.gate {
                        sub = sub.with_gate(gate.clone());
                    }
                    let out = run_fleet_with(&sub, None);
                    // Gate verdicts join the control log in shard time
                    // with global stream ids, through the same wire hop
                    // every routed event takes.
                    for ev in &out.gate_log {
                        if let crate::control::WirePayload::Gate { stream, frame, verdict } =
                            ev.payload
                        {
                            let Some(&global) = idx_map.get(stream) else { continue };
                            let event = WireEvent::gate(t0 + ev.at, global, frame, verdict);
                            let decoded = wire_hop(&event, scenario.codec);
                            log.push(ShardControl { shard: sh, event: decoded });
                        }
                    }
                    out.report
                }
            };
            for (k, &i) in idx_map.iter().enumerate() {
                let sr = &report.streams[k];
                streams[i].frames_total += sr.metrics.frames_total;
                streams[i].frames_processed += sr.metrics.frames_processed;
                streams[i].next_frame += sr.metrics.frames_total;
                for rec in &sr.records {
                    let lat = (rec.emit_ts - rec.capture_ts).max(0.0);
                    // Handover toll: the first carried-backlog frames
                    // after a migration or re-placement land late by
                    // the rebuild time. Report-side only — telemetry
                    // below lowers the raw slice, exactly as a remote
                    // shard (which cannot know coordinator history)
                    // records it.
                    if streams[i].carried_backlog > 0 {
                        streams[i].carried_backlog -= 1;
                        streams[i].latency.push(lat + streams[i].handover_lag);
                    } else {
                        streams[i].latency.push(lat);
                    }
                }
            }
            // Feed the forecaster the slice's realised arrival rates
            // (granted quota over the tick) — learned from what was
            // served, never peeked from the declared profile. The
            // divisor takes the exact FP round-trip the remote shard
            // server takes when it recovers the interval from its next
            // poll (`at / epoch` with `at = epoch·tick`), so learned
            // rates — and therefore forecast digests — stay
            // bit-identical across transports.
            if let Some(fc) = forecasters[sh].as_mut() {
                let next = (epoch + 1) as f64;
                let flush_tick = next * tick / next;
                for (k, &i) in idx_map.iter().enumerate() {
                    fc.observe(i, report.streams[k].metrics.frames_total as f64 / flush_tick);
                }
            }
            let slice_busy = report.device_busy.iter().sum::<f64>();
            let slice_frames = report.device_frames.iter().sum::<u64>();
            shard_busy[sh] += slice_busy;
            shard_frames[sh] += slice_frames;
            if scenario.telemetry {
                // Lower the slice through the same shape a remote shard
                // ships in its `Slice`, so both modes build the same
                // snapshot (pinned in `integration_transport`).
                let slice: Vec<(u64, u64, Vec<f64>)> = report
                    .streams
                    .iter()
                    .map(|sr| {
                        (
                            sr.metrics.frames_total,
                            sr.metrics.frames_processed,
                            sr.records
                                .iter()
                                .map(|r| (r.emit_ts - r.capture_ts).max(0.0))
                                .collect(),
                        )
                    })
                    .collect();
                record_slice_telemetry(
                    &mut telemetry,
                    sh,
                    slice_busy,
                    slice_frames,
                    slice.iter().map(|(t, p, l)| (*t, *p, l.as_slice())),
                );
            }
        }
        for (i, s) in streams.iter_mut().enumerate() {
            if s.shard.is_none() && s.active() && quotas[i] > 0 {
                s.frames_total += quotas[i];
                s.next_frame += quotas[i];
            }
        }

        epochs_run = epoch + 1;
        if let (Some(t_start), Some(t_gossip), Some(t_plan)) =
            (epoch_clock, after_gossip, after_plan)
        {
            phase_timings.push(EpochPhases {
                epoch,
                gossip: (t_gossip - t_start).as_secs_f64(),
                plan: (t_plan - t_gossip).as_secs_f64(),
                serve: t_plan.elapsed().as_secs_f64(),
            });
        }
        if streams.iter().all(|s| !s.active()) {
            break;
        }
    }

    if scenario.telemetry {
        record_coordinator_telemetry(&mut telemetry, epochs_run, migrations, &log);
    }

    let stream_reports: Vec<ShardStreamReport> = streams
        .iter()
        .map(|s| ShardStreamReport {
            name: s.spec.name.clone(),
            demand: s.spec.demand(),
            frames_total: s.frames_total,
            frames_processed: s.frames_processed,
            migrations: s.migrations,
            final_shard: s.shard,
            p99_latency: s.latency.p99(),
            orphaned_for: if s.orphaned_at.is_some() {
                Some(f64::INFINITY)
            } else if s.ever_orphaned {
                Some(s.worst_gap)
            } else {
                None
            },
        })
        .collect();

    ShardReport {
        streams: stream_reports,
        shard_capacity: capacity,
        shard_alive: alive,
        shard_busy,
        shard_frames,
        initial_committed,
        control_log: log,
        migrations,
        policy: scenario.policy,
        gossip_interval: tick,
        epochs_run,
        telemetry,
        phase_timings,
        plan_stats,
        forecast_trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};

    fn pool(n: usize, rate: f64) -> Vec<DeviceInstance> {
        (0..n)
            .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
            .collect()
    }

    fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
        (0..n)
            .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
            .collect()
    }

    #[test]
    fn least_loaded_split_balances_and_serves_everything() {
        // Mixed demands [3, 2, 2, 3] over 2 shards × 3 devices (capacity
        // 7.125 each): least-loaded lands 6 / 4 FPS, both shards stay in
        // band, nothing migrates, and every stream is served near-fully.
        let streams: Vec<StreamSpec> = [3.0, 2.0, 2.0, 3.0]
            .iter()
            .enumerate()
            .map(|(i, &fps)| {
                StreamSpec::new(&format!("s{i}"), fps, (fps * 40.0) as u64).with_window(4)
            })
            .collect();
        let scenario = ShardScenario::builder(vec![pool(3, 2.5), pool(3, 2.5)], streams)
            .gossip(10.0)
            .epochs(8)
            .seed(3)
            .build();
        let report = run_sharded(&scenario);
        assert_eq!(report.migrations, 0);
        assert_eq!(report.orphan_count(), 0);
        assert!((report.initial_committed[0] - 6.0).abs() < 1e-9, "{:?}", report.initial_committed);
        assert!((report.initial_committed[1] - 4.0).abs() < 1e-9);
        for s in &report.streams {
            assert_eq!(s.frames_total, (s.demand * 40.0) as u64, "stream {}", s.name);
            assert!(
                s.frames_processed as f64 > 0.9 * s.frames_total as f64,
                "stream {} processed {}/{}",
                s.name,
                s.frames_processed,
                s.frames_total
            );
        }
        // Every placement crossed the wire: one attach event per stream.
        let attaches = report
            .control_log
            .iter()
            .filter(|c| {
                matches!(
                    c.event.as_action(),
                    Some(ControlAction::AttachStream(_))
                )
            })
            .count();
        assert_eq!(attaches, 4);
    }

    #[test]
    fn overloaded_shard_sheds_streams_via_migration() {
        // Round-robin parks both heavy streams wherever the index falls;
        // with demands [6, 2, 6, 2] over 2 shards (capacity 14.25 each),
        // RR puts 12 on shard 0 and 4 on shard 1 — in band, no moves.
        // Force imbalance: demands [9, 1, 9, 1] → shard 0 carries 18.
        let mut streams = Vec::new();
        for (i, fps) in [9.0, 1.0, 9.0, 1.0].iter().enumerate() {
            streams.push(StreamSpec::new(&format!("s{i}"), *fps, (*fps * 60.0) as u64).with_window(4));
        }
        let scenario = ShardScenario::builder(vec![pool(6, 2.5), pool(6, 2.5)], streams)
            .policy(PlacementPolicy::RoundRobin)
            .gossip(10.0)
            .epochs(8)
            .seed(5)
            .build();
        let report = run_sharded(&scenario);
        // RR initial split: shard 0 gets s0+s2 (18 > 14.25), shard 1 gets
        // s1+s3 (2).
        assert!((report.initial_imbalance() - 16.0).abs() < 1e-9, "{:?}", report.initial_committed);
        // One 9-FPS stream migrates (18 → 9 ≤ 14.25; target 2 + 9 ≤ 14.25).
        assert_eq!(report.migrations, 1, "control log: {:?}", report.control_log.len());
        let migrated: Vec<&ShardStreamReport> =
            report.streams.iter().filter(|s| s.migrations > 0).collect();
        assert_eq!(migrated.len(), 1);
        assert_eq!(migrated[0].demand, 9.0);
    }

    #[test]
    fn shard_loss_orphans_are_replaced_within_one_gossip_interval() {
        // 3 shards × 3 streams; shard 0 dies at epoch 2. Its 3 streams
        // must be back on surviving shards by the next gossip round.
        let scenario = ShardScenario::builder(
            vec![pool(4, 2.5), pool(4, 2.5), pool(4, 2.5)],
            uniform_streams(9, 2.5, 200, 4),
        )
        .gossip(10.0)
        .epochs(10)
        .seed(7)
        .failure(2, 0)
        .build();
        let report = run_sharded(&scenario);
        assert!(!report.shard_alive[0]);
        assert_eq!(report.orphan_count(), 3);
        assert!(
            report.orphans_replaced_within(report.gossip_interval),
            "worst gap {} vs interval {}",
            report.worst_orphan_gap(),
            report.gossip_interval
        );
        // Orphans end up resident on a survivor and keep processing.
        for s in report.streams.iter().filter(|s| s.orphaned_for.is_some()) {
            assert!(matches!(s.final_shard, Some(1) | Some(2)), "{:?}", s.final_shard);
            assert!(s.frames_processed > 0);
        }
    }

    #[test]
    fn restarted_shard_rejoins_gossip_and_takes_load_back() {
        // Rolling restart of shard 0: die at epoch 2, rejoin at epoch 4.
        // The rejoined shard attends the epoch-4 gossip round as a fresh
        // instance, and the band rebalancer re-levels streams onto it
        // (the survivor is far over band with all six residents).
        let scenario = ShardScenario::builder(
            vec![pool(3, 2.5), pool(3, 2.5)],
            uniform_streams(6, 2.5, 300, 4),
        )
        .gossip(10.0)
        .epochs(14)
        .seed(29)
        .restart(0, 2, 4)
        .build();
        let report = run_sharded(&scenario);
        assert!(report.shard_alive[0], "restarted shard must finish alive");
        assert!(report.orphan_count() > 0, "the failure must orphan streams");
        assert!(
            report.streams.iter().all(|s| s.orphaned_for != Some(f64::INFINITY)),
            "every orphan must be re-placed"
        );
        assert!(
            report.streams.iter().any(|s| s.final_shard == Some(0)),
            "planner must re-level onto the rejoined shard"
        );
        assert!(report.migrations > 0, "re-levelling takes migrations");
        for s in &report.streams {
            assert_eq!(s.frames_total, 300, "stream {}", s.name);
        }
        // A rejoin scheduled for a shard that never died is a no-op.
        let noop = ShardScenario::builder(
            vec![pool(3, 2.5), pool(3, 2.5)],
            uniform_streams(4, 2.5, 100, 4),
        )
        .gossip(10.0)
        .epochs(6)
        .seed(29)
        .rejoin(3, 1)
        .build();
        let clean = run_sharded(&noop);
        assert_eq!(clean.orphan_count(), 0);
        assert!(clean.shard_alive.iter().all(|&a| a));
    }

    #[test]
    fn handover_toll_prices_migrations_without_changing_frame_accounting() {
        // Same restart scenario with and without the handover toll: the
        // frame counts are identical (the toll prices latency, never
        // throughput), but some migrated or re-placed stream's p99 gets
        // strictly worse once its first post-move window pays the
        // rebuild time.
        let mk = || {
            ShardScenario::builder(
                vec![pool(3, 2.5), pool(3, 2.5)],
                uniform_streams(6, 2.5, 300, 4),
            )
            .gossip(10.0)
            .epochs(14)
            .seed(29)
            .restart(0, 2, 4)
        };
        let free = run_sharded(&mk().build());
        let tolled = run_sharded(&mk().handover().build());
        assert_eq!(tolled.total_frames(), free.total_frames());
        assert_eq!(tolled.total_processed(), free.total_processed());
        assert_eq!(tolled.control_log, free.control_log);
        let mut strictly_worse = 0;
        for (t, f) in tolled.streams.iter().zip(&free.streams) {
            assert!(t.p99_latency >= f.p99_latency - 1e-9, "stream {}", t.name);
            if t.p99_latency > f.p99_latency + 1e-9 {
                strictly_worse += 1;
            }
        }
        assert!(strictly_worse > 0, "the toll must show up in some p99");
    }

    #[test]
    fn autoscaling_shard_absorbs_overload_without_migration() {
        // Round-robin parks 12 FPS on shard 0 (initial capacity 9.5).
        // Migrate-only restores the band by shedding a 6-FPS stream;
        // with shard-local autoscale the digest advertises post-scale
        // headroom (projected 19 ≥ committed 12), the planner stays put,
        // and the controller attaches replicas locally instead.
        let mk_streams = || -> Vec<StreamSpec> {
            [6.0, 1.0, 6.0, 1.0]
                .iter()
                .enumerate()
                .map(|(i, &fps)| {
                    StreamSpec::new(&format!("s{i}"), fps, (fps * 40.0) as u64).with_window(4)
                })
                .collect()
        };
        let base = ShardScenario::builder(vec![pool(4, 2.5), pool(4, 2.5)], mk_streams())
            .policy(PlacementPolicy::RoundRobin)
            .gossip(10.0)
            .epochs(8)
            .seed(31);
        let migrate_only = run_sharded(&base.clone().build());
        assert!(migrate_only.migrations >= 1, "{}", migrate_only.migrations);
        assert_eq!(migrate_only.scale_actions(), 0);

        let cfg = AutoscaleConfig {
            max_devices: 8,
            ..AutoscaleConfig::default()
        };
        let scaled = run_sharded(&base.clone().autoscale(cfg).build());
        assert_eq!(
            scaled.migrations, 0,
            "local scaling must pre-empt migration: {:?}",
            scaled.control_log.len()
        );
        assert!(scaled.scale_actions() >= 1, "expected local scale actions");
        // Scale actions are attributed to the overloaded shard and are
        // wire-clean: the audit log survives another encode→decode hop.
        assert!(scaled.scale_actions_for(0) >= 1);
        let audit = scaled.audit_log();
        let decoded = EventLog::decode(&audit.encode()).expect("audit log decodes");
        assert_eq!(decoded, audit);
        // Deterministic given the seed (the wire path must not wobble).
        let again = run_sharded(
            &base
                .autoscale(AutoscaleConfig {
                    max_devices: 8,
                    ..AutoscaleConfig::default()
                })
                .build(),
        );
        assert_eq!(again.control_log, scaled.control_log);
        assert_eq!(again.total_processed(), scaled.total_processed());
    }

    #[test]
    fn deterministic_given_seed() {
        let scenario = ShardScenario::builder(
            vec![pool(2, 2.5), pool(2, 2.5)],
            uniform_streams(4, 5.0, 100, 4),
        )
        .gossip(5.0)
        .epochs(8)
        .seed(11)
        .build();
        let a = run_sharded(&scenario);
        let b = run_sharded(&scenario);
        assert_eq!(a.total_processed(), b.total_processed());
        assert_eq!(a.control_log, b.control_log);
    }

    #[test]
    fn gated_shard_run_logs_verdicts_and_replays_verbatim() {
        use crate::control::ControlOrigin;
        use crate::gate::GateConfig;
        // Quiet streams under the default (lobby-dynamics) gate: most
        // frames skip, and every verdict crosses the wire into the
        // coordinator's control log with [`ControlOrigin::Gate`].
        let base = ShardScenario::builder(
            vec![pool(4, 2.5), pool(4, 2.5)],
            uniform_streams(4, 5.0, 100, 4),
        )
        .gossip(10.0)
        .epochs(6)
        .seed(17);
        let plain = run_sharded(&base.clone().build());
        let gated = run_sharded(&base.clone().gate(GateConfig::default()).build());
        let gate_events = gated
            .control_log
            .iter()
            .filter(|c| c.event.origin == ControlOrigin::Gate)
            .count();
        assert!(gate_events > 50, "only {gate_events} gate events");
        assert!(
            gated.total_processed() < plain.total_processed(),
            "gating must shed work: {} vs {}",
            gated.total_processed(),
            plain.total_processed()
        );
        // Deterministic and wire-clean: the audit log (placement verbs
        // and gate verdicts interleaved) survives another round trip.
        let again = run_sharded(&base.gate(GateConfig::default()).build());
        assert_eq!(again.control_log, gated.control_log);
        let audit = gated.audit_log();
        assert_eq!(EventLog::decode(&audit.encode()).expect("decodes"), audit);
    }

    #[test]
    fn telemetry_snapshot_is_deterministic_and_accounts_every_slice() {
        let scenario = ShardScenario::builder(
            vec![pool(2, 2.5), pool(2, 2.5)],
            uniform_streams(4, 2.5, 50, 4),
        )
        .gossip(10.0)
        .epochs(6)
        .seed(13)
        .telemetry()
        .build();
        let a = run_sharded(&scenario);
        let b = run_sharded(&scenario);
        // The registry is part of the deterministic run outcome; only
        // the wall-clock phase timings may differ between runs.
        assert_eq!(a.telemetry, b.telemetry);
        assert_eq!(a.phase_timings.len(), a.epochs_run);
        assert!(a
            .phase_timings
            .iter()
            .all(|p| p.gossip >= 0.0 && p.plan >= 0.0 && p.serve >= 0.0));
        // Every frame arrived through a served slice (all four streams
        // place at epoch 0), so the counters reconcile with the report.
        let by_kind = |kind: &str| -> u64 {
            (0..2)
                .map(|sh| {
                    a.telemetry.counter(&MetricKey::with_labels(
                        "eva_shard_frames_total",
                        &[("shard", &format!("{sh}")), ("kind", kind)],
                    ))
                })
                .sum()
        };
        assert_eq!(by_kind("arrived"), a.total_frames());
        assert_eq!(by_kind("processed"), a.total_processed());
        assert_eq!(
            a.telemetry.counter(&MetricKey::new("eva_epochs_total")),
            a.epochs_run as u64
        );
        assert_eq!(
            a.telemetry
                .counter_family_total("eva_control_events_total"),
            a.control_log.len() as u64
        );
        // The same scenario without the flag carries no registry.
        let off = run_sharded(&ShardScenario {
            telemetry: false,
            ..scenario
        });
        assert_eq!(off.telemetry, Registry::new());
        assert!(off.phase_timings.is_empty());
    }

    #[test]
    fn report_json_reparses() {
        let scenario = ShardScenario::builder(
            vec![pool(2, 2.5), pool(2, 2.5)],
            uniform_streams(4, 2.5, 50, 4),
        )
        .gossip(10.0)
        .epochs(4)
        .seed(13)
        .build();
        let report = run_sharded(&scenario);
        let j = report.to_json();
        let back = Json::parse(&j.to_string()).expect("shard JSON must reparse");
        assert_eq!(
            back.get("policy").and_then(Json::as_str),
            Some("least-loaded")
        );
        assert_eq!(
            back.get("frames_total").and_then(Json::as_i64),
            Some(report.total_frames() as i64)
        );
        let shards = back.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        let streams = back.get("streams").unwrap().as_arr().unwrap();
        assert_eq!(streams.len(), 4);
        // Planner counters surface in the JSON (flat: every alive view
        // examined at each rebalance round).
        let plan = back.get("plan_stats").unwrap();
        assert_eq!(
            plan.get("reads").and_then(Json::as_i64),
            Some(report.plan_stats.reads() as i64)
        );
        // Tables render with one row per entity.
        assert_eq!(report.stream_table().rows.len(), 4);
        assert_eq!(report.shard_table().rows.len(), 2);
    }

    #[test]
    fn binary_codec_run_is_bit_identical_to_the_json_run() {
        // Same scenario, both wire codecs, with autoscale + gate so the
        // log carries every payload family: the run outcome and the
        // audit log must be exactly equal — the codec changes bytes on
        // the wire, never the decoded events.
        let base = ShardScenario::builder(
            vec![pool(4, 2.5), pool(4, 2.5)],
            uniform_streams(6, 3.0, 120, 4),
        )
        .policy(PlacementPolicy::RoundRobin)
        .gossip(10.0)
        .epochs(8)
        .seed(23)
        .autoscale(AutoscaleConfig::default())
        .gate(GateConfig::default());
        let json_run = run_sharded(&base.clone().build());
        let bin_run = run_sharded(&base.codec(Codec::Binary).build());
        assert_eq!(bin_run.control_log, json_run.control_log);
        assert_eq!(bin_run.total_processed(), json_run.total_processed());
        assert_eq!(bin_run.migrations, json_run.migrations);
        assert_eq!(bin_run.audit_log(), json_run.audit_log());
    }

    #[test]
    fn grouped_planning_spanning_the_fleet_matches_flat_exactly() {
        // One group covering every shard always descends, so grouped
        // planning degenerates to the flat planner: identical control
        // log and migrations, with the group overhead visible only in
        // the counters.
        let mk = || {
            let mut streams = Vec::new();
            for (i, fps) in [9.0, 1.0, 9.0, 1.0].iter().enumerate() {
                streams.push(
                    StreamSpec::new(&format!("s{i}"), *fps, (*fps * 60.0) as u64).with_window(4),
                );
            }
            ShardScenario::builder(vec![pool(6, 2.5), pool(6, 2.5)], streams)
                .policy(PlacementPolicy::RoundRobin)
                .gossip(10.0)
                .epochs(8)
                .seed(5)
        };
        let flat = run_sharded(&mk().build());
        let grouped = run_sharded(&mk().groups(2).build());
        assert_eq!(grouped.control_log, flat.control_log);
        assert_eq!(grouped.migrations, flat.migrations);
        assert_eq!(grouped.total_processed(), flat.total_processed());
        assert!(grouped.plan_stats.groups_total > 0);
        assert_eq!(
            grouped.plan_stats.shards_examined,
            flat.plan_stats.shards_examined
        );
    }

    #[test]
    fn in_band_fleet_plans_from_group_digests_alone() {
        // Balanced fleet: no group ever shows negative member headroom,
        // so the grouped planner never descends — per-shard views read
        // at rebalance drop to zero while the flat run reads M per
        // epoch. The run outcome is identical (nothing to move either
        // way).
        let mk = || {
            ShardScenario::builder(
                vec![pool(3, 2.5), pool(3, 2.5), pool(3, 2.5), pool(3, 2.5)],
                uniform_streams(8, 2.0, 160, 4),
            )
            .gossip(10.0)
            .epochs(8)
            .seed(9)
        };
        let flat = run_sharded(&mk().build());
        let grouped = run_sharded(&mk().groups(2).build());
        assert_eq!(flat.migrations, 0);
        assert_eq!(grouped.migrations, 0);
        assert_eq!(grouped.control_log, flat.control_log);
        assert_eq!(grouped.plan_stats.shards_examined, 0);
        assert!(flat.plan_stats.shards_examined > 0);
        assert!(
            grouped.plan_stats.reads() < flat.plan_stats.reads(),
            "grouped {} vs flat {}",
            grouped.plan_stats.reads(),
            flat.plan_stats.reads()
        );
    }
}
