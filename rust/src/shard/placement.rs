//! Stream placement: which shard a joining (or re-placed) stream lands
//! on.
//!
//! Placement sees only the gossip view — per-shard capacity and
//! committed load ([`ShardView`]) — never shard internals, so the same
//! policies work across process boundaries. Three policies:
//!
//! * [`PlacementPolicy::LeastLoaded`] — greedy headroom-maximising: the
//!   alive shard with the most uncommitted capacity takes the stream
//!   (ties break to the lowest shard id). Balances skewed arrival rates
//!   at admission time.
//! * [`PlacementPolicy::Hash`] — stable FNV-1a hash of the stream name
//!   over the alive shards: no shared placement state at all, at the
//!   cost of load-blindness (the gossip rebalancer cleans up after it).
//! * [`PlacementPolicy::RoundRobin`] — arrival order modulo alive
//!   shards: the classic load-blind baseline the experiments use to
//!   provoke deterministic imbalance.

/// One shard as the placement layer sees it: the gossip headroom digest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardView {
    pub shard: usize,
    pub alive: bool,
    /// Admission capacity: util-adjusted Σμ of the shard's pool (FPS).
    pub capacity: f64,
    /// Committed offered load: Σλ of the shard's resident streams (FPS).
    pub committed: f64,
    /// Forecast-Σλ one horizon ahead, when the publishing shard's
    /// confidence band was tight. `None` on legacy digests and
    /// forecast-free runs — every consumer then falls back to
    /// `committed` via [`ShardView::load`].
    pub forecast: Option<f64>,
}

impl ShardView {
    /// Projected offered load: the larger of committed and forecast Σλ.
    /// Planning against this is what lets placement act *ahead* of a
    /// predicted ramp; with no forecast slot it is exactly `committed`.
    pub fn load(&self) -> f64 {
        match self.forecast {
            Some(f) => self.committed.max(f),
            None => self.committed,
        }
    }

    /// Uncommitted capacity against projected load (may be negative when
    /// overloaded).
    pub fn headroom(&self) -> f64 {
        self.capacity - self.load()
    }

    /// Inside the §III-B-style band: projected load at or below the
    /// util-adjusted pool rate.
    pub fn in_band(&self) -> bool {
        self.load() <= self.capacity + 1e-9
    }
}

/// How streams are assigned to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    LeastLoaded,
    Hash,
    RoundRobin,
}

impl PlacementPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::Hash => "hash",
            PlacementPolicy::RoundRobin => "round-robin",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "least-loaded" | "least" | "ll" => Some(PlacementPolicy::LeastLoaded),
            "hash" => Some(PlacementPolicy::Hash),
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            _ => None,
        }
    }

    /// Pick a shard for a stream. `name` keys hash placement, `seq` is
    /// the stream's arrival index (round-robin), `views` is the current
    /// gossip table. Returns `None` only when no shard is alive; the
    /// chosen shard's admission still decides admit/degrade/reject.
    pub fn place(&self, name: &str, seq: usize, views: &[ShardView]) -> Option<usize> {
        let alive: Vec<&ShardView> = views.iter().filter(|v| v.alive).collect();
        if alive.is_empty() {
            return None;
        }
        match self {
            PlacementPolicy::LeastLoaded => {
                let mut best = alive[0];
                for &v in &alive[1..] {
                    if v.headroom() > best.headroom() + 1e-9 {
                        best = v;
                    }
                }
                Some(best.shard)
            }
            PlacementPolicy::Hash => {
                let k = (fnv1a(name) % alive.len() as u64) as usize;
                Some(alive[k].shard)
            }
            PlacementPolicy::RoundRobin => Some(alive[seq % alive.len()].shard),
        }
    }
}

/// FNV-1a over the stream name: stable across processes and runs (no
/// per-process hasher seed, unlike `std::collections` hashing).
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(caps: &[(f64, f64)]) -> Vec<ShardView> {
        caps.iter()
            .enumerate()
            .map(|(i, &(capacity, committed))| ShardView {
                shard: i,
                alive: true,
                capacity,
                committed,
                forecast: None,
            })
            .collect()
    }

    #[test]
    fn least_loaded_picks_max_headroom_with_low_id_ties() {
        let p = PlacementPolicy::LeastLoaded;
        let v = views(&[(10.0, 8.0), (10.0, 2.0), (10.0, 5.0)]);
        assert_eq!(p.place("s", 0, &v), Some(1));
        // Exact tie: lowest shard id wins.
        let v = views(&[(10.0, 4.0), (10.0, 4.0)]);
        assert_eq!(p.place("s", 0, &v), Some(0));
    }

    #[test]
    fn dead_shards_are_never_chosen() {
        let mut v = views(&[(10.0, 9.0), (10.0, 0.0)]);
        v[1].alive = false;
        for policy in [
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::Hash,
            PlacementPolicy::RoundRobin,
        ] {
            for (seq, name) in ["a", "b", "c", "d"].iter().enumerate() {
                assert_eq!(policy.place(name, seq, &v), Some(0), "{policy:?}");
            }
        }
        v[0].alive = false;
        assert_eq!(PlacementPolicy::LeastLoaded.place("a", 0, &v), None);
    }

    #[test]
    fn hash_is_stable_and_name_keyed() {
        let v = views(&[(10.0, 0.0), (10.0, 0.0), (10.0, 0.0)]);
        let a = PlacementPolicy::Hash.place("cam-a", 0, &v);
        // Same name, any seq, same shard — and repeatable.
        assert_eq!(PlacementPolicy::Hash.place("cam-a", 7, &v), a);
        assert_eq!(PlacementPolicy::Hash.place("cam-a", 0, &v), a);
        // FNV-1a reference value (empty string hashes to the offset basis).
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a("cam-a"), fnv1a("cam-b"));
    }

    #[test]
    fn round_robin_cycles_alive_shards() {
        let v = views(&[(10.0, 0.0), (10.0, 0.0)]);
        let p = PlacementPolicy::RoundRobin;
        assert_eq!(p.place("x", 0, &v), Some(0));
        assert_eq!(p.place("x", 1, &v), Some(1));
        assert_eq!(p.place("x", 2, &v), Some(0));
    }

    #[test]
    fn policy_labels_roundtrip() {
        for p in [
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::Hash,
            PlacementPolicy::RoundRobin,
        ] {
            assert_eq!(PlacementPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("rr"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("bogus"), None);
    }

    #[test]
    fn view_band_and_headroom() {
        let v = ShardView {
            shard: 0,
            alive: true,
            capacity: 9.5,
            committed: 7.5,
            forecast: None,
        };
        assert!((v.headroom() - 2.0).abs() < 1e-12);
        assert!(v.in_band());
        let v = ShardView { committed: 12.0, ..v };
        assert!(!v.in_band());
        assert!(v.headroom() < 0.0);
    }

    #[test]
    fn forecast_slot_projects_load_but_never_shrinks_it() {
        let v = ShardView {
            shard: 0,
            alive: true,
            capacity: 10.0,
            committed: 6.0,
            forecast: Some(9.0),
        };
        // A ramp forecast raises projected load and eats headroom…
        assert!((v.load() - 9.0).abs() < 1e-12);
        assert!((v.headroom() - 1.0).abs() < 1e-12);
        assert!(v.in_band());
        // …but a forecast *below* committed never frees capacity that is
        // already spoken for.
        let v = ShardView { forecast: Some(2.0), ..v };
        assert!((v.load() - 6.0).abs() < 1e-12);
        // Least-loaded placement steers around the shard about to ramp.
        let quiet = ShardView {
            shard: 1,
            alive: true,
            capacity: 10.0,
            committed: 7.0,
            forecast: None,
        };
        let ramping = ShardView {
            shard: 0,
            alive: true,
            capacity: 10.0,
            committed: 6.0,
            forecast: Some(9.5),
        };
        let got = PlacementPolicy::LeastLoaded.place("s", 0, &[ramping, quiet]);
        assert_eq!(got, Some(1));
    }
}
