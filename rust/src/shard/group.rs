//! Two-level coordination: shard groups and delta-encoded digests.
//!
//! Flat gossip makes the coordinator read M per-shard digests and the
//! planner walk M views every epoch — linear in fleet size, a ceiling of
//! maybe thousands of streams. This module adds the hierarchy that
//! breaks it:
//!
//! * **Shard groups** ([`ShardGroup`], [`GroupDigest`]) — contiguous
//!   blocks of shards whose digests aggregate member headroom: Σμ
//!   (capacity), Σλ (committed), and the min/max per-member headroom so
//!   a group-level read can tell *whether any member is out of band*
//!   without listing members. The coordinator plans over G = ⌈M/k⌉
//!   group digests and descends into a group's members only on
//!   imbalance (see [`crate::shard::plan`]). In a real deployment the
//!   per-group aggregation runs on a group leader, so the coordinator's
//!   own epoch cost is O(G + descended members), sub-linear in M while
//!   the fleet is mostly in band.
//! * **Delta digests** ([`DeltaEncoder`], [`DeltaDecoder`],
//!   [`DigestDelta`]) — a digest epoch carries only the shards whose
//!   capacity or committed Σλ moved beyond a threshold since the last
//!   acked epoch (plus deaths), with periodic full-snapshot resync
//!   frames bounding how long a lost delta can skew a view. The delta's
//!   uniform timestamp doubles as the heartbeat for *unchanged* shards,
//!   so at threshold 0 a delta stream reconstructs views identical to
//!   shipping full snapshots every epoch.
//!
//! Both have a JSON codec (audit/debug) and a compact binary codec
//! ([`encode_delta`]/[`decode_delta`] over [`crate::control::binary`])
//! with property-tested exact parity.

use crate::control::binary::{ByteReader, ByteWriter};
use crate::control::wire::{req_f64, req_usize, WireError};
use crate::shard::gossip::Headroom;
use crate::shard::placement::ShardView;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// A contiguous block of shard ids coordinated as one unit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardGroup {
    pub id: usize,
    /// Member shard ids (global, ascending).
    pub members: Vec<usize>,
}

/// Partition `num_shards` shards into contiguous groups of (up to)
/// `group_size` members. `group_size` is clamped to ≥ 1; the last group
/// may be short.
pub fn group_shards(num_shards: usize, group_size: usize) -> Vec<ShardGroup> {
    let k = group_size.max(1);
    (0..num_shards)
        .step_by(k)
        .enumerate()
        .map(|(id, lo)| ShardGroup {
            id,
            members: (lo..(lo + k).min(num_shards)).collect(),
        })
        .collect()
}

/// A group's aggregate headroom digest — what the coordinator reads
/// instead of the members' per-shard digests while the group is in band.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupDigest {
    pub group: usize,
    /// Members with a live gossip view.
    pub alive: usize,
    /// Σμ over live members (FPS).
    pub capacity: f64,
    /// Σλ over live members (FPS).
    pub committed: f64,
    /// Projected Σλ over live members: each member contributes
    /// `max(committed, forecast)` ([`ShardView::load`]). Equal to
    /// `committed` when no member carries a forecast slot.
    pub forecast: f64,
    /// Worst per-member headroom (negative ⇒ some member out of band).
    pub min_headroom: f64,
    /// Best per-member headroom (what the group can absorb in one shard).
    pub max_headroom: f64,
}

impl GroupDigest {
    /// Aggregate headroom Σμ − Σλ.
    pub fn headroom(&self) -> f64 {
        self.capacity - self.committed
    }

    /// Aggregate headroom against projected load Σμ − max(Σλ, forecast):
    /// what the group can still absorb *after* its predicted ramps land.
    pub fn projected_headroom(&self) -> f64 {
        self.capacity - self.forecast
    }

    /// Whether the coordinator must descend into members: some member is
    /// out of its §III-B band (same tolerance as
    /// [`ShardView::in_band`]), even if the group nets out positive.
    pub fn needs_descent(&self) -> bool {
        self.alive > 0 && self.min_headroom < -1e-9
    }
}

/// Fold the members' placement views into one [`GroupDigest`]. Dead
/// members contribute nothing (their slot reads as zero capacity).
pub fn aggregate(group: &ShardGroup, views: &[ShardView]) -> GroupDigest {
    let mut d = GroupDigest {
        group: group.id,
        alive: 0,
        capacity: 0.0,
        committed: 0.0,
        forecast: 0.0,
        min_headroom: f64::INFINITY,
        max_headroom: f64::NEG_INFINITY,
    };
    for &m in &group.members {
        let Some(v) = views.get(m) else { continue };
        if !v.alive {
            continue;
        }
        d.alive += 1;
        d.capacity += v.capacity;
        d.committed += v.committed;
        d.forecast += v.load();
        d.min_headroom = d.min_headroom.min(v.headroom());
        d.max_headroom = d.max_headroom.max(v.headroom());
    }
    if d.alive == 0 {
        d.min_headroom = 0.0;
        d.max_headroom = 0.0;
    }
    d
}

// ---- delta-encoded digest streams -------------------------------------

/// One digest epoch on the wire: either a full snapshot (`full`) or the
/// shards that changed beyond the encoder's threshold since the last
/// epoch, plus deaths. `at` is uniform across the epoch and acts as the
/// heartbeat for every live shard, changed or not.
#[derive(Debug, Clone, PartialEq)]
pub struct DigestDelta {
    pub epoch: usize,
    pub at: f64,
    pub full: bool,
    pub entries: Vec<Headroom>,
    /// Shards that lost their digest since the last epoch.
    pub dead: Vec<usize>,
}

/// Coordinator/leader side: tracks the last state the peer acked and
/// emits minimal [`DigestDelta`]s, with a full snapshot every
/// `resync_every` epochs (and on the first).
#[derive(Debug, Clone)]
pub struct DeltaEncoder {
    threshold: f64,
    resync_every: usize,
    epochs_sent: usize,
    last: Vec<Option<Headroom>>,
}

impl DeltaEncoder {
    /// `threshold` bounds the *cumulative* drift (FPS) a shard may
    /// accumulate against the last shipped state before it is forced
    /// onto the wire: the L1 sum of capacity, committed, and forecast
    /// movement since the last emit. 0 means every change ships.
    /// `resync_every` ≥ 1: every n-th epoch is a full snapshot
    /// regardless.
    pub fn new(num_shards: usize, threshold: f64, resync_every: usize) -> DeltaEncoder {
        DeltaEncoder {
            threshold: threshold.max(0.0),
            resync_every: resync_every.max(1),
            epochs_sent: 0,
            last: vec![None; num_shards],
        }
    }

    fn changed(&self, prev: &Option<Headroom>, cur: &Option<Headroom>) -> bool {
        match (prev, cur) {
            (None, None) => false,
            (Some(_), None) | (None, Some(_)) => true,
            (Some(p), Some(c)) => {
                // Cumulative L1 drift since the last *emitted* state.
                // Gating each field independently let capacity and
                // committed creep in opposite directions, each below
                // threshold, compounding up to 2× threshold of headroom
                // skew before anything shipped; the combined bound keeps
                // the receiver's headroom within one threshold of truth.
                let fdrift = match (p.forecast, c.forecast) {
                    (None, None) => 0.0,
                    (Some(a), Some(b)) => (a - b).abs(),
                    // A forecast slot appearing or vanishing always ships.
                    _ => f64::INFINITY,
                };
                (p.capacity - c.capacity).abs()
                    + (p.committed - c.committed).abs()
                    + fdrift
                    > self.threshold
            }
        }
    }

    /// Encode the digest epoch for `current` (one slot per shard, `None`
    /// = no live digest) against the last encoded state.
    pub fn encode(&mut self, epoch: usize, at: f64, current: &[Option<Headroom>]) -> DigestDelta {
        let full = self.epochs_sent % self.resync_every == 0;
        self.epochs_sent += 1;
        let mut entries = Vec::new();
        let mut dead = Vec::new();
        for (shard, cur) in current.iter().enumerate() {
            let prev = self.last.get(shard).cloned().flatten();
            match cur {
                Some(h) => {
                    if full || self.changed(&prev, cur) {
                        entries.push(Headroom { at, ..*h });
                    }
                }
                None => {
                    if prev.is_some() && !full {
                        dead.push(shard);
                    }
                }
            }
        }
        if full {
            // A snapshot lists every live shard; absence means dead.
            dead.clear();
        }
        // A full frame resets the reference state; a delta advances only
        // the shards it shipped, so unshipped drift keeps accumulating
        // against the *acked* state rather than silently vanishing.
        if full {
            self.last = current.to_vec();
        } else {
            for e in &entries {
                if let Some(slot) = self.last.get_mut(e.shard) {
                    *slot = Some(*e);
                }
            }
            for &shard in &dead {
                if let Some(slot) = self.last.get_mut(shard) {
                    *slot = None;
                }
            }
        }
        DigestDelta {
            epoch,
            at,
            full,
            entries,
            dead,
        }
    }
}

/// Receiver side: folds [`DigestDelta`]s back into a per-shard view.
#[derive(Debug, Clone)]
pub struct DeltaDecoder {
    view: Vec<Option<Headroom>>,
}

impl DeltaDecoder {
    pub fn new(num_shards: usize) -> DeltaDecoder {
        DeltaDecoder {
            view: vec![None; num_shards],
        }
    }

    /// Apply one epoch. The delta's uniform `at` refreshes the heartbeat
    /// of *every* surviving shard — unchanged shards stay alive without
    /// being re-listed.
    pub fn apply(&mut self, d: &DigestDelta) {
        if d.full {
            for slot in self.view.iter_mut() {
                *slot = None;
            }
        }
        for &shard in &d.dead {
            if let Some(slot) = self.view.get_mut(shard) {
                *slot = None;
            }
        }
        for e in &d.entries {
            if let Some(slot) = self.view.get_mut(e.shard) {
                *slot = Some(*e);
            }
        }
        for slot in self.view.iter_mut().flatten() {
            slot.at = d.at;
        }
    }

    /// The reconstructed per-shard digests (one slot per shard).
    pub fn view(&self) -> &[Option<Headroom>] {
        &self.view
    }
}

// ---- JSON codec (audit/debug) ------------------------------------------

fn headroom_to_json(h: &Headroom) -> Json {
    let mut o = BTreeMap::new();
    o.insert("shard".to_string(), Json::Num(h.shard as f64));
    o.insert("capacity".to_string(), Json::Num(h.capacity));
    o.insert("committed".to_string(), Json::Num(h.committed));
    // Optional slot: absent on legacy digests and forecast-free runs, so
    // forecast-free encodings are byte-identical to pre-forecast builds.
    if let Some(f) = h.forecast {
        o.insert("forecast".to_string(), Json::Num(f));
    }
    Json::Obj(o)
}

fn headroom_from_json(v: &Json, at: f64) -> Result<Headroom, WireError> {
    let forecast = match v.get("forecast") {
        None | Some(Json::Null) => None,
        Some(j) => Some(
            j.as_f64()
                .ok_or_else(|| WireError::new("digest forecast must be a number"))?,
        ),
    };
    Ok(Headroom {
        shard: req_usize(v, "shard")?,
        at,
        capacity: req_f64(v, "capacity")?,
        committed: req_f64(v, "committed")?,
        forecast,
    })
}

/// Serialise a [`DigestDelta`]. Entry timestamps are uniform by
/// construction, so only the epoch-level `at` is carried.
pub fn delta_to_json(d: &DigestDelta) -> Json {
    let mut o = BTreeMap::new();
    o.insert("epoch".to_string(), Json::Num(d.epoch as f64));
    o.insert("at".to_string(), Json::Num(d.at));
    o.insert("full".to_string(), Json::Bool(d.full));
    o.insert(
        "entries".to_string(),
        Json::Arr(d.entries.iter().map(headroom_to_json).collect()),
    );
    o.insert(
        "dead".to_string(),
        Json::Arr(d.dead.iter().map(|&s| Json::Num(s as f64)).collect()),
    );
    Json::Obj(o)
}

pub fn delta_from_json(v: &Json) -> Result<DigestDelta, WireError> {
    let epoch = req_usize(v, "epoch")?;
    let at = req_f64(v, "at")?;
    let full = v
        .get("full")
        .and_then(Json::as_bool)
        .ok_or_else(|| WireError::new("missing or mistyped field \"full\""))?;
    let mut entries = Vec::new();
    match v.get("entries") {
        Some(Json::Arr(xs)) => {
            for x in xs {
                entries.push(headroom_from_json(x, at)?);
            }
        }
        _ => return Err(WireError::new("missing or mistyped field \"entries\"")),
    }
    let mut dead = Vec::new();
    match v.get("dead") {
        Some(Json::Arr(xs)) => {
            for x in xs {
                let n = x
                    .as_f64()
                    .ok_or_else(|| WireError::new("dead entry must be a shard id"))?;
                if n < 0.0 || n.fract() != 0.0 {
                    return Err(WireError::new("dead entry must be a shard id"));
                }
                dead.push(n as usize);
            }
        }
        _ => return Err(WireError::new("missing or mistyped field \"dead\"")),
    }
    Ok(DigestDelta {
        epoch,
        at,
        full,
        entries,
        dead,
    })
}

// ---- binary codec (hot path) -------------------------------------------

/// Compact binary [`DigestDelta`]: varint epoch/ids, adaptive floats,
/// per-entry capacity+committed only (the uniform `at` ships once).
/// Forecast slots ride a trailing optional section — `(entry index,
/// forecast)` pairs — written only when some entry carries one, so
/// forecast-free deltas are byte-identical to pre-forecast builds and
/// legacy bytes decode with every forecast absent.
pub fn encode_delta(d: &DigestDelta) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.varint(d.epoch as u64);
    w.f64(d.at);
    w.bool(d.full);
    w.varint(d.entries.len() as u64);
    for e in &d.entries {
        w.varint(e.shard as u64);
        w.f64(e.capacity);
        w.f64(e.committed);
    }
    w.varint(d.dead.len() as u64);
    for &s in &d.dead {
        w.varint(s as u64);
    }
    let forecasts: Vec<(usize, f64)> = d
        .entries
        .iter()
        .enumerate()
        .filter_map(|(i, e)| e.forecast.map(|f| (i, f)))
        .collect();
    if !forecasts.is_empty() {
        w.varint(forecasts.len() as u64);
        for (i, f) in forecasts {
            w.varint(i as u64);
            w.f64(f);
        }
    }
    w.into_bytes()
}

pub fn decode_delta(bytes: &[u8]) -> Result<DigestDelta, WireError> {
    let mut r = ByteReader::new(bytes);
    let epoch = r.usize()?;
    let at = r.f64()?;
    let full = r.bool()?;
    let n = r.usize()?;
    let mut entries = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        entries.push(Headroom {
            shard: r.usize()?,
            at,
            capacity: r.f64()?,
            committed: r.f64()?,
            forecast: None,
        });
    }
    let n = r.usize()?;
    let mut dead = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        dead.push(r.usize()?);
    }
    // Trailing optional forecast section (absent on legacy encoders).
    if r.remaining() > 0 {
        let n = r.usize()?;
        for _ in 0..n {
            let idx = r.usize()?;
            let f = r.f64()?;
            let slot = entries
                .get_mut(idx)
                .ok_or_else(|| WireError::new("forecast index out of range"))?;
            slot.forecast = Some(f);
        }
    }
    if r.remaining() > 0 {
        return Err(WireError::new("trailing bytes after digest delta"));
    }
    Ok(DigestDelta {
        epoch,
        at,
        full,
        entries,
        dead,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    fn view(shard: usize, alive: bool, capacity: f64, committed: f64) -> ShardView {
        ShardView {
            shard,
            alive,
            capacity,
            committed,
            forecast: None,
        }
    }

    #[test]
    fn groups_partition_every_shard_exactly_once() {
        let groups = group_shards(10, 4);
        assert_eq!(groups.len(), 3);
        let all: Vec<usize> = groups.iter().flat_map(|g| g.members.clone()).collect();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        assert_eq!(groups[2].members, vec![8, 9]);
        // Degenerate sizes still partition.
        assert_eq!(group_shards(3, 0).len(), 3);
        assert_eq!(group_shards(0, 4).len(), 0);
    }

    #[test]
    fn aggregate_sums_live_members_and_tracks_worst_headroom() {
        let groups = group_shards(4, 2);
        let views = vec![
            view(0, true, 10.0, 4.0),  // headroom +6
            view(1, true, 10.0, 12.0), // headroom -2: out of band
            view(2, true, 8.0, 8.0),   // headroom 0: in band (≤ tolerance)
            view(3, false, 0.0, 0.0),  // dead
        ];
        let d0 = aggregate(&groups[0], &views);
        assert_eq!(d0.alive, 2);
        assert_eq!(d0.capacity, 20.0);
        assert_eq!(d0.committed, 16.0);
        assert_eq!(d0.headroom(), 4.0);
        // No forecast slots: projected load degenerates to committed.
        assert_eq!(d0.forecast, 16.0);
        assert_eq!(d0.projected_headroom(), 4.0);
        assert_eq!(d0.min_headroom, -2.0);
        assert_eq!(d0.max_headroom, 6.0);
        // Group nets out positive but a member is out of band: descend.
        assert!(d0.needs_descent());
        let d1 = aggregate(&groups[1], &views);
        assert_eq!(d1.alive, 1);
        assert!(!d1.needs_descent());
        // All-dead group is inert.
        let dead = aggregate(&groups[1], &[view(0, true, 1.0, 0.0)]);
        assert_eq!(dead.alive, 0);
        assert!(!dead.needs_descent());
    }

    #[test]
    fn aggregate_folds_forecast_slots_into_projection_and_descent() {
        let groups = group_shards(2, 2);
        let views = vec![
            // In band now (4 < 10) but forecasting a ramp past capacity.
            ShardView {
                shard: 0,
                alive: true,
                capacity: 10.0,
                committed: 4.0,
                forecast: Some(11.0),
            },
            view(1, true, 10.0, 6.0),
        ];
        let d = aggregate(&groups[0], &views);
        assert_eq!(d.committed, 10.0);
        // Projected: max(4, 11) + 6.
        assert_eq!(d.forecast, 17.0);
        assert_eq!(d.headroom(), 10.0);
        assert_eq!(d.projected_headroom(), 3.0);
        // The worst *projected* member headroom is 10 − 11 = −1: the
        // coordinator descends ahead of the ramp, not after it.
        assert_eq!(d.min_headroom, -1.0);
        assert!(d.needs_descent());
    }

    fn random_digest(rng: &mut Rng, shard: usize) -> Headroom {
        Headroom {
            shard,
            at: 0.0,
            capacity: rng.range(5.0, 20.0),
            committed: rng.range(0.0, 25.0),
            forecast: if rng.chance(0.4) {
                Some(rng.range(0.0, 30.0))
            } else {
                None
            },
        }
    }

    fn random_state(rng: &mut Rng, n: usize) -> Vec<Option<Headroom>> {
        (0..n)
            .map(|shard| {
                if rng.chance(0.15) {
                    None
                } else {
                    Some(random_digest(rng, shard))
                }
            })
            .collect()
    }

    fn drift(rng: &mut Rng, state: &mut [Option<Headroom>]) {
        for (shard, slot) in state.iter_mut().enumerate() {
            if slot.is_some() {
                if rng.chance(0.1) {
                    *slot = None;
                } else if let Some(h) = slot.as_mut() {
                    // Most shards drift a little; a few jump.
                    let step = if rng.chance(0.2) { 3.0 } else { 0.05 };
                    h.committed = (h.committed + rng.range(-step, step)).max(0.0);
                    if rng.chance(0.1) {
                        // Forecast slots come and go with confidence.
                        h.forecast = if rng.chance(0.5) {
                            Some(rng.range(0.0, 30.0))
                        } else {
                            None
                        };
                    } else if let Some(f) = h.forecast.as_mut() {
                        *f = (*f + rng.range(-step, step)).max(0.0);
                    }
                }
            } else if rng.chance(0.2) {
                *slot = Some(random_digest(rng, shard));
            }
        }
    }

    #[test]
    fn prop_threshold_zero_delta_stream_reconstructs_full_snapshots_exactly() {
        check("delta stream == snapshots", Config::default(), |rng| {
            let n = rng.int_in(1, 12) as usize;
            let mut enc = DeltaEncoder::new(n, 0.0, rng.int_in(2, 6) as usize);
            let mut dec = DeltaDecoder::new(n);
            let mut state = random_state(rng, n);
            for epoch in 0..10 {
                let at = epoch as f64 * 10.0;
                let stamped: Vec<Option<Headroom>> = state
                    .iter()
                    .map(|s| s.map(|h| Headroom { at, ..h }))
                    .collect();
                let delta = enc.encode(epoch, at, &stamped);
                // The wire hop must be lossless too.
                let wired =
                    decode_delta(&encode_delta(&delta)).map_err(|e| e.to_string())?;
                if wired != delta {
                    return Err(format!("binary delta round trip: {wired:?} != {delta:?}"));
                }
                let json =
                    delta_from_json(&delta_to_json(&delta)).map_err(|e| e.to_string())?;
                if json != delta {
                    return Err(format!("json delta round trip: {json:?} != {delta:?}"));
                }
                dec.apply(&delta);
                if dec.view() != stamped.as_slice() {
                    return Err(format!(
                        "epoch {epoch}: decoded view {:?} != snapshot {stamped:?}",
                        dec.view()
                    ));
                }
                drift(rng, &mut state);
            }
            Ok(())
        });
    }

    #[test]
    fn prop_thresholded_views_stay_within_threshold_of_truth() {
        check("delta threshold error bound", Config::default(), |rng| {
            let n = rng.int_in(2, 10) as usize;
            let threshold = rng.range(0.1, 1.0);
            let mut enc = DeltaEncoder::new(n, threshold, 4);
            let mut dec = DeltaDecoder::new(n);
            let mut state = random_state(rng, n);
            for epoch in 0..12 {
                let at = epoch as f64 * 10.0;
                let stamped: Vec<Option<Headroom>> = state
                    .iter()
                    .map(|s| s.map(|h| Headroom { at, ..h }))
                    .collect();
                dec.apply(&enc.encode(epoch, at, &stamped));
                for (truth, got) in stamped.iter().zip(dec.view()) {
                    match (truth, got) {
                        (Some(t), Some(g)) => {
                            // Drift below the threshold may be withheld,
                            // but the *cumulative* skew across all three
                            // fields never exceeds one threshold — this
                            // is the bound the per-field gating of the
                            // old encoder violated (up to 2× threshold
                            // of headroom error).
                            let fskew = match (t.forecast, g.forecast) {
                                (None, None) => 0.0,
                                (Some(a), Some(b)) => (a - b).abs(),
                                _ => {
                                    return Err(format!(
                                        "epoch {epoch}: forecast presence skew"
                                    ))
                                }
                            };
                            let skew = (t.committed - g.committed).abs()
                                + (t.capacity - g.capacity).abs()
                                + fskew;
                            if skew > threshold + 1e-9 {
                                return Err(format!(
                                    "epoch {epoch}: cumulative skew {skew} > threshold {threshold}"
                                ));
                            }
                            if g.at != at {
                                return Err(format!("heartbeat not refreshed at {epoch}"));
                            }
                        }
                        // Presence changes always ship.
                        (None, Some(_)) | (Some(_), None) => {
                            return Err(format!("epoch {epoch}: presence skew"))
                        }
                        (None, None) => {}
                    }
                }
                drift(rng, &mut state);
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_creep_forces_emit_before_headroom_error_compounds() {
        // Regression: the old encoder gated capacity and committed
        // *independently* against the threshold, so opposed sub-threshold
        // creeps — capacity up 0.09/epoch, committed down 0.09/epoch —
        // compounded to ~1.9 FPS of headroom skew (just under 2×
        // threshold) before either field shipped. The cumulative-drift
        // gate must force an emit once the combined movement crosses one
        // threshold, bounding headroom skew to threshold + one epoch's
        // step.
        let threshold = 1.0;
        let step = 0.09;
        let mut enc = DeltaEncoder::new(1, threshold, 1000);
        let mut dec = DeltaDecoder::new(1);
        let mut truth = Headroom {
            shard: 0,
            at: 0.0,
            capacity: 10.0,
            committed: 5.0,
            forecast: None,
        };
        dec.apply(&enc.encode(0, 0.0, &[Some(truth)]));
        let mut worst = 0.0f64;
        let mut emitted_midstream = false;
        for epoch in 1..40 {
            truth.at = epoch as f64;
            truth.capacity += step;
            truth.committed = (truth.committed - step).max(0.0);
            let d = enc.encode(epoch, truth.at, &[Some(truth)]);
            emitted_midstream |= !d.entries.is_empty();
            dec.apply(&d);
            let got = dec.view()[0].expect("shard stays live");
            let skew = (truth.capacity - got.capacity)
                + (got.committed - truth.committed);
            worst = worst.max(skew);
        }
        assert!(emitted_midstream, "creep never forced an emit");
        // Old encoder: worst ≈ 1.89 (21 epochs of silent 0.18/epoch
        // creep). Fixed: the emit fires once |Δcap|+|Δcom| > 1.0, i.e.
        // at 1.08 combined.
        assert!(
            worst <= threshold + 2.0 * step + 1e-9,
            "headroom skew compounded to {worst}"
        );
    }

    #[test]
    fn deltas_ship_fewer_entries_than_snapshots_under_small_churn() {
        // The point of the exercise: with mostly-idle shards, a delta
        // epoch is much smaller than a snapshot epoch.
        let n = 64;
        let mut enc = DeltaEncoder::new(n, 0.5, 1000);
        let mut state: Vec<Option<Headroom>> = (0..n)
            .map(|shard| {
                Some(Headroom {
                    shard,
                    at: 0.0,
                    capacity: 10.0,
                    committed: 5.0,
                    forecast: None,
                })
            })
            .collect();
        let snapshot = enc.encode(0, 0.0, &state);
        assert!(snapshot.full);
        assert_eq!(snapshot.entries.len(), n);
        // One shard moves materially; the rest jitter below threshold.
        for (i, slot) in state.iter_mut().enumerate() {
            let h = slot.as_mut().unwrap();
            h.at = 10.0;
            h.committed += if i == 7 { 4.0 } else { 0.01 };
        }
        let delta = enc.encode(1, 10.0, &state);
        assert!(!delta.full);
        assert_eq!(delta.entries.len(), 1);
        assert_eq!(delta.entries[0].shard, 7);
        assert!(delta.dead.is_empty());
        let bytes = encode_delta(&delta).len();
        let snap_bytes = encode_delta(&snapshot).len();
        assert!(
            bytes * 10 < snap_bytes,
            "delta {bytes}B should be ≪ snapshot {snap_bytes}B"
        );
    }

    #[test]
    fn malformed_delta_payloads_are_errors() {
        let d = DigestDelta {
            epoch: 2,
            at: 20.0,
            full: false,
            entries: vec![Headroom {
                shard: 1,
                at: 20.0,
                capacity: 9.5,
                committed: 3.25,
                forecast: Some(4.5),
            }],
            dead: vec![0],
        };
        // The same frame minus its forecast slot is what a legacy
        // encoder would emit — a strict byte prefix of `bytes`.
        let legacy = DigestDelta {
            entries: vec![Headroom { forecast: None, ..d.entries[0] }],
            dead: d.dead.clone(),
            ..d.clone()
        };
        let legacy_bytes = encode_delta(&legacy);
        let bytes = encode_delta(&d);
        assert!(bytes.starts_with(&legacy_bytes) && bytes.len() > legacy_bytes.len());
        for cut in 0..bytes.len() {
            if cut == legacy_bytes.len() {
                // Exactly the legacy frame: decodes, forecast absent —
                // the forward-compat contract.
                let rt = decode_delta(&bytes[..cut]).unwrap();
                assert_eq!(rt, legacy);
                continue;
            }
            assert!(decode_delta(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing bytes after a complete forecast section are an error…
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_delta(&long).is_err());
        // …as is a forecast pair pointing past the entry list (varints
        // 1 = one pair, 3 = entry index of a 1-entry frame).
        let mut bad_idx = legacy_bytes;
        bad_idx.push(1);
        bad_idx.push(3);
        assert!(decode_delta(&bad_idx).is_err());
        assert!(delta_from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
