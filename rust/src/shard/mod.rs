//! Stream sharding across fleet instances.
//!
//! One `fleet::serve`/`fleet::sim` instance scales to one process'
//! worth of streams; the ROADMAP's heavy-traffic north star needs many.
//! This subsystem partitions N streams over M shard instances — each
//! wrapping its own device pool, admission policy and registry — behind
//! a thin placement layer, with all coordination expressed in the
//! serialisable [`crate::control`] vocabulary:
//!
//! * [`placement`] — where a joining stream lands: least-loaded
//!   (headroom-greedy), hash (stateless FNV-1a over the stream name) or
//!   round-robin, all over the gossip view only.
//! * [`gossip`] — the periodic capacity exchange: per-shard headroom
//!   digests (util-adjusted Σμ vs committed Σλ, the §III-B band per
//!   shard) with missed-heartbeat expiry, plus the band-restoring
//!   migration planner.
//! * [`sim`] — the co-simulation runner: gossip-epoch-quantised virtual
//!   time, stream migration and shard-loss re-placement executed as
//!   serialised detach→attach [`crate::control::WireEvent`]s (encoded
//!   and decoded on every hop, exactly the surface a cross-process
//!   deployment needs).
//! * [`remote`] — the same co-simulation with each fleet instance
//!   behind a real socket ([`crate::transport`]): shards answer gossip
//!   polls and serve epoch slices over length-prefixed frames, and a
//!   dropped connection surfaces as shard loss — the gossip planner
//!   re-places the orphans within one interval. Sessions authenticate
//!   via [`crate::control::SessionCaps`] tokens, rejected handshakes
//!   get a typed `Reject` frame, and a restarted shard redials and
//!   rejoins gossip as a fresh session.
//! * [`group`] — two-level coordination: shard *groups* whose digests
//!   aggregate member headroom (Σμ, Σλ, min/max per-member), so the
//!   coordinator plans over ⌈M/k⌉ aggregates and descends into members
//!   only on imbalance; plus delta-encoded digest streams (changed
//!   shards only, periodic full-snapshot resync) with exact-parity
//!   JSON and binary codecs.
//! * [`plan`] — the migration planner split out of event fan-out:
//!   flat or grouped planning as a pure function from gossip state to
//!   migrations plus deterministic work counters ([`plan::PlanStats`]),
//!   independently benchable (`benches/coordinator_scale.rs`).
//! * [`autoscale`] — shard-local capacity control: an embedded
//!   [`crate::autoscale::AutoscaleController`] runs the §III-B closed
//!   loop against the shard's own pool between epoch slices, digests
//!   advertise post-scale headroom so migrations start only when local
//!   scaling is exhausted, and every scale action rides the wire back
//!   to the coordinator's audit [`crate::control::EventLog`].

pub mod autoscale;
pub mod gossip;
pub mod group;
pub mod placement;
pub mod plan;
pub mod remote;
pub mod sim;

pub use autoscale::{projected_capacity, ShardAutoscaler};
pub use gossip::{plan_moves, GossipTable, Headroom, Migration};
pub use group::{
    aggregate, decode_delta, delta_from_json, delta_to_json, encode_delta, group_shards,
    DeltaDecoder, DeltaEncoder, DigestDelta, GroupDigest, ShardGroup,
};
pub use plan::{plan, plan_flat, plan_grouped, PlanStats};
pub use placement::{fnv1a, PlacementPolicy, ShardView};
pub use remote::{
    run_sharded_remote, serve_shard, serve_shard_sessions, RemoteShard, RemoteTransport,
};
pub use sim::{
    record_coordinator_telemetry, record_slice_telemetry, run_sharded, EpochPhases,
    ScenarioBuilder, ShardControl, ShardReport, ShardScenario, ShardStreamReport,
};
