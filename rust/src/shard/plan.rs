//! The migration planner, split out of event fan-out.
//!
//! Before this module the epoch loop called
//! [`crate::shard::gossip::plan_moves`] inline and immediately fanned
//! the resulting detach→attach events out to shards — the two concerns
//! were inseparable and neither was benchable alone. Now the *plan*
//! phase is a pure function from gossip state to migrations plus
//! deterministic work counters ([`PlanStats`]), and the runners keep
//! only the fan-out.
//!
//! Two strategies share one entry point ([`plan`]):
//!
//! * **flat** — the original single-level planner: examine every shard
//!   view, O(M) per epoch.
//! * **grouped** — two-level: fold views into [`GroupDigest`]s
//!   ([`crate::shard::group`]), plan over G = ⌈M/k⌉ aggregates, and
//!   *descend* into a group's members only when its digest shows a
//!   member out of band. Target capacity comes from the best-headroom
//!   in-band groups until the gathered headroom covers the measured
//!   excess; everything else stays masked. The per-epoch coordinator
//!   cost is O(G + descended members) — sub-linear in M while overload
//!   is localised, which is exactly what `benches/coordinator_scale.rs`
//!   pins.
//!
//! The grouped planner degrades to the flat one: when every group needs
//! descent the candidate set is every shard and the move list is
//! *identical* (the underlying [`plan_moves`] is shared), a property the
//! tests pin.

use crate::shard::gossip::{plan_moves, Migration};
use crate::shard::group::{aggregate, group_shards, GroupDigest};
use crate::shard::placement::ShardView;

/// Deterministic work counters for one plan invocation. Wall-clock
/// timings ride the PR 7 phase histograms; these counters are the
/// noise-free sub-linearity witness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlanStats {
    /// Group digests read (0 for the flat planner).
    pub groups_total: usize,
    /// Groups whose members the planner descended into.
    pub groups_descended: usize,
    /// Per-shard views examined (flat: all of them; grouped: members of
    /// descended + target groups only).
    pub shards_examined: usize,
    /// Migrations planned.
    pub migrations: usize,
}

impl PlanStats {
    /// Total coordinator-side reads this epoch: group digests plus
    /// per-shard views. The bench pins this growing sub-linearly in M.
    pub fn reads(&self) -> usize {
        self.groups_total + self.shards_examined
    }

    /// Fold counters across epochs (for per-run reporting).
    pub fn absorb(&mut self, other: &PlanStats) {
        self.groups_total += other.groups_total;
        self.groups_descended += other.groups_descended;
        self.shards_examined += other.shards_examined;
        self.migrations += other.migrations;
    }
}

/// Single-level planning: examine every view.
pub fn plan_flat(
    views: &[ShardView],
    residents: &[(usize, f64, usize)],
) -> (Vec<Migration>, PlanStats) {
    let moves = plan_moves(views, residents);
    let stats = PlanStats {
        groups_total: 0,
        groups_descended: 0,
        shards_examined: views.len(),
        migrations: moves.len(),
    };
    (moves, stats)
}

/// Two-level planning over groups of `group_size` shards.
pub fn plan_grouped(
    views: &[ShardView],
    residents: &[(usize, f64, usize)],
    group_size: usize,
) -> (Vec<Migration>, PlanStats) {
    let groups = group_shards(views.len(), group_size);
    let digests: Vec<GroupDigest> = groups.iter().map(|g| aggregate(g, views)).collect();

    // Sources: any group whose digest shows a member out of band.
    let mut descended = vec![false; groups.len()];
    let mut excess = 0.0;
    for (gi, d) in digests.iter().enumerate() {
        if d.needs_descent() {
            descended[gi] = true;
            for &m in &groups[gi].members {
                let v = &views[m];
                if v.alive && !v.in_band() {
                    // Projected load, so a forecast ramp counts as excess
                    // before it lands.
                    excess += v.load() - v.capacity;
                }
            }
        }
    }

    let mut stats = PlanStats {
        groups_total: groups.len(),
        groups_descended: 0,
        shards_examined: 0,
        migrations: 0,
    };
    if excess <= 0.0 {
        // Every group in band: nothing to plan, nothing descended.
        return (Vec::new(), stats);
    }

    // Targets: best-headroom in-band groups until the gathered headroom
    // covers the excess. In-band groups have no negative-headroom
    // member, so the aggregate headroom is exactly the absorbable slack.
    let mut order: Vec<usize> = (0..groups.len()).filter(|&gi| !descended[gi]).collect();
    order.sort_by(|&a, &b| {
        digests[b]
            .max_headroom
            .partial_cmp(&digests[a].max_headroom)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut gathered = 0.0;
    for gi in order {
        if gathered >= excess {
            break;
        }
        descended[gi] = true;
        // Projected headroom: a target group about to ramp is not slack.
        gathered += digests[gi].projected_headroom().max(0.0);
    }

    // Mask every shard outside the descended groups and reuse the flat
    // planner on the shrunken candidate set — identical move semantics,
    // smaller working set.
    let mut masked = views.to_vec();
    let mut candidate = vec![false; views.len()];
    for (gi, g) in groups.iter().enumerate() {
        if !descended[gi] {
            continue;
        }
        stats.groups_descended += 1;
        for &m in &g.members {
            candidate[m] = true;
        }
    }
    for v in masked.iter_mut() {
        if !candidate[v.shard] {
            v.alive = false;
        }
    }
    stats.shards_examined = candidate.iter().filter(|&&c| c).count();

    let moves = plan_moves(&masked, residents);
    stats.migrations = moves.len();
    (moves, stats)
}

/// Plan band-restoring migrations. `group_size = None` is the flat
/// planner; `Some(k)` plans over ⌈M/k⌉ group aggregates and descends
/// only on imbalance.
pub fn plan(
    views: &[ShardView],
    residents: &[(usize, f64, usize)],
    group_size: Option<usize>,
) -> (Vec<Migration>, PlanStats) {
    match group_size {
        None => plan_flat(views, residents),
        Some(k) => plan_grouped(views, residents, k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    fn view(shard: usize, capacity: f64, committed: f64) -> ShardView {
        ShardView {
            shard,
            alive: true,
            capacity,
            committed,
            forecast: None,
        }
    }

    #[test]
    fn flat_and_grouped_agree_when_every_group_descends() {
        // Both groups hold an out-of-band shard: the grouped candidate
        // set is every shard and the plans must be identical.
        let views = vec![
            view(0, 10.0, 14.0),
            view(1, 10.0, 2.0),
            view(2, 10.0, 13.0),
            view(3, 10.0, 1.0),
        ];
        let residents = [
            (0, 4.0, 0),
            (1, 10.0, 0),
            (2, 2.0, 1),
            (3, 3.0, 2),
            (4, 10.0, 2),
            (5, 1.0, 3),
        ];
        let (flat_moves, flat_stats) = plan_flat(&views, &residents);
        let (grouped_moves, grouped_stats) = plan_grouped(&views, &residents, 2);
        assert!(!flat_moves.is_empty());
        assert_eq!(grouped_moves, flat_moves);
        assert_eq!(grouped_stats.shards_examined, 4);
        assert_eq!(grouped_stats.groups_descended, 2);
        assert_eq!(flat_stats.shards_examined, 4);
        assert_eq!(flat_stats.groups_total, 0);
    }

    #[test]
    fn in_band_fleet_examines_zero_shards() {
        let views: Vec<ShardView> = (0..64).map(|i| view(i, 10.0, 5.0)).collect();
        let residents: Vec<(usize, f64, usize)> =
            (0..64).map(|i| (i, 5.0, i)).collect();
        let (moves, stats) = plan_grouped(&views, &residents, 8);
        assert!(moves.is_empty());
        assert_eq!(stats.groups_total, 8);
        assert_eq!(stats.groups_descended, 0);
        assert_eq!(stats.shards_examined, 0);
        assert_eq!(stats.reads(), 8);
        // The flat planner reads 8× as much for the same (empty) answer.
        let (_, flat) = plan_flat(&views, &residents);
        assert_eq!(flat.reads(), 64);
    }

    #[test]
    fn localized_overload_descends_only_the_involved_groups() {
        // 64 shards in 8 groups; one shard in group 0 is overloaded and
        // group capacity exists nearby. Only source + enough target
        // groups are examined.
        let mut views: Vec<ShardView> = (0..64).map(|i| view(i, 10.0, 8.0)).collect();
        for v in views.iter_mut().skip(56) {
            v.committed = 3.0; // group 7 holds the slack: 7 FPS/shard
        }
        let mut residents: Vec<(usize, f64, usize)> = (0..64)
            .map(|i| (i, views[i].committed, i))
            .collect();
        residents[3] = (3, 8.0, 3);
        residents.push((64, 6.0, 3)); // the misfit the planner can shed
        views[3].committed = 14.0; // 4 FPS over the band
        let (moves, stats) = plan_grouped(&views, &residents, 8);
        assert_eq!(stats.groups_total, 8);
        // Source group 0 plus best-headroom target group 7: 16 shards
        // examined, not 64.
        assert_eq!(stats.groups_descended, 2);
        assert_eq!(stats.shards_examined, 16);
        assert!(stats.reads() < 64, "reads {} vs flat 64", stats.reads());
        // The 6-FPS stream lands on the best-headroom shard of group 7.
        assert_eq!(moves, vec![Migration { stream: 64, from: 3, to: 56 }]);
        assert_eq!(stats.migrations, 1);
    }

    #[test]
    fn intra_group_overload_is_fixed_inside_the_source_group() {
        // The overloaded member's own group has the headroom: the move
        // stays in-group (one conservative target group is still
        // reserved, but nothing lands there).
        let mut views: Vec<ShardView> = (0..16).map(|i| view(i, 10.0, 9.0)).collect();
        views[1].committed = 12.0;
        views[2].committed = 2.0;
        let mut residents: Vec<(usize, f64, usize)> =
            (0..16).map(|i| (i, views[i].committed, i)).collect();
        residents[1] = (1, 9.0, 1);
        residents.push((16, 3.0, 1));
        let (moves, stats) = plan_grouped(&views, &residents, 4);
        assert_eq!(moves, vec![Migration { stream: 16, from: 1, to: 2 }]);
        assert_eq!(stats.groups_descended, 2);
        assert_eq!(stats.shards_examined, 8);
    }

    #[test]
    fn forecast_ramp_descends_and_moves_before_load_lands() {
        // Shard 1 is in band *now* (6 < 10) but forecasts 14; group 1
        // has the slack. The grouped planner must treat the ramp as
        // excess, descend both groups, and move a stream pre-emptively.
        let mut views: Vec<ShardView> = (0..8).map(|i| view(i, 10.0, 6.0)).collect();
        views[1].forecast = Some(14.0);
        for v in views.iter_mut().skip(4) {
            v.committed = 2.0; // group 1 holds the slack
        }
        let mut residents: Vec<(usize, f64, usize)> =
            (0..8).map(|i| (i, views[i].committed, i)).collect();
        // Shard 1's 6 FPS committed = a 1-FPS pinned stream + this 5-FPS
        // one; its forecast projects the total ramping to 14.
        residents[1] = (1, 1.0, 1);
        residents.push((8, 5.0, 1));
        let (moves, stats) = plan_grouped(&views, &residents, 4);
        assert_eq!(stats.groups_descended, 2);
        // Shedding the 5-FPS stream brings projected load (14 − 5 = 9)
        // back inside the band, onto the slack group's lowest shard.
        assert_eq!(moves, vec![Migration { stream: 8, from: 1, to: 4 }]);
        // Without the forecast slot nothing is out of band and the
        // planner never descends at all.
        views[1].forecast = None;
        let (moves, stats) = plan_grouped(&views, &residents, 4);
        assert!(moves.is_empty());
        assert_eq!(stats.groups_descended, 0);
        assert_eq!(stats.shards_examined, 0);
    }

    #[test]
    fn prop_one_group_spanning_the_fleet_is_the_flat_planner() {
        check("one group == flat", Config::default(), |rng| {
            let m = rng.int_in(2, 12) as usize;
            let mut views = Vec::new();
            let mut residents = Vec::new();
            let mut next_stream = 0usize;
            for shard in 0..m {
                let capacity = rng.range(5.0, 15.0);
                let mut committed = 0.0;
                for _ in 0..rng.int_in(0, 4) {
                    let demand = rng.range(0.5, 6.0);
                    residents.push((next_stream, demand, shard));
                    committed += demand;
                    next_stream += 1;
                }
                views.push(ShardView {
                    shard,
                    alive: rng.chance(0.9),
                    capacity,
                    committed,
                    forecast: None,
                });
            }
            let (flat_moves, _) = plan_flat(&views, &residents);
            // One group spanning the fleet descends iff anything is out
            // of band, and then the candidate set is every shard.
            let (grouped_moves, stats) = plan_grouped(&views, &residents, m);
            if grouped_moves != flat_moves {
                return Err(format!("{grouped_moves:?} != {flat_moves:?}"));
            }
            if !flat_moves.is_empty() && stats.shards_examined != m {
                return Err(format!(
                    "single group with moves should examine all {m} shards, examined {}",
                    stats.shards_examined
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_grouped_moves_restore_the_band_no_worse_than_masked_flat() {
        // Safety, not optimality: every grouped move is one the flat
        // planner could have made (same shared plan_moves), and no move
        // pushes a target out of band.
        check("grouped moves are band-safe", Config::default(), |rng| {
            let m = rng.int_in(4, 16) as usize;
            let k = rng.int_in(2, 5) as usize;
            let mut views = Vec::new();
            let mut residents = Vec::new();
            let mut next_stream = 0usize;
            for shard in 0..m {
                let capacity = rng.range(5.0, 15.0);
                let mut committed = 0.0;
                for _ in 0..rng.int_in(0, 5) {
                    let demand = rng.range(0.5, 6.0);
                    residents.push((next_stream, demand, shard));
                    committed += demand;
                    next_stream += 1;
                }
                views.push(ShardView {
                    shard,
                    alive: true,
                    capacity,
                    committed,
                    forecast: None,
                });
            }
            let (moves, _) = plan_grouped(&views, &residents, k);
            let mut after = views.clone();
            for mv in &moves {
                let demand = residents
                    .iter()
                    .find(|&&(idx, _, _)| idx == mv.stream)
                    .map(|&(_, d, _)| d)
                    .ok_or_else(|| format!("move of unknown stream {}", mv.stream))?;
                after[mv.from].committed -= demand;
                after[mv.to].committed += demand;
                if !after[mv.to].in_band() {
                    return Err(format!("move {mv:?} pushed target out of band"));
                }
            }
            Ok(())
        });
    }
}
