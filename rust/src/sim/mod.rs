//! Discrete-event simulation kernel: a virtual clock plus a time-ordered
//! event heap. The coordinator's virtual-time pipeline is built on this,
//! which is what lets a full paper table (≈160 online-detection runs)
//! regenerate in milliseconds instead of hours of wall clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type SimTime = f64;

/// An event heap entry. Ordered by time (earliest first), then by a
/// monotone sequence number so same-time events preserve push order —
/// determinism matters for reproducible experiments.
struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue / clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
        }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Scheduling in the past is
    /// a logic error and panics (it would silently reorder causality).
    pub fn schedule(&mut self, at: SimTime, event: E) {
        assert!(
            at >= self.now - 1e-12,
            "scheduling into the past: {at} < {}",
            self.now
        );
        self.heap.push(Entry {
            time: at.max(self.now),
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after a delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        assert!(delay >= 0.0);
        let at = self.now + delay;
        self.heap.push(Entry {
            time: at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, ["a", "b", "c"]);
    }

    #[test]
    fn ties_broken_by_push_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, [1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(2.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 7.5);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        q.schedule(1.0, ());
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "first");
        let (_, e) = q.pop().unwrap();
        assert_eq!(e, "first");
        q.schedule_in(1.0, "second");
        q.schedule_in(0.5, "before-second");
        let (_, e) = q.pop().unwrap();
        assert_eq!(e, "before-second");
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (2.0, "second"));
        assert!(q.is_empty());
    }
}
