//! Length-prefixed, versioned frame codec for [`TransportMsg`]s.
//!
//! One frame on the wire is an 8-byte header followed by the payload
//! (all integers big-endian):
//!
//! ```text
//!  offset  size  field
//!  0       2     magic  0x45 0x56  ("EV")
//!  2       1     codec version: FRAME_VERSION (JSON payload) or
//!                FRAME_VERSION_BINARY (control::binary payload)
//!  3       1     reserved (written 0, ignored on read)
//!  4       4     payload length in bytes (u32)
//!  8       len   payload: TransportMsg::encode() JSON, or
//!                control::binary::encode_msg() bytes
//! ```
//!
//! The version byte selects the payload [`Codec`] *per frame*, so a
//! session can switch codecs mid-stream (the coordinator speaks first;
//! [`crate::transport::net::FrameConn`] answers in whatever codec the
//! last received frame used). Both codecs decode to the identical
//! [`TransportMsg`] — exact parity is property-tested here and in
//! [`crate::control::binary`].
//!
//! [`FrameDecoder`] is an incremental state machine fed from `read()`
//! return slices, so the adversarial realities of a stream socket are
//! handled explicitly rather than assumed away:
//!
//! * **split frames / truncated prefixes** — any byte of the header or
//!   payload may arrive in its own `read()`; the decoder buffers and
//!   reports "need more bytes" (`Ok(None)`), never an error, until a
//!   frame is complete (property-tested over random split points);
//! * **oversized lengths** — a length prefix above
//!   [`MAX_PAYLOAD_BYTES`] is rejected *before* buffering the payload,
//!   so a corrupt or hostile peer cannot make the decoder allocate
//!   gigabytes;
//! * **version mismatch** — a frame stamped with a different codec
//!   version is rejected at the header;
//! * **garbage between frames** — bytes after a valid frame that do not
//!   begin with the magic are rejected as soon as they are seen.
//!
//! All decode failures are fatal for the stream (framing is lost); the
//! session layer surfaces them as peer loss — but not *silently*: the
//! decoder keeps per-cause [`DecoderStats`] (bad magic, version
//! mismatch, oversized, payload errors) so a run report can distinguish
//! "the peer went away" from "the peer spoke garbage".

use std::fmt;

use crate::control::binary;
use crate::transport::msg::TransportMsg;

/// First two bytes of every frame ("EV").
pub const FRAME_MAGIC: [u8; 2] = [0x45, 0x56];

/// Frame version for JSON payloads (the audit/debug codec).
pub const FRAME_VERSION: u8 = 1;

/// Frame version for compact binary payloads
/// ([`crate::control::binary`]); decoders reject anything but these two.
pub const FRAME_VERSION_BINARY: u8 = 2;

/// Header size in bytes (magic + version + reserved + u32 length).
pub const HEADER_BYTES: usize = 8;

/// Default maximum payload a peer may declare (1 MiB — the largest
/// common message, a many-stream epoch slice with latencies, is a few
/// hundred KiB). Group-aggregate snapshot frames at very large fleet
/// sizes can legitimately exceed this; raise the cap per decoder with
/// [`FrameDecoder::with_max_payload`] / per encode with
/// [`encode_frame_with`].
pub const MAX_PAYLOAD_BYTES: usize = 1 << 20;

/// Payload codec carried by a frame's version byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// UTF-8 JSON ([`TransportMsg::encode`]) — the audit/debug format.
    #[default]
    Json,
    /// Compact binary ([`crate::control::binary::encode_msg`]).
    Binary,
}

impl Codec {
    /// CLI / report label.
    pub fn label(&self) -> &'static str {
        match self {
            Codec::Json => "json",
            Codec::Binary => "binary",
        }
    }

    /// Parse a CLI label.
    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "json" => Some(Codec::Json),
            "binary" => Some(Codec::Binary),
            _ => None,
        }
    }

    /// The frame version byte announcing this codec.
    pub fn frame_version(&self) -> u8 {
        match self {
            Codec::Json => FRAME_VERSION,
            Codec::Binary => FRAME_VERSION_BINARY,
        }
    }

    fn from_frame_version(v: u8) -> Option<Codec> {
        match v {
            FRAME_VERSION => Some(Codec::Json),
            FRAME_VERSION_BINARY => Some(Codec::Binary),
            _ => None,
        }
    }
}

/// Fatal framing failure: the byte stream is not (or no longer) a valid
/// frame sequence.
#[derive(Debug, Clone, PartialEq)]
pub enum FrameError {
    /// The next two bytes are not [`FRAME_MAGIC`].
    BadMagic { got: [u8; 2] },
    /// The frame's codec version is neither [`FRAME_VERSION`] nor
    /// [`FRAME_VERSION_BINARY`].
    Version { got: u8 },
    /// The declared payload length exceeds the decoder's cap
    /// ([`MAX_PAYLOAD_BYTES`] unless raised).
    Oversized { len: usize },
    /// The payload is not a valid [`TransportMsg`] (bad UTF-8, bad JSON,
    /// or an unknown/malformed message).
    Payload(String),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic { got } => {
                write!(f, "bad frame magic {:#04x} {:#04x}", got[0], got[1])
            }
            FrameError::Version { got } => {
                write!(
                    f,
                    "unsupported frame version {got} (expected {FRAME_VERSION} or {FRAME_VERSION_BINARY})"
                )
            }
            FrameError::Oversized { len } => {
                write!(f, "frame payload of {len} bytes exceeds the payload cap")
            }
            FrameError::Payload(msg) => write!(f, "bad frame payload: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Encode one message as a complete JSON frame at the default payload
/// cap. See [`encode_frame_with`] for codec/cap control.
pub fn encode_frame(msg: &TransportMsg) -> Result<Vec<u8>, FrameError> {
    encode_frame_with(msg, Codec::Json, MAX_PAYLOAD_BYTES)
}

/// Encode one message as a complete frame (header + payload) in the
/// given codec. A payload above `max_payload` is an error, not a panic
/// — an oversized message (e.g. a pathological epoch slice) must
/// surface as a session failure the caller can handle, mirroring the
/// decode side.
pub fn encode_frame_with(
    msg: &TransportMsg,
    codec: Codec,
    max_payload: usize,
) -> Result<Vec<u8>, FrameError> {
    let payload = match codec {
        Codec::Json => msg.encode().into_bytes(),
        Codec::Binary => binary::encode_msg(msg),
    };
    if payload.len() > max_payload {
        return Err(FrameError::Oversized { len: payload.len() });
    }
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(codec.frame_version());
    out.push(0);
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Per-cause decode accounting, updated by [`FrameDecoder::feed`] and
/// [`FrameDecoder::try_next`]. Counters saturate rather than wrap.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DecoderStats {
    /// Complete frames successfully decoded.
    pub frames_decoded: u64,
    /// Raw bytes handed to [`FrameDecoder::feed`].
    pub bytes_fed: u64,
    /// Streams that desynchronised (bytes that cannot start a frame).
    pub bad_magic: u64,
    /// Frames stamped with an unknown codec version.
    pub version_mismatch: u64,
    /// Length prefixes above the decoder's payload cap.
    pub oversized: u64,
    /// Complete frames whose payload was not a valid [`TransportMsg`].
    pub payload_errors: u64,
}

impl DecoderStats {
    /// Total decode failures across every cause.
    pub fn errors(&self) -> u64 {
        self.bad_magic
            .saturating_add(self.version_mismatch)
            .saturating_add(self.oversized)
            .saturating_add(self.payload_errors)
    }
}

/// Incremental frame decoder; feed it whatever `read()` returned and
/// drain complete messages with [`FrameDecoder::try_next`].
#[derive(Debug)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    stats: DecoderStats,
    max_payload: usize,
    last_codec: Codec,
}

impl Default for FrameDecoder {
    fn default() -> FrameDecoder {
        FrameDecoder {
            buf: Vec::new(),
            stats: DecoderStats::default(),
            max_payload: MAX_PAYLOAD_BYTES,
            last_codec: Codec::Json,
        }
    }
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// A decoder accepting payloads up to `max_payload` bytes instead of
    /// the [`MAX_PAYLOAD_BYTES`] default (group-aggregate snapshots at
    /// very large fleet sizes can legitimately exceed it). The cap still
    /// applies *before* buffering, so a hostile length prefix never
    /// allocates more than the configured bound.
    pub fn with_max_payload(max_payload: usize) -> FrameDecoder {
        FrameDecoder {
            max_payload,
            ..FrameDecoder::default()
        }
    }

    /// This decoder's payload cap in bytes.
    pub fn max_payload(&self) -> usize {
        self.max_payload
    }

    /// The codec of the most recently decoded frame ([`Codec::Json`]
    /// before any frame arrives) — lets a responder answer a peer in
    /// whatever codec it speaks.
    pub fn last_codec(&self) -> Codec {
        self.last_codec
    }

    /// Buffer more bytes from the stream.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.stats.bytes_fed = self.stats.bytes_fed.saturating_add(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame. Non-zero
    /// at end-of-stream means the peer died mid-frame.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Decode accounting so far (frames, bytes, per-cause errors).
    pub fn stats(&self) -> DecoderStats {
        self.stats
    }

    /// Decode the next complete frame. `Ok(None)` means the buffer holds
    /// only a frame prefix (possibly empty) — feed more bytes. Errors
    /// are fatal: framing is lost and the stream must be dropped.
    pub fn try_next(&mut self) -> Result<Option<TransportMsg>, FrameError> {
        // Validate magic/version as soon as the bytes exist, so garbage
        // is caught even when the stream ends before a full header.
        if self.buf.len() >= 2 && self.buf[..2] != FRAME_MAGIC {
            self.stats.bad_magic = self.stats.bad_magic.saturating_add(1);
            return Err(FrameError::BadMagic {
                got: [self.buf[0], self.buf[1]],
            });
        }
        let codec = if self.buf.len() >= 3 {
            match Codec::from_frame_version(self.buf[2]) {
                Some(c) => Some(c),
                None => {
                    self.stats.version_mismatch = self.stats.version_mismatch.saturating_add(1);
                    return Err(FrameError::Version { got: self.buf[2] });
                }
            }
        } else {
            None
        };
        if self.buf.len() < HEADER_BYTES {
            return Ok(None);
        }
        let codec = codec.expect("header implies version byte was seen");
        let len = u32::from_be_bytes([self.buf[4], self.buf[5], self.buf[6], self.buf[7]]) as usize;
        if len > self.max_payload {
            self.stats.oversized = self.stats.oversized.saturating_add(1);
            return Err(FrameError::Oversized { len });
        }
        if self.buf.len() < HEADER_BYTES + len {
            return Ok(None);
        }
        let payload = &self.buf[HEADER_BYTES..HEADER_BYTES + len];
        let decoded = match codec {
            Codec::Json => std::str::from_utf8(payload)
                .map_err(|e| FrameError::Payload(format!("payload is not UTF-8: {e}")))
                .and_then(|text| {
                    TransportMsg::decode(text).map_err(|e| FrameError::Payload(e.msg))
                }),
            Codec::Binary => {
                binary::decode_msg(payload).map_err(|e| FrameError::Payload(e.msg))
            }
        };
        let msg = match decoded {
            Ok(msg) => msg,
            Err(e) => {
                self.stats.payload_errors = self.stats.payload_errors.saturating_add(1);
                return Err(e);
            }
        };
        self.buf.drain(..HEADER_BYTES + len);
        self.stats.frames_decoded = self.stats.frames_decoded.saturating_add(1);
        self.last_codec = codec;
        Ok(Some(msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::{ControlAction, ControlOrigin, WireEvent};
    use crate::fleet::admission::AdmissionPolicy;
    use crate::fleet::stream::StreamSpec;
    use crate::transport::msg::{SliceStream, TRANSPORT_VERSION};
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    /// A random message drawn across every variant, with the f64 fields
    /// exercised on awkward fractional values.
    fn arbitrary_msg(rng: &mut Rng) -> TransportMsg {
        match rng.below(10) {
            0 => TransportMsg::Hello {
                shard: rng.below(16) as usize,
                protocol: TRANSPORT_VERSION,
                admission: AdmissionPolicy::default(),
                roster: (0..rng.below(4)).map(|i| format!("cam{i}")).collect(),
                caps: crate::control::caps::SessionCaps {
                    autoscale: rng.chance(0.5).then(|| {
                        crate::autoscale::policy::AutoscaleConfig {
                            cooldown: rng.range(0.5, 30.0),
                            max_devices: rng.below(32) as usize + 1,
                            device_rate: rng.range(0.5, 40.0),
                            target_utilization: rng.range(0.5, 1.0),
                            ..crate::autoscale::policy::AutoscaleConfig::default()
                        }
                    }),
                    gate: rng.chance(0.5).then(|| {
                        let skip = rng.range(0.0, 0.2);
                        crate::gate::GateConfig {
                            skip_threshold: skip,
                            resume_threshold: skip + rng.range(0.0, 0.2),
                            max_skip_run: rng.below(8) + 1,
                            tracker_stretch: rng.range(1.0, 10.0),
                            ..crate::gate::GateConfig::default()
                        }
                    }),
                    telemetry: rng.chance(0.5),
                    token: rng.chance(0.5).then(|| format!("tok{}", rng.below(1000))),
                    forecast: rng.chance(0.4).then(|| crate::forecast::ForecastConfig {
                        alpha: rng.range(0.05, 1.0),
                        period: rng.below(48) as usize,
                        band: rng.range(0.0, 0.5),
                        hold_window: rng.below(6) as usize,
                        ..crate::forecast::ForecastConfig::default()
                    }),
                    ..crate::control::caps::SessionCaps::default()
                },
            },
            1 => TransportMsg::Welcome {
                shard: rng.below(16) as usize,
                capacity: rng.range(0.1, 100.0),
            },
            2 => TransportMsg::Control(WireEvent::action(
                rng.range(0.0, 1e4),
                ControlOrigin::Placement,
                ControlAction::AttachStream(
                    StreamSpec::new(
                        &format!("s{}", rng.below(100)),
                        rng.range(0.1, 60.0),
                        rng.below(10_000),
                    )
                    .with_weight(rng.range(0.1, 8.0)),
                ),
            )),
            3 => TransportMsg::Poll {
                epoch: rng.below(1000) as usize,
                at: rng.range(0.0, 1e4),
            },
            4 => TransportMsg::Digest {
                shard: rng.below(16) as usize,
                at: rng.range(0.0, 1e4),
                capacity: rng.range(0.0, 100.0),
                committed: rng.range(0.0, 100.0),
                forecast: if rng.chance(0.5) {
                    Some(rng.range(0.0, 100.0))
                } else {
                    None
                },
            },
            5 => TransportMsg::Tick {
                epoch: rng.below(1000) as usize,
                at: rng.range(0.0, 1e4),
                seed: rng.next_u64(),
                quotas: (0..rng.below(6) as usize).map(|i| (i, rng.below(500))).collect(),
            },
            6 => TransportMsg::Slice {
                epoch: rng.below(1000) as usize,
                busy: rng.range(0.0, 1e3),
                frames: rng.below(10_000),
                streams: (0..rng.below(4) as usize)
                    .map(|i| SliceStream {
                        id: i,
                        total: rng.below(500),
                        processed: rng.below(500),
                        latencies: (0..rng.below(8)).map(|_| rng.range(0.0, 10.0)).collect(),
                    })
                    .collect(),
            },
            7 => {
                let mut snapshot = crate::telemetry::Registry::new();
                for i in 0..rng.below(3) {
                    snapshot.inc(
                        crate::telemetry::MetricKey::with_labels(
                            "eva_frames_total",
                            &[("stream", &format!("cam{i}"))],
                        ),
                        rng.below(500),
                    );
                }
                for _ in 0..rng.below(8) {
                    snapshot.observe(
                        crate::telemetry::MetricKey::new("eva_e2e_seconds"),
                        rng.range(0.0, 10.0),
                    );
                }
                TransportMsg::Telemetry {
                    shard: rng.below(16) as usize,
                    epoch: rng.below(1000) as usize,
                    snapshot,
                }
            }
            8 => TransportMsg::Reject {
                code: ["auth", "protocol", "quota"][rng.below(3) as usize].to_string(),
                detail: if rng.chance(0.5) {
                    format!("refused at attempt {}", rng.below(10))
                } else {
                    String::new()
                },
            },
            _ => TransportMsg::Bye,
        }
    }

    #[test]
    fn prop_frames_survive_arbitrary_split_points() {
        // Several frames concatenated, delivered in random-sized chunks
        // (including 1-byte reads): the decoder reassembles exactly the
        // encoded sequence, with Ok(None) at every incomplete boundary.
        check("frames survive splits", Config::default(), |rng| {
            let msgs: Vec<TransportMsg> =
                (0..1 + rng.below(4)).map(|_| arbitrary_msg(rng)).collect();
            let mut stream = Vec::new();
            for m in &msgs {
                stream.extend_from_slice(&encode_frame(m).expect("encode"));
            }
            let mut dec = FrameDecoder::new();
            let mut out = Vec::new();
            let mut pos = 0usize;
            while pos < stream.len() {
                let chunk = 1 + rng.below(9) as usize;
                let end = (pos + chunk).min(stream.len());
                dec.feed(&stream[pos..end]);
                pos = end;
                loop {
                    match dec.try_next() {
                        Ok(Some(m)) => out.push(m),
                        Ok(None) => break,
                        Err(e) => return Err(format!("decode failed at byte {pos}: {e}")),
                    }
                }
            }
            if out != msgs {
                return Err(format!("got {} messages, sent {}", out.len(), msgs.len()));
            }
            if dec.buffered() != 0 {
                return Err(format!("{} stray bytes buffered", dec.buffered()));
            }
            let stats = dec.stats();
            if stats.frames_decoded != msgs.len() as u64
                || stats.bytes_fed != stream.len() as u64
                || stats.errors() != 0
            {
                return Err(format!("clean stream mis-counted: {stats:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_truncated_prefix_is_pending_not_error() {
        // A frame cut anywhere — inside the length prefix or the payload
        // — is "need more bytes", never an error; feeding the remainder
        // completes it.
        check("truncation pends", Config::default(), |rng| {
            let msg = arbitrary_msg(rng);
            let frame = encode_frame(&msg).expect("encode");
            let cut = 1 + rng.below(frame.len() as u64 - 1) as usize;
            let mut dec = FrameDecoder::new();
            dec.feed(&frame[..cut]);
            match dec.try_next() {
                Ok(None) => {}
                Ok(Some(_)) => return Err(format!("decoded from {cut}/{} bytes", frame.len())),
                Err(e) => return Err(format!("truncation at {cut} errored: {e}")),
            }
            dec.feed(&frame[cut..]);
            match dec.try_next() {
                Ok(Some(m)) if m == msg => {}
                other => return Err(format!("completion failed: {other:?}")),
            }
            let stats = dec.stats();
            if stats.frames_decoded != 1 || stats.errors() != 0 {
                return Err(format!("truncation mis-counted: {stats:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_oversized_length_is_rejected_before_buffering() {
        check("oversized rejected", Config::default(), |rng| {
            let len = MAX_PAYLOAD_BYTES as u32 + 1 + rng.below(1 << 20) as u32;
            let mut header = Vec::new();
            header.extend_from_slice(&FRAME_MAGIC);
            header.push(FRAME_VERSION);
            header.push(0);
            header.extend_from_slice(&len.to_be_bytes());
            let mut dec = FrameDecoder::new();
            dec.feed(&header);
            match dec.try_next() {
                Err(FrameError::Oversized { len: got }) if got == len as usize => {}
                other => return Err(format!("expected Oversized, got {other:?}")),
            }
            let stats = dec.stats();
            if stats.oversized != 1 || stats.frames_decoded != 0 || stats.errors() != 1 {
                return Err(format!("oversized mis-counted: {stats:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_version_mismatch_is_rejected() {
        check("version rejected", Config::default(), |rng| {
            let mut frame = encode_frame(&arbitrary_msg(rng)).expect("encode");
            let bogus = loop {
                let v = rng.below(256) as u8;
                if v != FRAME_VERSION && v != FRAME_VERSION_BINARY {
                    break v;
                }
            };
            frame[2] = bogus;
            let mut dec = FrameDecoder::new();
            dec.feed(&frame);
            match dec.try_next() {
                Err(FrameError::Version { got }) if got == bogus => {}
                other => return Err(format!("expected Version, got {other:?}")),
            }
            let stats = dec.stats();
            if stats.version_mismatch != 1 || stats.frames_decoded != 0 {
                return Err(format!("version mismatch mis-counted: {stats:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_garbage_after_valid_frame_is_rejected() {
        check("garbage rejected", Config::default(), |rng| {
            let msg = arbitrary_msg(rng);
            let mut stream = encode_frame(&msg).expect("encode");
            // Garbage that cannot start a frame (first byte != magic[0]).
            let mut garbage: Vec<u8> =
                (0..2 + rng.below(16)).map(|_| rng.below(256) as u8).collect();
            if garbage[0] == FRAME_MAGIC[0] {
                garbage[0] ^= 0xFF;
            }
            stream.extend_from_slice(&garbage);
            let mut dec = FrameDecoder::new();
            dec.feed(&stream);
            match dec.try_next() {
                Ok(Some(m)) if m == msg => {}
                other => return Err(format!("valid frame lost: {other:?}")),
            }
            match dec.try_next() {
                Err(FrameError::BadMagic { .. }) => {}
                other => return Err(format!("expected BadMagic after frame, got {other:?}")),
            }
            let stats = dec.stats();
            if stats.frames_decoded != 1 || stats.bad_magic != 1 || stats.errors() != 1 {
                return Err(format!("garbage mis-counted: {stats:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn corrupt_payload_is_a_payload_error() {
        // Valid header, declared length, but the payload is not a
        // transport message.
        let payload = b"{\"msg\":\"nonsense\"}";
        let mut frame = Vec::new();
        frame.extend_from_slice(&FRAME_MAGIC);
        frame.push(FRAME_VERSION);
        frame.push(0);
        frame.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        frame.extend_from_slice(payload);
        let mut dec = FrameDecoder::new();
        dec.feed(&frame);
        assert!(matches!(dec.try_next(), Err(FrameError::Payload(_))));
        assert_eq!(dec.stats().payload_errors, 1);
        assert_eq!(dec.stats().frames_decoded, 0);
        // Non-UTF-8 payloads likewise.
        let mut frame = Vec::new();
        frame.extend_from_slice(&FRAME_MAGIC);
        frame.push(FRAME_VERSION);
        frame.push(0);
        frame.extend_from_slice(&2u32.to_be_bytes());
        frame.extend_from_slice(&[0xFF, 0xFE]);
        let mut dec = FrameDecoder::new();
        dec.feed(&frame);
        assert!(matches!(dec.try_next(), Err(FrameError::Payload(_))));
        assert_eq!(dec.stats().payload_errors, 1);
        assert_eq!(dec.stats().bytes_fed, frame.len() as u64);
    }

    #[test]
    fn prop_binary_frames_decode_to_the_identical_message() {
        // Frame-level exact parity: the same message encoded in both
        // codecs decodes to equal values, and the decoder reports which
        // codec each frame used.
        check("binary frame parity", Config::default(), |rng| {
            let msg = arbitrary_msg(rng);
            let json_frame = encode_frame_with(&msg, Codec::Json, MAX_PAYLOAD_BYTES)
                .map_err(|e| e.to_string())?;
            let bin_frame = encode_frame_with(&msg, Codec::Binary, MAX_PAYLOAD_BYTES)
                .map_err(|e| e.to_string())?;
            let mut dec = FrameDecoder::new();
            dec.feed(&json_frame);
            let from_json = dec
                .try_next()
                .map_err(|e| e.to_string())?
                .ok_or("json frame incomplete")?;
            if dec.last_codec() != Codec::Json {
                return Err(format!("expected Json, saw {:?}", dec.last_codec()));
            }
            dec.feed(&bin_frame);
            let from_bin = dec
                .try_next()
                .map_err(|e| e.to_string())?
                .ok_or("binary frame incomplete")?;
            if dec.last_codec() != Codec::Binary {
                return Err(format!("expected Binary, saw {:?}", dec.last_codec()));
            }
            if from_json != msg || from_bin != msg {
                return Err("codec divergence".to_string());
            }
            if from_bin != from_json {
                return Err(format!("{from_bin:?} != {from_json:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_binary_frames_survive_arbitrary_split_points() {
        // The incremental decoder handles binary payloads byte-by-byte
        // exactly as it does JSON ones, including mixed-codec streams.
        check("binary frames survive splits", Config::default(), |rng| {
            let msgs: Vec<TransportMsg> =
                (0..1 + rng.below(4)).map(|_| arbitrary_msg(rng)).collect();
            let mut stream = Vec::new();
            let mut codecs = Vec::new();
            for m in &msgs {
                let codec = if rng.chance(0.5) { Codec::Binary } else { Codec::Json };
                codecs.push(codec);
                stream.extend_from_slice(
                    &encode_frame_with(m, codec, MAX_PAYLOAD_BYTES).expect("encode"),
                );
            }
            let mut dec = FrameDecoder::new();
            let mut out = Vec::new();
            let mut pos = 0usize;
            while pos < stream.len() {
                let chunk = 1 + rng.below(9) as usize;
                let end = (pos + chunk).min(stream.len());
                dec.feed(&stream[pos..end]);
                pos = end;
                loop {
                    match dec.try_next() {
                        Ok(Some(m)) => {
                            if dec.last_codec() != codecs[out.len()] {
                                return Err(format!(
                                    "frame {} codec {:?} != sent {:?}",
                                    out.len(),
                                    dec.last_codec(),
                                    codecs[out.len()]
                                ));
                            }
                            out.push(m);
                        }
                        Ok(None) => break,
                        Err(e) => return Err(format!("decode failed at byte {pos}: {e}")),
                    }
                }
            }
            if out != msgs {
                return Err(format!("got {} messages, sent {}", out.len(), msgs.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn payload_cap_is_configurable_but_defaults_hold() {
        // A frame bigger than the default cap is rejected by a default
        // decoder and accepted by one with a raised cap — and the raised
        // cap still rejects lengths above itself before buffering.
        let big = TransportMsg::Slice {
            epoch: 1,
            busy: 1.0,
            frames: 1,
            streams: (0..24_000)
                .map(|i| SliceStream {
                    id: i,
                    total: 1_000_000 + i as u64,
                    processed: 999_999,
                    latencies: vec![0.123456789, 1.23456789e-3],
                })
                .collect(),
        };
        let cap = 8 << 20;
        assert!(matches!(
            encode_frame(&big),
            Err(FrameError::Oversized { .. })
        ));
        let frame = encode_frame_with(&big, Codec::Json, cap).expect("raised-cap encode");
        assert!(frame.len() > MAX_PAYLOAD_BYTES);

        let mut strict = FrameDecoder::new();
        strict.feed(&frame);
        assert!(matches!(strict.try_next(), Err(FrameError::Oversized { .. })));
        assert_eq!(strict.stats().oversized, 1);

        let mut wide = FrameDecoder::with_max_payload(cap);
        assert_eq!(wide.max_payload(), cap);
        wide.feed(&frame);
        assert_eq!(wide.try_next().expect("decode"), Some(big));

        let mut header = Vec::new();
        header.extend_from_slice(&FRAME_MAGIC);
        header.push(FRAME_VERSION);
        header.push(0);
        header.extend_from_slice(&((cap as u32) + 1).to_be_bytes());
        let mut wide = FrameDecoder::with_max_payload(cap);
        wide.feed(&header);
        assert!(matches!(wide.try_next(), Err(FrameError::Oversized { .. })));
    }

    #[test]
    fn error_messages_render() {
        assert!(FrameError::BadMagic { got: [0, 1] }.to_string().contains("magic"));
        assert!(FrameError::Version { got: 9 }.to_string().contains("version 9"));
        assert!(FrameError::Oversized { len: 1 << 30 }.to_string().contains("cap"));
        assert!(FrameError::Payload("x".into()).to_string().contains("payload"));
    }
}
