//! Cross-host transport for the serialisable control plane.
//!
//! [`crate::control`] made every control decision a JSON document; this
//! layer makes those documents travel between processes. Four pieces,
//! bottom-up:
//!
//! * [`frame`] — the length-prefixed, versioned frame codec: an 8-byte
//!   header (magic, codec version, u32 payload length) around one
//!   payload — JSON ([`frame::Codec::Json`], the audit format) or
//!   compact binary ([`frame::Codec::Binary`],
//!   [`crate::control::binary`]) selected per frame by the version byte
//!   — with an incremental decoder that handles split frames, truncated
//!   prefixes, oversized-length rejection (configurable cap), version
//!   mismatch and garbage between frames (property-tested).
//! * [`msg`] — the session vocabulary ([`TransportMsg`]): control
//!   traffic is always a [`crate::control::WireEvent`] inside a
//!   `Control` frame; around it sit the handshake (`Hello`/`Welcome`),
//!   the per-epoch gossip (`Poll`/`Digest`), the epoch-slice exchange
//!   (`Tick`/`Slice`) and the goodbye (`Bye`).
//! * [`net`] — blocking sockets over `std::net` TCP and Unix-domain
//!   sockets: framed connections with read deadlines, peer-loss
//!   surfacing (clean vs mid-frame close) and a dial-with-backoff
//!   client. No async runtime, no new dependencies.
//! * [`serve`] — the remote wall-clock consumer: a `fleet::serve`
//!   process driven by a decoded [`crate::control::EventLog`] stream
//!   instead of in-process calls.
//!
//! The remote *virtual-time* driver — each shard of the co-simulation
//! behind its own socket — lives in [`crate::shard::remote`], next to
//! the in-process runner whose semantics it mirrors.

pub mod frame;
pub mod msg;
pub mod net;
pub mod serve;

pub use frame::{
    encode_frame, encode_frame_with, Codec, DecoderStats, FrameDecoder, FrameError,
    FRAME_VERSION, FRAME_VERSION_BINARY, MAX_PAYLOAD_BYTES,
};
pub use msg::{SliceStream, TransportMsg, TRANSPORT_VERSION};
pub use net::{
    connect, connect_with_backoff, ConnStats, Endpoint, FrameConn, Listener, TransportError,
};
pub use serve::{
    drive_remote_serve, run_serve_consumer, serve_from_log, specs_from_log, RemoteServeOutcome,
};
