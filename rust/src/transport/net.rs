//! Blocking socket transport: TCP and Unix-domain endpoints, a framed
//! connection type, and a dial-with-backoff client.
//!
//! Everything is `std::net` / `std::os::unix::net` — no async runtime,
//! no new dependencies. One [`FrameConn`] wraps one stream socket with a
//! [`FrameDecoder`]; [`FrameConn::recv`] blocks until a complete frame
//! arrives (handling partial reads and split frames) and surfaces peer
//! loss as [`TransportError::PeerClosed`], distinguishing a clean close
//! from one that truncated a frame in flight. A default 30-second read
//! deadline keeps a wedged peer from hanging a blocking session forever;
//! the session layer treats the timeout like any other peer loss.
//!
//! [`connect_with_backoff`] is the client side: it retries a refused
//! dial with doubling sleeps, because in a real deployment (and in the
//! tests here) the coordinator usually races the shard processes' bind.

use std::fmt;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use crate::transport::frame::{
    encode_frame_with, Codec, DecoderStats, FrameDecoder, FrameError, MAX_PAYLOAD_BYTES,
};
use crate::transport::msg::TransportMsg;

/// Default blocking-read deadline on accepted/dialled sockets.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// Where a transport peer listens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP address, e.g. `127.0.0.1:0` (loopback, ephemeral port).
    Tcp(String),
    /// Unix-domain socket path.
    Uds(PathBuf),
}

impl Endpoint {
    /// Loopback TCP on an ephemeral port (the default for local runs).
    pub fn loopback() -> Endpoint {
        Endpoint::Tcp("127.0.0.1:0".to_string())
    }

    /// A fresh Unix-domain socket path under the system temp dir, unique
    /// within and across processes.
    pub fn temp_uds(tag: &str) -> Endpoint {
        static COUNTER: AtomicUsize = AtomicUsize::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        Endpoint::Uds(std::env::temp_dir().join(format!(
            "eva-{tag}-{}-{n}.sock",
            std::process::id()
        )))
    }

    pub fn label(&self) -> String {
        match self {
            Endpoint::Tcp(addr) => format!("tcp://{addr}"),
            Endpoint::Uds(path) => format!("uds://{}", path.display()),
        }
    }
}

/// Transport failure as the session layer sees it.
#[derive(Debug)]
pub enum TransportError {
    /// The peer closed the connection. `mid_frame` is true when the
    /// close truncated a frame in flight (bytes were buffered).
    PeerClosed { mid_frame: bool },
    /// Framing was lost (bad magic/version/length/payload).
    Frame(FrameError),
    /// Socket-level failure (includes read-deadline expiry).
    Io(std::io::Error),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::PeerClosed { mid_frame: true } => {
                write!(f, "peer closed the connection mid-frame")
            }
            TransportError::PeerClosed { mid_frame: false } => {
                write!(f, "peer closed the connection")
            }
            TransportError::Frame(e) => write!(f, "framing lost: {e}"),
            TransportError::Io(e) => write!(f, "socket error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<FrameError> for TransportError {
    fn from(e: FrameError) -> TransportError {
        TransportError::Frame(e)
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Stream {
    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            Stream::Uds(s) => s.set_read_timeout(t),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Uds(s) => s.flush(),
        }
    }
}

/// Per-connection traffic accounting: what this side sent plus the
/// receive decoder's [`DecoderStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConnStats {
    /// Frames written (send side).
    pub sent_frames: u64,
    /// Bytes written, headers included (send side).
    pub sent_bytes: u64,
    /// Receive-side decode accounting.
    pub recv: DecoderStats,
}

/// One framed, blocking transport connection.
pub struct FrameConn {
    stream: Stream,
    decoder: FrameDecoder,
    codec: Codec,
    sent_frames: u64,
    sent_bytes: u64,
}

impl FrameConn {
    fn new(stream: Stream) -> std::io::Result<FrameConn> {
        stream.set_read_timeout(Some(DEFAULT_TIMEOUT))?;
        Ok(FrameConn {
            stream,
            decoder: FrameDecoder::new(),
            codec: Codec::Json,
            sent_frames: 0,
            sent_bytes: 0,
        })
    }

    /// Override the blocking-read deadline (`None` blocks forever).
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(t)
    }

    /// Switch the payload codec for frames *sent* on this connection
    /// (the decoder always accepts both). Defaults to JSON for audit
    /// compatibility.
    pub fn set_codec(&mut self, codec: Codec) {
        self.codec = codec;
    }

    /// The codec frames are currently sent in.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// The codec of the most recently received frame — a responder can
    /// mirror it ([`FrameConn::set_codec`]) to answer a peer in whatever
    /// codec it speaks, without any handshake field.
    pub fn last_recv_codec(&self) -> Codec {
        self.decoder.last_codec()
    }

    /// Traffic accounting so far, both directions.
    pub fn stats(&self) -> ConnStats {
        ConnStats {
            sent_frames: self.sent_frames,
            sent_bytes: self.sent_bytes,
            recv: self.decoder.stats(),
        }
    }

    /// Send one message as a frame (write-all + flush) in the
    /// connection's current codec.
    pub fn send(&mut self, msg: &TransportMsg) -> Result<(), TransportError> {
        let frame = encode_frame_with(msg, self.codec, MAX_PAYLOAD_BYTES)?;
        self.stream.write_all(&frame)?;
        self.stream.flush()?;
        self.sent_frames = self.sent_frames.saturating_add(1);
        self.sent_bytes = self.sent_bytes.saturating_add(frame.len() as u64);
        Ok(())
    }

    /// Block until one complete message arrives. Frames split across any
    /// number of reads reassemble; a peer close surfaces as
    /// [`TransportError::PeerClosed`] with the mid-frame flag set when
    /// buffered bytes were abandoned.
    pub fn recv(&mut self) -> Result<TransportMsg, TransportError> {
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(msg) = self.decoder.try_next()? {
                return Ok(msg);
            }
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(TransportError::PeerClosed {
                    mid_frame: self.decoder.buffered() > 0,
                });
            }
            self.decoder.feed(&chunk[..n]);
        }
    }
}

/// A bound transport listener (server side).
pub enum Listener {
    Tcp(TcpListener),
    Uds(UnixListener, PathBuf),
}

impl Listener {
    /// Bind to an endpoint. TCP `:0` picks an ephemeral port — read the
    /// actual address back with [`Listener::local_endpoint`]. A stale
    /// UDS path from a dead process is removed before binding.
    pub fn bind(endpoint: &Endpoint) -> std::io::Result<Listener> {
        match endpoint {
            Endpoint::Tcp(addr) => Ok(Listener::Tcp(TcpListener::bind(addr.as_str())?)),
            Endpoint::Uds(path) => {
                let _ = std::fs::remove_file(path);
                Ok(Listener::Uds(UnixListener::bind(path)?, path.clone()))
            }
        }
    }

    /// The endpoint peers should dial (with ephemeral ports resolved).
    pub fn local_endpoint(&self) -> std::io::Result<Endpoint> {
        match self {
            Listener::Tcp(l) => Ok(Endpoint::Tcp(l.local_addr()?.to_string())),
            Listener::Uds(_, path) => Ok(Endpoint::Uds(path.clone())),
        }
    }

    /// Block until one peer connects.
    pub fn accept(&self) -> std::io::Result<FrameConn> {
        match self {
            Listener::Tcp(l) => {
                let (stream, _) = l.accept()?;
                FrameConn::new(Stream::Tcp(stream))
            }
            Listener::Uds(l, _) => {
                let (stream, _) = l.accept()?;
                FrameConn::new(Stream::Uds(stream))
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Dial an endpoint once.
pub fn connect(endpoint: &Endpoint) -> std::io::Result<FrameConn> {
    match endpoint {
        Endpoint::Tcp(addr) => FrameConn::new(Stream::Tcp(TcpStream::connect(addr.as_str())?)),
        Endpoint::Uds(path) => FrameConn::new(Stream::Uds(UnixStream::connect(path)?)),
    }
}

/// Dial with exponential backoff: up to `attempts` tries, sleeping
/// `initial` and doubling between them (so the coordinator may start
/// before its shards finish binding). Returns the last error when every
/// attempt fails.
pub fn connect_with_backoff(
    endpoint: &Endpoint,
    attempts: u32,
    initial: Duration,
) -> Result<FrameConn, TransportError> {
    let mut delay = initial;
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..attempts.max(1) {
        match connect(endpoint) {
            Ok(conn) => return Ok(conn),
            Err(e) => last = Some(e),
        }
        if attempt + 1 < attempts.max(1) {
            std::thread::sleep(delay);
            delay = delay.saturating_mul(2);
        }
    }
    Err(TransportError::Io(last.unwrap_or_else(|| {
        std::io::Error::other("no connection attempts made")
    })))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::msg::TransportMsg;

    fn ping(epoch: usize) -> TransportMsg {
        TransportMsg::Poll {
            epoch,
            at: epoch as f64,
        }
    }

    fn echo_server(listener: Listener, frames: usize) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            for _ in 0..frames {
                let msg = conn.recv().expect("server recv");
                conn.send(&msg).expect("server send");
            }
        })
    }

    #[test]
    fn tcp_loopback_roundtrip() {
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let server = echo_server(listener, 3);
        let mut conn = connect(&endpoint).expect("connect");
        for epoch in 0..3 {
            conn.send(&ping(epoch)).expect("send");
            assert_eq!(conn.recv().expect("recv"), ping(epoch));
        }
        server.join().unwrap();
        // Both directions are accounted: 3 frames out, 3 echoed back.
        let stats = conn.stats();
        assert_eq!(stats.sent_frames, 3);
        assert_eq!(stats.recv.frames_decoded, 3);
        assert_eq!(stats.recv.errors(), 0);
        assert!(stats.sent_bytes > 3 * crate::transport::frame::HEADER_BYTES as u64);
        assert_eq!(stats.recv.bytes_fed, stats.sent_bytes, "echo symmetry");
    }

    #[test]
    fn uds_roundtrip_and_path_cleanup() {
        let endpoint = Endpoint::temp_uds("net-test");
        let path = match &endpoint {
            Endpoint::Uds(p) => p.clone(),
            _ => unreachable!(),
        };
        {
            let listener = Listener::bind(&endpoint).expect("bind");
            let server = echo_server(listener, 1);
            let mut conn = connect(&endpoint).expect("connect");
            conn.send(&ping(7)).expect("send");
            assert_eq!(conn.recv().expect("recv"), ping(7));
            server.join().unwrap();
        }
        // Listener drop removed the socket file.
        assert!(!path.exists(), "stale socket at {}", path.display());
    }

    #[test]
    fn responder_mirrors_the_codec_the_peer_speaks() {
        // The client switches to binary mid-session; the echo server
        // mirrors whatever codec the last received frame used, with no
        // handshake field involved.
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            for _ in 0..2 {
                let msg = conn.recv().expect("server recv");
                conn.set_codec(conn.last_recv_codec());
                conn.send(&msg).expect("server send");
            }
        });
        let mut conn = connect(&endpoint).expect("connect");
        assert_eq!(conn.codec(), Codec::Json);
        conn.send(&ping(0)).expect("send json");
        assert_eq!(conn.recv().expect("recv"), ping(0));
        assert_eq!(conn.last_recv_codec(), Codec::Json);
        conn.set_codec(Codec::Binary);
        conn.send(&ping(1)).expect("send binary");
        assert_eq!(conn.recv().expect("recv"), ping(1));
        assert_eq!(conn.last_recv_codec(), Codec::Binary, "reply not mirrored");
        server.join().unwrap();
        assert_eq!(conn.stats().recv.errors(), 0);
    }

    #[test]
    fn peer_loss_is_surfaced_and_flags_mid_frame() {
        // Clean close: PeerClosed { mid_frame: false }.
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let server = std::thread::spawn(move || {
            let _conn = listener.accept().expect("accept");
            // Dropped immediately: clean close.
        });
        let mut conn = connect(&endpoint).expect("connect");
        server.join().unwrap();
        match conn.recv() {
            Err(TransportError::PeerClosed { mid_frame: false }) => {}
            other => panic!("expected clean PeerClosed, got {other:?}"),
        }

        // Mid-frame close: the peer writes half a frame and dies.
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            let frame = crate::transport::frame::encode_frame(&ping(0)).expect("encode");
            match &mut conn.stream {
                Stream::Tcp(s) => {
                    s.write_all(&frame[..frame.len() / 2]).expect("half write");
                    s.flush().expect("flush");
                }
                _ => unreachable!(),
            }
            // Drop: close with a truncated frame in flight.
        });
        let mut conn = connect(&endpoint).expect("connect");
        server.join().unwrap();
        match conn.recv() {
            Err(TransportError::PeerClosed { mid_frame: true }) => {}
            other => panic!("expected mid-frame PeerClosed, got {other:?}"),
        }
    }

    #[test]
    fn garbage_on_the_socket_is_a_frame_error() {
        let listener = Listener::bind(&Endpoint::loopback()).expect("bind");
        let endpoint = listener.local_endpoint().expect("endpoint");
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            match &mut conn.stream {
                Stream::Tcp(s) => {
                    s.write_all(b"GET / HTTP/1.1\r\n").expect("write");
                    s.flush().expect("flush");
                }
                _ => unreachable!(),
            }
        });
        let mut conn = connect(&endpoint).expect("connect");
        server.join().unwrap();
        match conn.recv() {
            Err(TransportError::Frame(FrameError::BadMagic { .. })) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn backoff_client_wins_a_race_with_a_slow_bind() {
        // The UDS path is known before anything binds: dial first, bind
        // 40 ms later — the backoff client connects on a retry.
        let endpoint = Endpoint::temp_uds("late-bind");
        let ep = endpoint.clone();
        let server = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            let listener = Listener::bind(&ep).expect("bind");
            let mut conn = listener.accept().expect("accept");
            let msg = conn.recv().expect("recv");
            conn.send(&msg).expect("send");
        });
        let mut conn = connect_with_backoff(&endpoint, 8, Duration::from_millis(10))
            .expect("backoff connect");
        conn.send(&ping(1)).expect("send");
        assert_eq!(conn.recv().expect("recv"), ping(1));
        server.join().unwrap();

        // And a dead endpoint still fails after the attempts run out.
        let nowhere = Endpoint::temp_uds("nowhere");
        assert!(matches!(
            connect_with_backoff(&nowhere, 2, Duration::from_millis(1)),
            Err(TransportError::Io(_))
        ));
    }
}
