//! Remote wall-clock serving: a `fleet::serve` consumer driven by a
//! decoded [`EventLog`] stream instead of in-process calls.
//!
//! The wall-clock engine ([`crate::fleet::serve::serve_fleet_logged`])
//! already *emits* its control plane as wire events; this module closes
//! the loop on the *consuming* side. A consumer process owns a worker
//! pool and listens on a socket; a driver ships stream membership as
//! [`TransportMsg::Control`] frames (the same `attach-stream` events
//! every other layer uses), then a [`TransportMsg::Tick`] as the "go"
//! barrier. The consumer lowers the accumulated [`EventLog`] into
//! `(clip, spec)` pairs — clips are synthesised locally from the spec,
//! keyed by the stream name, because pixels never cross the control
//! plane — runs the real threaded serve, and answers with its admission
//! decisions (as control frames, completing the round trip) and a
//! [`TransportMsg::Slice`] summary.
//!
//! [`serve_from_log`] is the transport-free core: any decoded event log
//! — from a socket, a file, or a replayed run — drives the same serve.

use anyhow::{anyhow, Result};

use crate::control::{ControlAction, EventLog, WireEvent};
use crate::detector::Detector;
use crate::fleet::metrics::FleetReport;
use crate::fleet::serve::{serve_fleet_logged, FleetServeConfig};
use crate::fleet::stream::StreamSpec;
use crate::shard::fnv1a;
use crate::transport::msg::{SliceStream, TransportMsg};
use crate::transport::net::{connect_with_backoff, Endpoint, Listener, TransportError};
use crate::video::{generate, presets, Clip};

/// Side length of the synthetic clips a consumer generates for remote
/// streams (pixels are consumer-local; only specs cross the wire).
pub const REMOTE_CLIP_SIZE: u32 = 32;

/// Lower an event log's membership into the stream specs it leaves
/// attached: `attach-stream` events append, `detach-stream` ids index
/// the attach order. Decision payloads and device verbs are ignored —
/// the consumer owns its pool.
pub fn specs_from_log(log: &EventLog) -> Vec<StreamSpec> {
    let mut specs: Vec<Option<StreamSpec>> = Vec::new();
    for event in &log.events {
        match event.as_action() {
            Some(ControlAction::AttachStream(spec)) => specs.push(Some(spec.clone())),
            Some(ControlAction::DetachStream(id)) => {
                if let Some(slot) = specs.get_mut(*id) {
                    *slot = None;
                }
            }
            _ => {}
        }
    }
    specs.into_iter().flatten().collect()
}

/// Drive one wall-clock serve from a decoded event log: synthesise a
/// clip per attached spec (seeded by the stream name, so any consumer
/// materialises the same pixels for the same stream) and run
/// [`serve_fleet_logged`] over `config`'s pool. Returns the fleet
/// report plus the run's own decision log.
pub fn serve_from_log<F>(
    log: &EventLog,
    config: &FleetServeConfig,
    factory: F,
) -> Result<(FleetReport, EventLog)>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    let specs = specs_from_log(log);
    if specs.is_empty() {
        return Err(anyhow!("event log attaches no streams"));
    }
    let clips: Vec<Clip> = specs
        .iter()
        .map(|s| {
            let frames = s.num_frames.min(u32::MAX as u64) as u32;
            generate(
                &presets::tiny_clip(REMOTE_CLIP_SIZE, frames, s.fps, fnv1a(&s.name)),
                None,
            )
        })
        .collect();
    let pairs: Vec<(&Clip, StreamSpec)> = clips.iter().zip(specs.iter().cloned()).collect();
    serve_fleet_logged(&pairs, config, factory)
}

/// Accept one driver session on `listener` and serve it: buffer control
/// frames into an [`EventLog`], serve on the `Tick` barrier, ship the
/// decisions back as control frames followed by a summary `Slice`.
/// Returns the local report, or `None` when the driver left (Bye or
/// peer loss) without ever serving.
pub fn run_serve_consumer<F>(
    listener: &Listener,
    config: &FleetServeConfig,
    factory: F,
) -> Result<Option<(FleetReport, EventLog)>>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    let mut conn = listener.accept()?;
    let mut log = EventLog::new();
    let mut served: Option<(FleetReport, EventLog)> = None;
    loop {
        let msg = match conn.recv() {
            Ok(m) => m,
            Err(TransportError::PeerClosed { .. }) => return Ok(served),
            Err(e) => return Err(e.into()),
        };
        match msg {
            TransportMsg::Control(event) => log.push(event),
            TransportMsg::Tick { epoch, .. } => {
                let (report, decisions) = serve_from_log(&log, config, &factory)?;
                for event in &decisions.events {
                    conn.send(&TransportMsg::Control(event.clone()))
                        .map_err(|e| anyhow!("decision send failed: {e}"))?;
                }
                let streams: Vec<SliceStream> = report
                    .streams
                    .iter()
                    .map(|s| SliceStream {
                        id: s.id,
                        total: s.metrics.frames_total,
                        processed: s.metrics.frames_processed,
                        latencies: Vec::new(),
                    })
                    .collect();
                conn.send(&TransportMsg::Slice {
                    epoch,
                    busy: report.device_busy.iter().sum(),
                    frames: report.total_processed(),
                    streams,
                })
                .map_err(|e| anyhow!("slice send failed: {e}"))?;
                served = Some((report, decisions));
            }
            TransportMsg::Bye => return Ok(served),
            // Driver-role replies make no sense here; ignore.
            _ => {}
        }
    }
}

/// What a driver gets back from a remote serve.
#[derive(Debug, Clone)]
pub struct RemoteServeOutcome {
    /// The consumer's admission decisions, as received over the wire.
    pub decisions: Vec<WireEvent>,
    /// Per-stream outcomes.
    pub streams: Vec<SliceStream>,
    /// Busy seconds summed over the consumer's pool.
    pub busy: f64,
    /// Frames processed across all streams.
    pub processed: u64,
}

/// Drive a remote serve consumer at `endpoint`: ship `specs` as
/// attach-stream control frames, fire the `Tick` barrier, and collect
/// the decision frames and summary slice.
pub fn drive_remote_serve(
    endpoint: &Endpoint,
    specs: &[StreamSpec],
) -> Result<RemoteServeOutcome> {
    let mut conn = connect_with_backoff(endpoint, 10, std::time::Duration::from_millis(5))
        .map_err(|e| anyhow!("dial {} failed: {e}", endpoint.label()))?;
    // The consumer serves in wall-clock time: a paced run legitimately
    // takes as long as the video lasts, so the driver must not trip the
    // default 30 s read deadline while waiting for results (peer loss is
    // still detected instantly via the closed socket).
    conn.set_read_timeout(None)
        .map_err(|e| anyhow!("clearing read deadline failed: {e}"))?;
    for spec in specs {
        let event = WireEvent::action(
            0.0,
            crate::control::ControlOrigin::Placement,
            ControlAction::AttachStream(spec.clone()),
        );
        conn.send(&TransportMsg::Control(event))
            .map_err(|e| anyhow!("attach send failed: {e}"))?;
    }
    conn.send(&TransportMsg::Tick {
        epoch: 0,
        at: 0.0,
        seed: 0,
        quotas: Vec::new(),
    })
    .map_err(|e| anyhow!("go barrier failed: {e}"))?;

    let mut decisions = Vec::new();
    loop {
        match conn.recv().map_err(|e| anyhow!("reply failed: {e}"))? {
            TransportMsg::Control(event) => decisions.push(event),
            TransportMsg::Slice {
                busy,
                frames,
                streams,
                ..
            } => {
                let _ = conn.send(&TransportMsg::Bye);
                return Ok(RemoteServeOutcome {
                    decisions,
                    streams,
                    busy,
                    processed: frames,
                });
            }
            other => return Err(anyhow!("unexpected reply {}", other.label())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::ControlOrigin;
    use crate::fleet::admission::AdmissionPolicy;
    use crate::types::{Detection, Frame};

    struct EchoDetector;

    impl Detector for EchoDetector {
        fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
            frame
                .ground_truth
                .iter()
                .map(|gt| Detection {
                    bbox: gt.bbox,
                    class_id: gt.class_id,
                    score: 0.9,
                })
                .collect()
        }

        fn label(&self) -> String {
            "echo".into()
        }
    }

    fn attach(at: f64, spec: StreamSpec) -> WireEvent {
        WireEvent::action(at, ControlOrigin::Placement, ControlAction::AttachStream(spec))
    }

    #[test]
    fn specs_from_log_applies_detaches_in_attach_order() {
        let mut log = EventLog::new();
        log.push(attach(0.0, StreamSpec::new("a", 10.0, 50)));
        log.push(attach(0.0, StreamSpec::new("b", 10.0, 50)));
        log.push(attach(0.0, StreamSpec::new("c", 10.0, 50)));
        log.push(WireEvent::action(
            1.0,
            ControlOrigin::Placement,
            ControlAction::DetachStream(1),
        ));
        let specs = specs_from_log(&log);
        assert_eq!(
            specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(),
            vec!["a", "c"]
        );
    }

    #[test]
    fn serve_from_log_matches_direct_serve_decisions() {
        let specs = vec![
            StreamSpec::new("cam-a", 20.0, 30).with_window(4),
            StreamSpec::new("cam-b", 20.0, 30).with_window(4),
        ];
        let mut log = EventLog::new();
        for s in &specs {
            log.push(attach(0.0, s.clone()));
        }
        let config = FleetServeConfig {
            admission: AdmissionPolicy::default(),
            device_rates: vec![30.0],
            paced: false,
            gate: None,
        };
        let (report, decisions) =
            serve_from_log(&log, &config, |_| Ok(Box::new(EchoDetector) as Box<dyn Detector>))
                .expect("serve");
        assert_eq!(report.streams.len(), 2);
        assert_eq!(decisions.len(), 2);
        // The log-driven run takes the same decisions as driving
        // serve_fleet_logged directly with the same specs and pool.
        let clips: Vec<Clip> = specs
            .iter()
            .map(|s| {
                generate(
                    &presets::tiny_clip(
                        REMOTE_CLIP_SIZE,
                        s.num_frames as u32,
                        s.fps,
                        fnv1a(&s.name),
                    ),
                    None,
                )
            })
            .collect();
        let pairs: Vec<(&Clip, StreamSpec)> =
            clips.iter().zip(specs.iter().cloned()).collect();
        let (_, direct) = serve_fleet_logged(&pairs, &config, |_| {
            Ok(Box::new(EchoDetector) as Box<dyn Detector>)
        })
        .expect("direct serve");
        assert_eq!(decisions, direct);
    }

    #[test]
    fn empty_log_is_an_error() {
        let config = FleetServeConfig {
            admission: AdmissionPolicy::default(),
            device_rates: vec![10.0],
            paced: false,
            gate: None,
        };
        assert!(serve_from_log(&EventLog::new(), &config, |_| {
            Ok(Box::new(EchoDetector) as Box<dyn Detector>)
        })
        .is_err());
    }
}
