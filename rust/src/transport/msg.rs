//! The cross-host session vocabulary carried inside transport frames.
//!
//! A [`TransportMsg`] is one message of the coordinator ⇄ shard (or
//! coordinator ⇄ remote-serve consumer) session protocol. Control
//! traffic proper is always a [`WireEvent`] — the same versioned
//! vocabulary the in-process co-simulation routes — wrapped in
//! [`TransportMsg::Control`]; the remaining variants are the session
//! plumbing a real multi-process deployment needs around it:
//!
//! * [`TransportMsg::Hello`] / [`TransportMsg::Welcome`] — session
//!   handshake: the coordinator ships the admission policy (over the
//!   existing [`crate::control::wire::admission_to_json`] codec), the
//!   global stream roster (so `DetachStream(StreamId)` ids resolve
//!   remotely), and one versioned [`SessionCaps`] object covering every
//!   optional capability (autoscale / gate / telemetry / auth token);
//!   the shard answers with its util-adjusted capacity.
//! * [`TransportMsg::Reject`] — typed handshake refusal (bad auth
//!   token, protocol mismatch): the peer learns *why* and fails fast
//!   instead of watching a silent close or a read timeout.
//! * [`TransportMsg::Poll`] / [`TransportMsg::Digest`] — the capacity
//!   gossip over the wire: one [`crate::shard::Headroom`]-shaped digest
//!   per epoch. A peer that cannot answer is a lost shard.
//! * [`TransportMsg::Tick`] / [`TransportMsg::Slice`] — one epoch of
//!   virtual-time serving: the coordinator ships per-stream arrival
//!   quotas and the epoch seed (as a decimal string — u64 seeds do not
//!   survive a JSON f64), the shard answers with per-stream outcomes.
//! * [`TransportMsg::Telemetry`] — an optional per-epoch metric
//!   snapshot ([`crate::telemetry::Registry`]) a shard ships ahead of
//!   its `Slice` when the coordinator's `Hello` asked for one.
//! * [`TransportMsg::Bye`] — orderly session end; anything else ending
//!   the connection is peer loss.
//!
//! Every variant round-trips exactly through [`crate::util::json`]
//! (unit-tested here; frame-level splitting is property-tested in
//! [`crate::transport::frame`]).

use std::collections::BTreeMap;

use crate::control::caps::SessionCaps;
use crate::control::wire::{
    admission_from_json, admission_to_json, autoscale_config_from_json, autoscale_config_to_json,
    gate_config_from_json, gate_config_to_json, req_f64, req_str, req_u64, req_usize,
};
use crate::control::{WireError, WireEvent};
use crate::fleet::admission::AdmissionPolicy;
use crate::shard::Headroom;
use crate::telemetry::Registry;
use crate::util::json::Json;

/// Session-protocol version stamped on every [`TransportMsg::Hello`];
/// peers reject a mismatch before any control traffic flows. (The frame
/// header carries its own codec version — see
/// [`crate::transport::frame::FRAME_VERSION`].)
pub const TRANSPORT_VERSION: i64 = 1;

/// Per-stream outcome of one served epoch slice (or of a whole remote
/// wall-clock run), keyed by global stream id.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceStream {
    pub id: usize,
    /// Frames that arrived in the slice.
    pub total: u64,
    pub processed: u64,
    /// Capture→emit latency of every record in the slice (seconds).
    pub latencies: Vec<f64>,
}

/// One message of the cross-host session protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportMsg {
    /// Coordinator → shard: open a session. `roster[i]` is the name of
    /// global stream id `i`, so wire `StreamId`s resolve remotely.
    /// `caps` is the versioned capability set for the session —
    /// shard-local autoscaling, per-frame gating, telemetry snapshots
    /// and the shared-secret auth token — under one forward-compatible
    /// contract ([`SessionCaps`]). On the JSON wire a `Hello` *also*
    /// writes the flat PR 5/6/7-era keys (`autoscale` / `gate` /
    /// `telemetry`, each only when set) so old peers keep decoding it;
    /// decode prefers the `caps` object and falls back to lifting the
    /// flat keys when a legacy peer omitted it.
    Hello {
        shard: usize,
        protocol: i64,
        admission: AdmissionPolicy,
        roster: Vec<String>,
        caps: SessionCaps,
    },
    /// Shard → coordinator: handshake reply with the shard's
    /// util-adjusted admission capacity (FPS).
    Welcome { shard: usize, capacity: f64 },
    /// Shard → coordinator: typed handshake refusal, sent *before* the
    /// connection closes so the dialler fails fast with a reason
    /// instead of a read timeout. `code` is a stable machine-readable
    /// string (`"auth"` for a bad/missing session token, `"protocol"`
    /// for a session-version mismatch; decoders must tolerate codes
    /// they do not know); `detail` is for humans and logs.
    Reject { code: String, detail: String },
    /// A control-plane event (either direction; the coordinator ships
    /// placement verbs, a remote-serve consumer ships decisions back).
    Control(WireEvent),
    /// Coordinator → shard: publish your headroom digest for `epoch`.
    Poll { epoch: usize, at: f64 },
    /// Shard → coordinator: the headroom digest ([`Headroom`] shape).
    /// `forecast` is the shard's confidence-gated forecast-Σλ slot
    /// (`None` when the shard runs no forecaster or its band is loose);
    /// both codecs treat it as optional, so legacy digests without the
    /// slot still decode.
    Digest {
        shard: usize,
        at: f64,
        capacity: f64,
        committed: f64,
        forecast: Option<f64>,
    },
    /// Coordinator → shard: serve one epoch slice. `quotas` pairs global
    /// stream ids with this epoch's arrival counts, in global id order;
    /// `seed` travels as a decimal string (u64-exact).
    Tick {
        epoch: usize,
        at: f64,
        seed: u64,
        quotas: Vec<(usize, u64)>,
    },
    /// Shard → coordinator: the served slice.
    Slice {
        epoch: usize,
        /// Busy seconds summed over the shard's pool.
        busy: f64,
        /// Frames processed summed over the shard's pool.
        frames: u64,
        streams: Vec<SliceStream>,
    },
    /// Shard → coordinator: the shard's metric snapshot after serving
    /// `epoch`. Sent ahead of the epoch's `Slice`, and only when the
    /// session's `Hello` set `telemetry`; each snapshot supersedes the
    /// previous one (cumulative counters, not deltas).
    Telemetry {
        shard: usize,
        epoch: usize,
        snapshot: Registry,
    },
    /// Orderly session end.
    Bye,
}

impl TransportMsg {
    /// The digest payload as a gossip [`Headroom`], if this is one.
    pub fn as_digest(&self) -> Option<Headroom> {
        match self {
            TransportMsg::Digest {
                shard,
                at,
                capacity,
                committed,
                forecast,
            } => Some(Headroom {
                shard: *shard,
                at: *at,
                capacity: *capacity,
                committed: *committed,
                forecast: *forecast,
            }),
            _ => None,
        }
    }

    /// Compact human label for session logs.
    pub fn label(&self) -> String {
        match self {
            TransportMsg::Hello { shard, .. } => format!("hello(shard {shard})"),
            TransportMsg::Welcome { shard, capacity } => {
                format!("welcome(shard {shard}, {capacity:.1} FPS)")
            }
            TransportMsg::Reject { code, .. } => format!("reject({code})"),
            TransportMsg::Control(ev) => format!("control({})", ev.label()),
            TransportMsg::Poll { epoch, .. } => format!("poll(epoch {epoch})"),
            TransportMsg::Digest { shard, .. } => format!("digest(shard {shard})"),
            TransportMsg::Tick { epoch, quotas, .. } => {
                format!("tick(epoch {epoch}, {} streams)", quotas.len())
            }
            TransportMsg::Slice { epoch, streams, .. } => {
                format!("slice(epoch {epoch}, {} streams)", streams.len())
            }
            TransportMsg::Telemetry { shard, epoch, .. } => {
                format!("telemetry(shard {shard}, epoch {epoch})")
            }
            TransportMsg::Bye => "bye".to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        match self {
            TransportMsg::Hello {
                shard,
                protocol,
                admission,
                roster,
                caps,
            } => {
                o.insert("msg".to_string(), Json::Str("hello".to_string()));
                o.insert("shard".to_string(), Json::Num(*shard as f64));
                o.insert("protocol".to_string(), Json::Num(*protocol as f64));
                o.insert("admission".to_string(), admission_to_json(admission));
                o.insert(
                    "roster".to_string(),
                    Json::Arr(roster.iter().map(|n| Json::Str(n.clone())).collect()),
                );
                // The flat PR 5/6/7-era keys ride alongside the caps
                // object (each only when set, the original contract) so
                // pre-caps peers keep decoding a new coordinator's
                // Hello. The auth token has no flat key on purpose:
                // pre-auth peers cannot be asked for one.
                if let Some(cfg) = &caps.autoscale {
                    o.insert("autoscale".to_string(), autoscale_config_to_json(cfg));
                }
                if let Some(cfg) = &caps.gate {
                    o.insert("gate".to_string(), gate_config_to_json(cfg));
                }
                if caps.telemetry {
                    o.insert("telemetry".to_string(), Json::Bool(true));
                }
                o.insert("caps".to_string(), caps.to_json());
            }
            TransportMsg::Welcome { shard, capacity } => {
                o.insert("msg".to_string(), Json::Str("welcome".to_string()));
                o.insert("shard".to_string(), Json::Num(*shard as f64));
                o.insert("capacity".to_string(), Json::Num(*capacity));
            }
            TransportMsg::Reject { code, detail } => {
                o.insert("msg".to_string(), Json::Str("reject".to_string()));
                o.insert("code".to_string(), Json::Str(code.clone()));
                o.insert("detail".to_string(), Json::Str(detail.clone()));
            }
            TransportMsg::Control(ev) => {
                o.insert("msg".to_string(), Json::Str("control".to_string()));
                o.insert("event".to_string(), ev.to_json());
            }
            TransportMsg::Poll { epoch, at } => {
                o.insert("msg".to_string(), Json::Str("poll".to_string()));
                o.insert("epoch".to_string(), Json::Num(*epoch as f64));
                o.insert("at".to_string(), Json::Num(*at));
            }
            TransportMsg::Digest {
                shard,
                at,
                capacity,
                committed,
                forecast,
            } => {
                o.insert("msg".to_string(), Json::Str("digest".to_string()));
                o.insert("shard".to_string(), Json::Num(*shard as f64));
                o.insert("at".to_string(), Json::Num(*at));
                o.insert("capacity".to_string(), Json::Num(*capacity));
                o.insert("committed".to_string(), Json::Num(*committed));
                // Optional forecast-Σλ slot: omitted when absent, so
                // forecast-free digests render byte-identical to
                // pre-forecast builds (and legacy decoders ignore it).
                if let Some(f) = forecast {
                    o.insert("forecast".to_string(), Json::Num(*f));
                }
            }
            TransportMsg::Tick {
                epoch,
                at,
                seed,
                quotas,
            } => {
                o.insert("msg".to_string(), Json::Str("tick".to_string()));
                o.insert("epoch".to_string(), Json::Num(*epoch as f64));
                o.insert("at".to_string(), Json::Num(*at));
                o.insert("seed".to_string(), Json::Str(format!("{seed}")));
                o.insert(
                    "quotas".to_string(),
                    Json::Arr(
                        quotas
                            .iter()
                            .map(|&(id, frames)| {
                                let mut q = BTreeMap::new();
                                q.insert("id".to_string(), Json::Num(id as f64));
                                q.insert("frames".to_string(), Json::Num(frames as f64));
                                Json::Obj(q)
                            })
                            .collect(),
                    ),
                );
            }
            TransportMsg::Slice {
                epoch,
                busy,
                frames,
                streams,
            } => {
                o.insert("msg".to_string(), Json::Str("slice".to_string()));
                o.insert("epoch".to_string(), Json::Num(*epoch as f64));
                o.insert("busy".to_string(), Json::Num(*busy));
                o.insert("frames".to_string(), Json::Num(*frames as f64));
                o.insert(
                    "streams".to_string(),
                    Json::Arr(
                        streams
                            .iter()
                            .map(|s| {
                                let mut m = BTreeMap::new();
                                m.insert("id".to_string(), Json::Num(s.id as f64));
                                m.insert("total".to_string(), Json::Num(s.total as f64));
                                m.insert("processed".to_string(), Json::Num(s.processed as f64));
                                m.insert(
                                    "latencies".to_string(),
                                    Json::Arr(s.latencies.iter().map(|&l| Json::Num(l)).collect()),
                                );
                                Json::Obj(m)
                            })
                            .collect(),
                    ),
                );
            }
            TransportMsg::Telemetry {
                shard,
                epoch,
                snapshot,
            } => {
                o.insert("msg".to_string(), Json::Str("telemetry".to_string()));
                o.insert("shard".to_string(), Json::Num(*shard as f64));
                o.insert("epoch".to_string(), Json::Num(*epoch as f64));
                o.insert("snapshot".to_string(), snapshot.to_json());
            }
            TransportMsg::Bye => {
                o.insert("msg".to_string(), Json::Str("bye".to_string()));
            }
        }
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<TransportMsg, WireError> {
        match req_str(v, "msg")? {
            "hello" => {
                let adm = v
                    .get("admission")
                    .ok_or_else(|| WireError::new("missing or mistyped field \"admission\""))?;
                let raw = v
                    .get("roster")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| WireError::new("missing or mistyped field \"roster\""))?;
                let mut roster = Vec::with_capacity(raw.len());
                for n in raw {
                    roster.push(
                        n.as_str()
                            .ok_or_else(|| WireError::new("roster entries must be strings"))?
                            .to_string(),
                    );
                }
                // The caps object is authoritative when present. A
                // legacy peer omits it, so the flat PR 5/6/7-era keys
                // are lifted instead — absent and null both read as
                // "capability off", the contract every one of those PRs
                // pinned individually and SessionCaps now owns.
                let caps = match v.get("caps") {
                    None | Some(Json::Null) => {
                        let autoscale = match v.get("autoscale") {
                            None | Some(Json::Null) => None,
                            Some(j) => Some(autoscale_config_from_json(j)?),
                        };
                        let gate = match v.get("gate") {
                            None | Some(Json::Null) => None,
                            Some(j) => Some(gate_config_from_json(j)?),
                        };
                        let telemetry = match v.get("telemetry") {
                            None | Some(Json::Null) => false,
                            Some(j) => j
                                .as_bool()
                                .ok_or_else(|| WireError::new("hello telemetry must be a bool"))?,
                        };
                        SessionCaps::from_legacy(autoscale, gate, telemetry)
                    }
                    Some(j) => SessionCaps::from_json(j)?,
                };
                Ok(TransportMsg::Hello {
                    shard: req_usize(v, "shard")?,
                    protocol: req_u64(v, "protocol")? as i64,
                    admission: admission_from_json(adm)?,
                    roster,
                    caps,
                })
            }
            "welcome" => Ok(TransportMsg::Welcome {
                shard: req_usize(v, "shard")?,
                capacity: req_f64(v, "capacity")?,
            }),
            "reject" => Ok(TransportMsg::Reject {
                code: req_str(v, "code")?.to_string(),
                // Tolerate a missing detail — only the code is load-
                // bearing for the dialler's error path.
                detail: v
                    .get("detail")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            }),
            "control" => {
                let ev = v
                    .get("event")
                    .ok_or_else(|| WireError::new("missing or mistyped field \"event\""))?;
                Ok(TransportMsg::Control(WireEvent::from_json(ev)?))
            }
            "poll" => Ok(TransportMsg::Poll {
                epoch: req_usize(v, "epoch")?,
                at: req_f64(v, "at")?,
            }),
            "digest" => Ok(TransportMsg::Digest {
                shard: req_usize(v, "shard")?,
                at: req_f64(v, "at")?,
                capacity: req_f64(v, "capacity")?,
                committed: req_f64(v, "committed")?,
                // Absent or null → no forecast slot (legacy digests);
                // present but mistyped is an error, not a default.
                forecast: match v.get("forecast") {
                    None | Some(Json::Null) => None,
                    Some(j) => Some(
                        j.as_f64()
                            .ok_or_else(|| WireError::new("digest forecast must be a number"))?,
                    ),
                },
            }),
            "tick" => {
                let seed = req_str(v, "seed")?
                    .parse::<u64>()
                    .map_err(|_| WireError::new("tick seed must be a decimal u64 string"))?;
                let raw = v
                    .get("quotas")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| WireError::new("missing or mistyped field \"quotas\""))?;
                let mut quotas = Vec::with_capacity(raw.len());
                for q in raw {
                    quotas.push((req_usize(q, "id")?, req_u64(q, "frames")?));
                }
                Ok(TransportMsg::Tick {
                    epoch: req_usize(v, "epoch")?,
                    at: req_f64(v, "at")?,
                    seed,
                    quotas,
                })
            }
            "slice" => {
                let raw = v
                    .get("streams")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| WireError::new("missing or mistyped field \"streams\""))?;
                let mut streams = Vec::with_capacity(raw.len());
                for s in raw {
                    let lat_raw = s
                        .get("latencies")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| WireError::new("missing or mistyped field \"latencies\""))?;
                    let mut latencies = Vec::with_capacity(lat_raw.len());
                    for l in lat_raw {
                        latencies.push(
                            l.as_f64()
                                .ok_or_else(|| WireError::new("latencies must be numbers"))?,
                        );
                    }
                    streams.push(SliceStream {
                        id: req_usize(s, "id")?,
                        total: req_u64(s, "total")?,
                        processed: req_u64(s, "processed")?,
                        latencies,
                    });
                }
                Ok(TransportMsg::Slice {
                    epoch: req_usize(v, "epoch")?,
                    busy: req_f64(v, "busy")?,
                    frames: req_u64(v, "frames")?,
                    streams,
                })
            }
            "telemetry" => {
                let snap = v
                    .get("snapshot")
                    .ok_or_else(|| WireError::new("missing or mistyped field \"snapshot\""))?;
                Ok(TransportMsg::Telemetry {
                    shard: req_usize(v, "shard")?,
                    epoch: req_usize(v, "epoch")?,
                    snapshot: Registry::from_json(snap)?,
                })
            }
            "bye" => Ok(TransportMsg::Bye),
            other => Err(WireError::new(format!("unknown transport message {other:?}"))),
        }
    }

    /// Serialise to a compact JSON string (the frame payload).
    pub fn encode(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a compact JSON string produced by [`TransportMsg::encode`].
    pub fn decode(text: &str) -> Result<TransportMsg, WireError> {
        let v = Json::parse(text).map_err(|e| WireError::new(e.to_string()))?;
        TransportMsg::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::policy::AutoscaleConfig;
    use crate::control::{ControlAction, ControlOrigin};
    use crate::fleet::stream::StreamSpec;
    use crate::gate::GateConfig;

    fn roundtrip(msg: &TransportMsg) {
        let text = msg.encode();
        let back = TransportMsg::decode(&text).expect("decode");
        assert_eq!(&back, msg, "wire text: {text}");
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(&TransportMsg::Hello {
            shard: 1,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]),
            roster: vec!["cam0".to_string(), "cam1".to_string()],
            caps: SessionCaps::default(),
        });
        roundtrip(&TransportMsg::Hello {
            shard: 0,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::default(),
            roster: vec!["cam0".to_string()],
            caps: SessionCaps {
                autoscale: Some(AutoscaleConfig {
                    max_devices: 9,
                    device_rate: 3.25,
                    ..AutoscaleConfig::default()
                }),
                gate: Some(GateConfig {
                    max_skip_run: 4,
                    tracker_stretch: 2.5,
                    ..GateConfig::default()
                }),
                telemetry: true,
                token: Some("s3cret".to_string()),
                ..SessionCaps::default()
            },
        });
        roundtrip(&TransportMsg::Welcome {
            shard: 1,
            capacity: 7.125,
        });
        roundtrip(&TransportMsg::Reject {
            code: "auth".to_string(),
            detail: "bad or missing session token".to_string(),
        });
        roundtrip(&TransportMsg::Control(WireEvent::action(
            2.5,
            ControlOrigin::Placement,
            ControlAction::AttachStream(StreamSpec::new("cam0", 7.25, 321).with_weight(2.5)),
        )));
        roundtrip(&TransportMsg::Poll { epoch: 3, at: 30.0 });
        roundtrip(&TransportMsg::Digest {
            shard: 0,
            at: 30.0,
            capacity: 9.5,
            committed: 7.25,
            forecast: None,
        });
        roundtrip(&TransportMsg::Digest {
            shard: 2,
            at: 31.0,
            capacity: 9.5,
            committed: 7.25,
            forecast: Some(8.375),
        });
        roundtrip(&TransportMsg::Tick {
            epoch: 3,
            at: 30.0,
            // A seed far outside the f64-exact integer range: the string
            // encoding must carry it bit-for-bit.
            seed: 0xDEAD_BEEF_CAFE_F00D,
            quotas: vec![(0, 25), (3, 12)],
        });
        roundtrip(&TransportMsg::Slice {
            epoch: 3,
            busy: 12.75,
            frames: 37,
            streams: vec![SliceStream {
                id: 0,
                total: 25,
                processed: 23,
                latencies: vec![0.125, 0.5, 1.0],
            }],
        });
        let mut snapshot = Registry::new();
        snapshot.inc(
            crate::telemetry::MetricKey::with_labels("eva_frames_total", &[("shard", "1")]),
            37,
        );
        snapshot.observe(crate::telemetry::MetricKey::new("eva_e2e_seconds"), 0.125);
        roundtrip(&TransportMsg::Telemetry {
            shard: 1,
            epoch: 3,
            snapshot,
        });
        roundtrip(&TransportMsg::Bye);
    }

    /// A hand-written legacy Hello: exactly the keys a PR 4/5/6/7-era
    /// encoder wrote (no `caps` object), with `extra` spliced in after
    /// the admission blob. The admission codec itself has been wire-
    /// stable since PR 3, so it is rendered rather than transcribed.
    fn era_hello(extra: &str) -> String {
        let adm = admission_to_json(&AdmissionPolicy::default()).to_string();
        format!(
            r#"{{"admission":{adm},{extra}"msg":"hello","protocol":1,"roster":["cam0"],"shard":1}}"#
        )
    }

    fn decode_hello_caps(text: &str) -> SessionCaps {
        match TransportMsg::decode(text).expect("era hello must decode") {
            TransportMsg::Hello { caps, .. } => caps,
            other => panic!("not a hello: {other:?}"),
        }
    }

    #[test]
    fn pr4_era_hello_without_optional_keys_decodes_as_default_caps() {
        // The oldest dialect: no autoscale, no gate, no telemetry, no
        // caps. Every capability must come back defaulted.
        let caps = decode_hello_caps(&era_hello(""));
        assert_eq!(caps, SessionCaps::default());
        // Explicit nulls read identically (the original PR 5 contract).
        let caps = decode_hello_caps(&era_hello(
            r#""autoscale":null,"gate":null,"telemetry":null,"#,
        ));
        assert_eq!(caps, SessionCaps::default());
    }

    #[test]
    fn pr5_era_hello_with_flat_autoscale_lifts_into_caps() {
        let cfg = AutoscaleConfig {
            max_devices: 9,
            device_rate: 3.25,
            ..AutoscaleConfig::default()
        };
        let auto = autoscale_config_to_json(&cfg).to_string();
        let caps = decode_hello_caps(&era_hello(&format!(r#""autoscale":{auto},"#)));
        assert_eq!(caps.autoscale, Some(cfg));
        assert!(caps.gate.is_none() && !caps.telemetry && caps.token.is_none());
    }

    #[test]
    fn pr6_era_hello_with_flat_gate_lifts_into_caps() {
        let cfg = GateConfig {
            max_skip_run: 4,
            tracker_stretch: 2.5,
            ..GateConfig::default()
        };
        let gate = gate_config_to_json(&cfg).to_string();
        let caps = decode_hello_caps(&era_hello(&format!(r#""gate":{gate},"#)));
        assert_eq!(caps.gate, Some(cfg));
        assert!(caps.autoscale.is_none() && !caps.telemetry);
    }

    #[test]
    fn pr7_era_hello_with_flat_telemetry_lifts_into_caps() {
        let caps = decode_hello_caps(&era_hello(r#""telemetry":true,"#));
        assert!(caps.telemetry);
        // A non-bool value on the legacy key is malformed, not coerced
        // — skew is tolerated, corruption is not.
        assert!(TransportMsg::decode(&era_hello(r#""telemetry":3,"#)).is_err());
    }

    #[test]
    fn caps_object_wins_over_flat_keys() {
        // A peer that writes both (every new encoder does) is read from
        // the caps object alone; contradictory flat keys are ignored
        // rather than merged.
        let caps = decode_hello_caps(&era_hello(r#""telemetry":true,"caps":{"version":1},"#));
        assert!(!caps.telemetry, "flat telemetry must lose to the caps object");
        let caps = decode_hello_caps(&era_hello(
            r#""caps":{"telemetry":true,"token":"k","version":1},"#,
        ));
        assert!(caps.telemetry);
        assert_eq!(caps.token.as_deref(), Some("k"));
    }

    #[test]
    fn new_hello_keeps_flat_keys_an_old_decoder_can_read() {
        // Version-skew, new → old: an old decoder knows nothing of
        // `caps`, so the flat keys it *does* read must mirror the caps
        // content exactly — and must stay omitted when unset so the
        // PR 5/6/7-era "absent means off" byte contract survives.
        let plain = TransportMsg::Hello {
            shard: 2,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::default(),
            roster: vec![],
            caps: SessionCaps::default(),
        }
        .encode();
        assert!(!plain.contains("autoscale"), "unset key leaked: {plain}");
        assert!(!plain.contains("gate"), "unset key leaked: {plain}");
        assert!(!plain.contains("telemetry"), "unset key leaked: {plain}");

        let full = TransportMsg::Hello {
            shard: 2,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::default(),
            roster: vec![],
            caps: SessionCaps {
                autoscale: Some(AutoscaleConfig::default()),
                gate: Some(GateConfig::default()),
                telemetry: true,
                token: Some("s3cret".to_string()),
                ..SessionCaps::default()
            },
        }
        .encode();
        let v = Json::parse(&full).unwrap();
        // Simulated old decoder: reads only the flat keys.
        assert_eq!(
            autoscale_config_from_json(v.get("autoscale").unwrap()).unwrap(),
            AutoscaleConfig::default()
        );
        assert_eq!(
            gate_config_from_json(v.get("gate").unwrap()).unwrap(),
            GateConfig::default()
        );
        assert_eq!(v.get("telemetry"), Some(&Json::Bool(true)));
        // The token rides only inside caps — no flat key exists for an
        // old peer to misread.
        assert_eq!(full.matches("\"token\"").count(), 1, "wire: {full}");
        assert!(v.get("token").is_none());
    }

    #[test]
    fn reject_decodes_with_unknown_codes_and_missing_detail() {
        // Forward compatibility on the refusal path: a future peer may
        // reject for reasons this build has never heard of, with or
        // without prose.
        let msg = TransportMsg::decode(r#"{"code":"quota-exhausted","msg":"reject"}"#).unwrap();
        assert_eq!(
            msg,
            TransportMsg::Reject {
                code: "quota-exhausted".to_string(),
                detail: String::new(),
            }
        );
        assert_eq!(msg.label(), "reject(quota-exhausted)");
        // A reject without a code is malformed.
        assert!(TransportMsg::decode(r#"{"msg":"reject"}"#).is_err());
    }

    #[test]
    fn random_gated_hellos_survive_the_frame_codec() {
        // Satellite pin: the optional gate config rides the handshake;
        // random Hellos with and without it must cross the full frame
        // codec as the identity.
        use crate::gate::signal::MotionDynamics;
        use crate::transport::frame::{encode_frame, FrameDecoder};
        use crate::util::prop::{check, Config};
        check("gated hellos survive frames", Config::default(), |rng| {
            let gate = rng.chance(0.7).then(|| {
                let skip = rng.range(0.0, 0.2);
                GateConfig {
                    skip_threshold: skip,
                    resume_threshold: skip + rng.range(0.0, 0.2),
                    scene_cut_threshold: rng.range(0.3, 0.9),
                    max_skip_run: rng.int_in(1, 8) as u64,
                    tracker_stretch: rng.range(1.0, 10.0),
                    pressure_threshold: rng.range(0.3, 1.0),
                    pressure_rung: rng.below(4) as usize,
                    alpha: rng.range(0.05, 1.0),
                    dynamics: MotionDynamics {
                        base: rng.range(0.0, 0.3),
                        jitter: rng.range(0.0, 0.15),
                        cut_every: if rng.chance(0.5) { rng.int_in(2, 300) as u64 } else { 0 },
                    },
                }
            });
            let msg = TransportMsg::Hello {
                shard: rng.below(8) as usize,
                protocol: TRANSPORT_VERSION,
                admission: AdmissionPolicy::default(),
                roster: (0..rng.below(4)).map(|i| format!("cam{i}")).collect(),
                caps: SessionCaps {
                    autoscale: rng.chance(0.3).then(AutoscaleConfig::default),
                    gate,
                    telemetry: rng.chance(0.5),
                    token: rng.chance(0.5).then(|| format!("tok{}", rng.below(100))),
                    forecast: rng.chance(0.3).then(|| crate::forecast::ForecastConfig {
                        period: rng.below(24) as usize,
                        band: rng.range(0.05, 0.5),
                        ..crate::forecast::ForecastConfig::default()
                    }),
                    ..SessionCaps::default()
                },
            };
            let bytes = encode_frame(&msg).map_err(|e| e.to_string())?;
            let mut dec = FrameDecoder::new();
            dec.feed(&bytes);
            let back = dec
                .try_next()
                .map_err(|e| e.to_string())?
                .ok_or("no frame decoded")?;
            if back != msg {
                return Err(format!("decoded {back:?} != original {msg:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn random_scale_actions_survive_the_frame_codec() {
        // Satellite pin: shard-local scale actions (device attach/detach
        // and ladder-rung swaps) ride TransportMsg::Control frames; the
        // whole path — wire event → session message → length-prefixed
        // frame → decoder — must be the identity for random payloads.
        use crate::control::{ControlAction, ControlOrigin};
        use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
        use crate::transport::frame::{encode_frame, FrameDecoder};
        use crate::util::prop::{check, Config};
        check("scale actions survive frames", Config::default(), |rng| {
            let origin = *rng.choose(&[ControlOrigin::Controller, ControlOrigin::Placement]);
            let action = match rng.below(3) {
                0 => {
                    let mut d = DeviceInstance::new(
                        *rng.choose(&[DeviceKind::Ncs2, DeviceKind::FastCpu, DeviceKind::TitanX]),
                        *rng.choose(&[DetectorModelId::Ssd300, DetectorModelId::Yolov3]),
                        rng.below(64) as usize,
                    );
                    d.jitter_cv = rng.range(0.0, 0.2);
                    if rng.chance(0.5) {
                        d.rate_override = Some(rng.range(0.5, 40.0));
                    }
                    ControlAction::AttachDevice(d)
                }
                1 => ControlAction::DetachDevice(rng.below(64) as usize),
                _ => ControlAction::SwapModel {
                    stream: rng.below(128) as usize,
                    rung: rng.below(4) as usize,
                },
            };
            let event = WireEvent::action(rng.range(0.0, 1e4), origin, action);
            let msg = TransportMsg::Control(event);
            let bytes = encode_frame(&msg).map_err(|e| e.to_string())?;
            let mut dec = FrameDecoder::new();
            dec.feed(&bytes);
            let back = dec
                .try_next()
                .map_err(|e| e.to_string())?
                .ok_or("no frame decoded")?;
            if back != msg {
                return Err(format!("decoded {back:?} != original {msg:?}"));
            }
            if dec.try_next().map_err(|e| e.to_string())?.is_some() {
                return Err("trailing frame from a single encode".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn digest_lowers_to_headroom() {
        let msg = TransportMsg::Digest {
            shard: 2,
            at: 10.0,
            capacity: 9.5,
            committed: 4.0,
            forecast: None,
        };
        let h = msg.as_digest().expect("digest");
        assert_eq!(h.shard, 2);
        assert_eq!(h.capacity, 9.5);
        assert_eq!(h.forecast, None);
        let msg = TransportMsg::Digest {
            shard: 2,
            at: 10.0,
            capacity: 9.5,
            committed: 4.0,
            forecast: Some(6.5),
        };
        assert_eq!(msg.as_digest().expect("digest").forecast, Some(6.5));
        assert!(TransportMsg::Bye.as_digest().is_none());
    }

    #[test]
    fn digest_forecast_slot_is_forward_compatible_in_both_codecs() {
        use crate::control::binary::{decode_msg, encode_msg};
        use crate::util::prop::{check, Config};
        // Legacy JSON digest (no forecast key): decodes with the slot
        // absent, and its re-rendering stays byte-identical (no key).
        let legacy = r#"{"at":30,"capacity":9.5,"committed":7.25,"msg":"digest","shard":0}"#;
        let msg = TransportMsg::decode(legacy).expect("legacy digest decodes");
        assert_eq!(
            msg.as_digest().expect("headroom shape").forecast,
            None
        );
        assert_eq!(msg.encode(), legacy);
        // Legacy *binary* digest: bytes that end at `committed` decode
        // with the slot absent, and a forecast-free encode reproduces
        // exactly those bytes.
        let bytes = encode_msg(&msg);
        let back = decode_msg(&bytes).expect("legacy binary digest decodes");
        assert_eq!(back, msg);
        // A null forecast is the explicit absent form.
        assert!(
            TransportMsg::decode(
                r#"{"msg":"digest","shard":0,"at":1,"capacity":2,"committed":1,"forecast":null}"#
            )
            .expect("null forecast")
            .as_digest()
            .unwrap()
            .forecast
            .is_none()
        );
        // A mistyped forecast is an error, not a default.
        assert!(TransportMsg::decode(
            r#"{"msg":"digest","shard":0,"at":1,"capacity":2,"committed":1,"forecast":"soon"}"#
        )
        .is_err());
        // Property: random digests with and without the slot round-trip
        // through both codecs, and the two codecs agree.
        check("digest forecast slot roundtrip", Config::default(), |rng| {
            let msg = TransportMsg::Digest {
                shard: rng.below(64) as usize,
                at: rng.range(0.0, 1e4),
                capacity: rng.range(0.0, 100.0),
                committed: rng.range(0.0, 100.0),
                forecast: if rng.chance(0.5) {
                    Some(rng.range(0.0, 100.0))
                } else {
                    None
                },
            };
            let back = TransportMsg::decode(&msg.encode()).map_err(|e| e.to_string())?;
            if back != msg {
                return Err(format!("json decoded {back:?} != original {msg:?}"));
            }
            let back = decode_msg(&encode_msg(&msg)).map_err(|e| e.to_string())?;
            if back != msg {
                return Err(format!("binary decoded {back:?} != original {msg:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_malformed_messages() {
        assert!(TransportMsg::decode("not json").is_err());
        assert!(TransportMsg::decode("{}").is_err());
        assert!(TransportMsg::decode(r#"{"msg":"launch-missiles"}"#).is_err());
        // A tick seed must survive as u64: floats and overflow are rejected.
        assert!(TransportMsg::decode(
            r#"{"msg":"tick","epoch":0,"at":0,"seed":"1.5","quotas":[]}"#
        )
        .is_err());
        assert!(TransportMsg::decode(
            r#"{"msg":"tick","epoch":0,"at":0,"seed":"99999999999999999999999","quotas":[]}"#
        )
        .is_err());
        // Control payloads reuse the full WireEvent validation.
        assert!(TransportMsg::decode(
            r#"{"msg":"control","event":{"at":0,"origin":"nobody","type":"detach-stream","stream_id":0}}"#
        )
        .is_err());
    }

    #[test]
    fn labels_cover_variants() {
        assert_eq!(
            TransportMsg::Poll { epoch: 4, at: 0.0 }.label(),
            "poll(epoch 4)"
        );
        assert_eq!(TransportMsg::Bye.label(), "bye");
        let tick = TransportMsg::Tick {
            epoch: 1,
            at: 5.0,
            seed: 7,
            quotas: vec![(0, 1)],
        };
        assert_eq!(tick.label(), "tick(epoch 1, 1 streams)");
        let snap = TransportMsg::Telemetry {
            shard: 2,
            epoch: 5,
            snapshot: Registry::new(),
        };
        assert_eq!(snap.label(), "telemetry(shard 2, epoch 5)");
    }
}
