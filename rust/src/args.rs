//! The `eva` binary's argument layer: one flag table every subcommand
//! parses against, the exit-2 usage contract (unknown subcommand,
//! unknown flag, stray positional, flag on a subcommand it cannot
//! steer), and the shared value parsers — device rates and socket
//! endpoints — that `fleet`, `shard` and `shard-server` all use.
//!
//! Exit codes: 2 means the command line itself is malformed; 1 means
//! the command was understood but failed at run time; 0 is success.

use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
use eva::transport::Endpoint;
use eva::util::cli::{usage, Args, Spec};

use anyhow::{anyhow, bail, Result};

pub fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "model", takes_value: true, help: "TinyDet variant (essd|eyolo)", default: Some("essd") },
        Spec { name: "workers", takes_value: true, help: "parallel detector replicas", default: Some("2") },
        Spec { name: "frames", takes_value: true, help: "clip length in frames (default 60; fleet default 300)", default: None },
        Spec { name: "fps", takes_value: true, help: "input stream rate λ", default: Some("10") },
        Spec { name: "seed", takes_value: true, help: "experiment seed", default: Some("7") },
        Spec { name: "id", takes_value: true, help: "table id for `table` (1..10|fig5|fig23|ablation|links|energy-frame|fleet|fleet-saturation)", default: None },
        Spec { name: "artifacts", takes_value: true, help: "artifact directory", default: Some("artifacts") },
        Spec { name: "lambda", takes_value: true, help: "input rate for nselect", default: Some("14") },
        Spec { name: "mu", takes_value: true, help: "per-model rate for nselect", default: Some("2.5") },
        Spec { name: "out", takes_value: true, help: "output directory for visualize", default: Some("/tmp/eva_frames") },
        Spec { name: "csv", takes_value: false, help: "emit CSV instead of framed table", default: None },
        Spec { name: "saturated", takes_value: false, help: "serve: feed frames as fast as possible", default: None },
        Spec { name: "streams", takes_value: true, help: "fleet: number of concurrent streams", default: Some("8") },
        Spec { name: "stream-fps", takes_value: true, help: "fleet: per-stream input rate λ", default: Some("5") },
        Spec { name: "rates", takes_value: true, help: "fleet/shard-server: comma-separated device rates μ", default: Some("13.5,2.5,2.5,2.5") },
        Spec { name: "window", takes_value: true, help: "fleet: per-stream freshness window", default: Some("4") },
        Spec { name: "no-admission", takes_value: false, help: "fleet: admit everything (overload shows as drops)", default: None },
        Spec { name: "scenario", takes_value: true, help: "autoscale/shard/gate: sweep to run (autoscale: step|diurnal|failure|all; shard: split|skew|failure|autoscale|churn|all|run|transport|scale; gate: lobby|highway|sports|all)", default: Some("step") },
        Spec { name: "json", takes_value: false, help: "fleet/autoscale/shard/forecast/gate/trace: emit machine-readable JSON instead of tables", default: None },
        Spec { name: "shards", takes_value: true, help: "shard: number of fleet instances (each gets a --rates pool)", default: Some("2") },
        Spec { name: "policy", takes_value: true, help: "shard: placement policy (least-loaded|hash|round-robin)", default: Some("least-loaded") },
        Spec { name: "gossip", takes_value: true, help: "shard: capacity-gossip interval in seconds", default: Some("5") },
        Spec { name: "transport", takes_value: true, help: "shard: control-plane transport for --scenario run (inproc|tcp|uds; sockets bind loopback)", default: Some("inproc") },
        Spec { name: "codec", takes_value: true, help: "shard: control-plane payload codec for --scenario run (json|binary; json is the audit format)", default: None },
        Spec { name: "groups", takes_value: true, help: "shard: rebalance over shard groups of this size for --scenario run (default: flat planning)", default: None },
        Spec { name: "autoscale", takes_value: false, help: "shard: embed an AutoscaleController in every shard (--scenario run), or select the autoscale overload sweep", default: None },
        Spec { name: "forecast", takes_value: false, help: "shard: arm per-stream arrival forecasting on --scenario run (predicted Σλ rides gossip, fuses into scaling/placement/admission)", default: None },
        Spec { name: "metrics-out", takes_value: true, help: "fleet/gate/shard/trace: write the run's metric snapshot (Prometheus text exposition) to this file", default: None },
        Spec { name: "trace-out", takes_value: true, help: "fleet/gate/trace: write the run's per-frame span traces (JSONL) to this file", default: None },
        Spec { name: "listen", takes_value: true, help: "shard-server: bind address (host:port, or unix:<path> for a Unix socket)", default: None },
        Spec { name: "token", takes_value: true, help: "shard/shard-server: shared session secret; handshakes without it get a typed reject", default: None },
        Spec { name: "sessions", takes_value: true, help: "shard-server: coordinator sessions to serve before exiting", default: Some("1") },
        Spec { name: "probe", takes_value: false, help: "shard-server: dial --listen, handshake, and exit instead of serving", default: None },
    ]
}

/// The one canonical subcommand list: the validity gate in `main`, the
/// usage strings and `run`'s dispatch must never drift apart.
pub const SUBCOMMANDS: [&str; 13] = [
    "serve", "offline", "fleet", "autoscale", "shard", "shard-server", "forecast", "gate",
    "trace", "table", "nselect", "visualize", "inspect",
];

fn subcommand_list() -> String {
    SUBCOMMANDS.join(" | ")
}

/// Exit 2 with a usage pointer: the command line itself is malformed
/// (unknown subcommand/flag, stray positional), as opposed to a command
/// that was understood but failed (exit 1).
pub fn usage_error(msg: &str) -> ! {
    eprintln!("error: {msg}");
    eprintln!("usage: eva <subcommand> [options]  ({})", subcommand_list());
    eprintln!("run `eva --help` for the full option list");
    std::process::exit(2);
}

/// The binary's front door: `--help`/empty prints usage and exits 0;
/// anything malformed exits 2; otherwise returns the validated
/// subcommand and its parsed flags.
pub fn parse_argv(raw: &[String]) -> (String, Args) {
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        print!("{}", usage("eva", "parallel detection for edge video analytics", &specs()));
        println!("\nsubcommands: {}", subcommand_list());
        std::process::exit(0);
    }
    let cmd = raw[0].clone();
    if !SUBCOMMANDS.contains(&cmd.as_str()) {
        usage_error(&format!("unknown subcommand {cmd:?}"));
    }
    let args = match Args::parse(&raw[1..], &specs()) {
        Ok(a) => a,
        Err(e) => usage_error(&e),
    };
    // No subcommand takes positional arguments; a stray one is almost
    // always a typo'd flag value and must not be silently ignored.
    if let [stray, ..] = args.positional() {
        usage_error(&format!("unexpected argument {stray:?}"));
    }
    (cmd, args)
}

/// Flag-applicability gate, applied before dispatch: a flag passed to a
/// subcommand it cannot steer would be silently ignored, and the CLI
/// contract is that nothing is. Exits 2 on violation.
pub fn check_applicability(cmd: &str, args: &Args) {
    // All gates test `Args::passed` — did the user actually write the
    // flag — never `get`, which also sees values filled in from spec
    // defaults (a defaulted flag must not trip the gate on every run).
    //
    // `--metrics-out` / `--trace-out` only apply where a run produces a
    // registry / span traces.
    if args.passed("metrics-out") && !matches!(cmd, "fleet" | "gate" | "shard" | "trace") {
        usage_error(&format!("--metrics-out does not apply to {cmd} (fleet|gate|shard|trace)"));
    }
    if args.passed("trace-out") && !matches!(cmd, "fleet" | "gate" | "trace") {
        usage_error(&format!("--trace-out does not apply to {cmd} (fleet|gate|trace)"));
    }
    // `--codec`/`--groups` steer the sharded control plane only.
    if args.passed("codec") && cmd != "shard" {
        usage_error(&format!("--codec does not apply to {cmd} (shard)"));
    }
    if args.passed("groups") && cmd != "shard" {
        usage_error(&format!("--groups does not apply to {cmd} (shard)"));
    }
    // The session layer: `--listen`/`--sessions`/`--probe` are the
    // shard-server surface; `--token` also rides the coordinator side
    // (`eva shard --scenario run --transport tcp|uds`).
    for flag in ["listen", "sessions", "probe"] {
        if args.passed(flag) && cmd != "shard-server" {
            usage_error(&format!("--{flag} does not apply to {cmd} (shard-server)"));
        }
    }
    if args.passed("token") && !matches!(cmd, "shard" | "shard-server") {
        usage_error(&format!("--token does not apply to {cmd} (shard|shard-server)"));
    }
    // `--forecast` arms the forecaster on the one-off sharded run; the
    // `forecast` subcommand's sweeps arm it themselves, so the flag
    // there would be a silent no-op.
    if args.passed("forecast") && cmd != "shard" {
        usage_error(&format!("--forecast does not apply to {cmd} (shard --scenario run)"));
    }
}

/// Parse `--rates` into a non-empty device-rate vector.
pub fn parse_rates(args: &Args) -> Result<Vec<f64>> {
    let raw = args.str_or("rates", "13.5,2.5,2.5,2.5");
    let rates: Vec<f64> = raw
        .split(',')
        .map(|p| {
            p.trim()
                .parse::<f64>()
                .map_err(|_| anyhow!("--rates: cannot parse {:?}", p.trim()))
        })
        .collect::<Result<Vec<f64>>>()?;
    if rates.is_empty() {
        bail!("--rates: need at least one device rate");
    }
    Ok(rates)
}

/// One device pool shaped by `--rates` (NCS2-class instances, slot per
/// rate).
pub fn device_pool(rates: &[f64]) -> Vec<DeviceInstance> {
    rates
        .iter()
        .enumerate()
        .map(|(i, &r)| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r))
        .collect()
}

/// Parse a `--listen` address: `unix:<path>` binds a Unix-domain
/// socket, anything else is a TCP `host:port` (non-loopback binds are
/// the point of `shard-server`).
pub fn parse_endpoint(addr: &str) -> Endpoint {
    match addr.strip_prefix("unix:") {
        Some(path) => Endpoint::Uds(std::path::PathBuf::from(path)),
        None => Endpoint::Tcp(addr.to_string()),
    }
}
