//! Benchmark clip presets mirroring the paper's Table I, plus small
//! PJRT-scale clips for the live serving examples.

use crate::video::motion::CameraMotion;
use crate::video::ClipSpec;

/// ETH-Sunnyday analog (Table I): 14 FPS, 354 frames, 640×480, moving
/// camera. Object speeds are calibrated so that ~5-frame-stale boxes lose
/// enough IoU to reproduce the paper's mAP drop (86.9 % -> 66.1 % with a
/// single NCS2; §II-B).
pub fn eth_sunnyday(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "eth_sunnyday".to_string(),
        fps: 14.0,
        num_frames: 354,
        width: 640,
        height: 480,
        camera: CameraMotion::Pan { speed: 0.12 },
        min_objects: 3,
        max_objects: 6,
        min_speed: 0.12,
        max_speed: 0.32,
        min_height: 0.18,
        max_height: 0.45,
        seed,
    }
}

/// ADL-Rundle-6 analog (Table I): 30 FPS, 525 frames, 1920×1080, static
/// camera, denser pedestrian scene.
pub fn adl_rundle6(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "adl_rundle6".to_string(),
        fps: 30.0,
        num_frames: 525,
        width: 1920,
        height: 1080,
        camera: CameraMotion::Static,
        min_objects: 4,
        max_objects: 8,
        min_speed: 0.12,
        max_speed: 0.35,
        min_height: 0.15,
        max_height: 0.40,
        seed,
    }
}

/// Small clip for PJRT-served end-to-end runs (square frames at the
/// detector's input size).
pub fn tiny_clip(size: u32, num_frames: u32, fps: f64, seed: u64) -> ClipSpec {
    ClipSpec {
        name: format!("tiny{size}"),
        fps,
        num_frames,
        width: size,
        height: size,
        camera: CameraMotion::Static,
        min_objects: 1,
        max_objects: 3,
        min_speed: 0.04,
        max_speed: 0.15,
        min_height: 0.18,
        max_height: 0.42,
        seed,
    }
}

/// Static lobby camera (content-dynamics preset for `gate`): fixed
/// camera, one or two near-stationary figures. Almost every frame is a
/// candidate for motion-gated skipping.
pub fn static_lobby(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "static_lobby".to_string(),
        fps: 15.0,
        num_frames: 450,
        width: 640,
        height: 480,
        camera: CameraMotion::Static,
        min_objects: 1,
        max_objects: 2,
        min_speed: 0.005,
        max_speed: 0.03,
        min_height: 0.18,
        max_height: 0.35,
        seed,
    }
}

/// Fixed highway camera (content-dynamics preset): static mount but
/// constant fast traffic — moderate, sustained motion energy.
pub fn highway_cam(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "highway_cam".to_string(),
        fps: 25.0,
        num_frames: 500,
        width: 1280,
        height: 720,
        camera: CameraMotion::Static,
        min_objects: 3,
        max_objects: 6,
        min_speed: 0.35,
        max_speed: 0.7,
        min_height: 0.12,
        max_height: 0.30,
        seed,
    }
}

/// Broadcast sports feed (content-dynamics preset): panning camera,
/// many fast large objects — nearly every frame needs a detection.
pub fn sports_feed(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "sports_feed".to_string(),
        fps: 30.0,
        num_frames: 600,
        width: 1280,
        height: 720,
        camera: CameraMotion::Pan { speed: 0.25 },
        min_objects: 6,
        max_objects: 10,
        min_speed: 0.4,
        max_speed: 0.9,
        min_height: 0.15,
        max_height: 0.40,
        seed,
    }
}

/// Look up a preset by name (CLI surface).
pub fn by_name(name: &str, seed: u64) -> Option<ClipSpec> {
    match name {
        "eth_sunnyday" | "eth" => Some(eth_sunnyday(seed)),
        "adl_rundle6" | "adl" => Some(adl_rundle6(seed)),
        "static_lobby" | "lobby" => Some(static_lobby(seed)),
        "highway_cam" | "highway" => Some(highway_cam(seed)),
        "sports_feed" | "sports" => Some(sports_feed(seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameters() {
        let eth = eth_sunnyday(0);
        assert_eq!(eth.fps, 14.0);
        assert_eq!(eth.num_frames, 354);
        assert_eq!((eth.width, eth.height), (640, 480));
        assert!(matches!(eth.camera, CameraMotion::Pan { .. }));

        let adl = adl_rundle6(0);
        assert_eq!(adl.fps, 30.0);
        assert_eq!(adl.num_frames, 525);
        assert_eq!((adl.width, adl.height), (1920, 1080));
        assert_eq!(adl.camera, CameraMotion::Static);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("eth", 1).is_some());
        assert!(by_name("adl_rundle6", 1).is_some());
        assert!(by_name("lobby", 1).is_some());
        assert!(by_name("highway_cam", 1).is_some());
        assert!(by_name("sports", 1).is_some());
        assert!(by_name("nope", 1).is_none());
    }

    #[test]
    fn content_dynamics_parameters() {
        let lobby = static_lobby(0);
        assert_eq!(lobby.camera, CameraMotion::Static);
        assert!(lobby.max_speed <= 0.03);
        assert_eq!(lobby.fps, 15.0);
        assert_eq!(lobby.num_frames, 450);

        let highway = highway_cam(0);
        assert_eq!(highway.camera, CameraMotion::Static);
        assert!(highway.min_speed > lobby.max_speed);

        let sports = sports_feed(0);
        assert!(matches!(sports.camera, CameraMotion::Pan { .. }));
        assert!(sports.max_speed >= highway.max_speed);
        assert!(sports.max_objects >= highway.max_objects);
    }

    #[test]
    fn pixel_energy_separates_lobby_from_sports() {
        // Rasterised at a small size to keep the test fast; the widest
        // preset gap (lobby vs sports) must survive the raster noise
        // floor. The full three-way ordering is pinned on the synthetic
        // motion models in `gate::signal`.
        use crate::gate::signal::clip_mean_energy;
        use crate::video::generate;
        let mut lobby = static_lobby(7);
        lobby.num_frames = 24;
        let mut sports = sports_feed(7);
        sports.num_frames = 24;
        let e_lobby = clip_mean_energy(&generate(&lobby, Some(64)));
        let e_sports = clip_mean_energy(&generate(&sports, Some(64)));
        assert!(
            e_lobby < e_sports,
            "lobby {e_lobby:.5} must stay below sports {e_sports:.5}"
        );
    }
}
