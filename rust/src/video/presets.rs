//! Benchmark clip presets mirroring the paper's Table I, plus small
//! PJRT-scale clips for the live serving examples.

use crate::video::motion::CameraMotion;
use crate::video::ClipSpec;

/// ETH-Sunnyday analog (Table I): 14 FPS, 354 frames, 640×480, moving
/// camera. Object speeds are calibrated so that ~5-frame-stale boxes lose
/// enough IoU to reproduce the paper's mAP drop (86.9 % -> 66.1 % with a
/// single NCS2; §II-B).
pub fn eth_sunnyday(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "eth_sunnyday".to_string(),
        fps: 14.0,
        num_frames: 354,
        width: 640,
        height: 480,
        camera: CameraMotion::Pan { speed: 0.12 },
        min_objects: 3,
        max_objects: 6,
        min_speed: 0.12,
        max_speed: 0.32,
        min_height: 0.18,
        max_height: 0.45,
        seed,
    }
}

/// ADL-Rundle-6 analog (Table I): 30 FPS, 525 frames, 1920×1080, static
/// camera, denser pedestrian scene.
pub fn adl_rundle6(seed: u64) -> ClipSpec {
    ClipSpec {
        name: "adl_rundle6".to_string(),
        fps: 30.0,
        num_frames: 525,
        width: 1920,
        height: 1080,
        camera: CameraMotion::Static,
        min_objects: 4,
        max_objects: 8,
        min_speed: 0.12,
        max_speed: 0.35,
        min_height: 0.15,
        max_height: 0.40,
        seed,
    }
}

/// Small clip for PJRT-served end-to-end runs (square frames at the
/// detector's input size).
pub fn tiny_clip(size: u32, num_frames: u32, fps: f64, seed: u64) -> ClipSpec {
    ClipSpec {
        name: format!("tiny{size}"),
        fps,
        num_frames,
        width: size,
        height: size,
        camera: CameraMotion::Static,
        min_objects: 1,
        max_objects: 3,
        min_speed: 0.04,
        max_speed: 0.15,
        min_height: 0.18,
        max_height: 0.42,
        seed,
    }
}

/// Look up a preset by name (CLI surface).
pub fn by_name(name: &str, seed: u64) -> Option<ClipSpec> {
    match name {
        "eth_sunnyday" | "eth" => Some(eth_sunnyday(seed)),
        "adl_rundle6" | "adl" => Some(adl_rundle6(seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameters() {
        let eth = eth_sunnyday(0);
        assert_eq!(eth.fps, 14.0);
        assert_eq!(eth.num_frames, 354);
        assert_eq!((eth.width, eth.height), (640, 480));
        assert!(matches!(eth.camera, CameraMotion::Pan { .. }));

        let adl = adl_rundle6(0);
        assert_eq!(adl.fps, 30.0);
        assert_eq!(adl.num_frames, 525);
        assert_eq!((adl.width, adl.height), (1920, 1080));
        assert_eq!(adl.camera, CameraMotion::Static);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("eth", 1).is_some());
        assert!(by_name("adl_rundle6", 1).is_some());
        assert!(by_name("nope", 1).is_none());
    }
}
