//! RGB8 rasterisation matching `python/compile/scene.py`'s appearance
//! contract, so the build-time-trained TinyDet generalises to the frames
//! this module produces at serving time.

use crate::util::Rng;
use crate::video::motion::TrackState;

/// Per-class base colour (r, g, b) in [0,1] — shared contract with
/// `python/compile/scene.py::CLASS_APPEARANCE`.
pub const CLASS_COLOUR: [[f32; 3]; 3] = [
    [0.85, 0.25, 0.20], // person  — reddish
    [0.25, 0.30, 0.85], // cyclist — bluish
    [0.20, 0.80, 0.30], // car     — greenish
];

/// Render one frame at `size`² resolution: low-frequency grayish noise
/// background plus the objects as bordered colour blocks.
pub fn rasterize_frame(
    rng: &mut Rng,
    size: u32,
    tracks: &[TrackState],
    cam: (f64, f64),
) -> Vec<u8> {
    let s = size as usize;
    let mut img = background(rng, s);
    for t in tracks {
        let vb = t.view_box(cam);
        if vb.visible_fraction() <= 0.0 {
            continue;
        }
        draw_object(rng, &mut img, s, vb.cx, vb.cy, vb.w, vb.h, t.class_id, t.shade);
    }
    // f32 [0,1] -> u8.
    img.iter()
        .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
        .collect()
}

/// Low-frequency grayish background in [0.25, 0.65], f32 RGB row-major.
fn background(rng: &mut Rng, s: usize) -> Vec<f32> {
    let coarse_n = s / 8 + 2;
    let mut coarse = vec![0.0f32; coarse_n * coarse_n];
    for v in coarse.iter_mut() {
        *v = rng.range(0.25, 0.65) as f32;
    }
    // Hoist the per-column interpolation coefficients (identical for
    // every row) out of the pixel loop — §Perf iteration 2.
    let xcoef: Vec<(usize, f32)> = (0..s)
        .map(|x| {
            let fx = x as f32 / 8.0;
            let x0 = (fx as usize).min(coarse_n - 2);
            (x0, fx - x0 as f32)
        })
        .collect();
    let mut img = vec![0.0f32; s * s * 3];
    for y in 0..s {
        let fy = y as f32 / 8.0;
        let y0 = (fy as usize).min(coarse_n - 2);
        let ty = fy - y0 as f32;
        let row0 = &coarse[y0 * coarse_n..(y0 + 1) * coarse_n];
        let row1 = &coarse[(y0 + 1) * coarse_n..(y0 + 2) * coarse_n];
        for (x, &(x0, tx)) in xcoef.iter().enumerate() {
            let top = row0[x0] * (1.0 - tx) + row0[x0 + 1] * tx;
            let bot = row1[x0] * (1.0 - tx) + row1[x0 + 1] * tx;
            let v = top * (1.0 - ty) + bot * ty + 0.02 * rng.fast_normalish() as f32;
            let v = v.clamp(0.0, 1.0);
            let idx = (y * s + x) * 3;
            img[idx] = v;
            img[idx + 1] = v;
            img[idx + 2] = v;
        }
    }
    img
}

#[allow(clippy::too_many_arguments)]
fn draw_object(
    rng: &mut Rng,
    img: &mut [f32],
    s: usize,
    cx: f32,
    cy: f32,
    w: f32,
    h: f32,
    class_id: usize,
    shade: f32,
) {
    let x0 = (((cx - w / 2.0) * s as f32).round() as i64).max(0) as usize;
    let x1 = ((((cx + w / 2.0) * s as f32).round() as i64).min(s as i64)) as usize;
    let y0 = (((cy - h / 2.0) * s as f32).round() as i64).max(0) as usize;
    let y1 = ((((cy + h / 2.0) * s as f32).round() as i64).min(s as i64)) as usize;
    if x1 <= x0 || y1 <= y0 {
        return;
    }
    let base = CLASS_COLOUR[class_id];
    for y in y0..y1 {
        for x in x0..x1 {
            let idx = (y * s + x) * 3;
            for c in 0..3 {
                let v = base[c] * shade + 0.04 * rng.fast_normalish() as f32;
                img[idx + c] = v.clamp(0.0, 1.0);
            }
        }
    }
    // Darker border (localisation cue, as in the python generator).
    if y1 - y0 > 2 && x1 - x0 > 2 {
        for x in x0..x1 {
            for &y in &[y0, y1 - 1] {
                let idx = (y * s + x) * 3;
                for c in 0..3 {
                    img[idx + c] *= 0.5;
                }
            }
        }
        for y in y0..y1 {
            for &x in &[x0, x1 - 1] {
                let idx = (y * s + x) * 3;
                for c in 0..3 {
                    img[idx + c] *= 0.5;
                }
            }
        }
    }
}

/// Write a frame as a binary PPM (P6) — used by `eva visualize` to dump
/// Figure 2/3-style comparisons without an image stack.
pub fn write_ppm(path: &std::path::Path, width: u32, height: u32, rgb: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{} {}\n255\n", width, height)?;
    f.write_all(rgb)?;
    Ok(())
}

/// Draw a 1-pixel rectangle outline (for detection overlays in dumps).
pub fn draw_box_outline(rgb: &mut [u8], size: usize, bbox: &crate::types::BBox, colour: [u8; 3]) {
    let (x0f, y0f, x1f, y1f) = bbox.corners();
    let x0 = ((x0f * size as f32) as i64).clamp(0, size as i64 - 1) as usize;
    let x1 = ((x1f * size as f32) as i64).clamp(0, size as i64 - 1) as usize;
    let y0 = ((y0f * size as f32) as i64).clamp(0, size as i64 - 1) as usize;
    let y1 = ((y1f * size as f32) as i64).clamp(0, size as i64 - 1) as usize;
    for x in x0..=x1 {
        for &y in &[y0, y1] {
            let idx = (y * size + x) * 3;
            rgb[idx..idx + 3].copy_from_slice(&colour);
        }
    }
    for y in y0..=y1 {
        for &x in &[x0, x1] {
            let idx = (y * size + x) * 3;
            rgb[idx..idx + 3].copy_from_slice(&colour);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::presets;
    use crate::video::motion::TrackState;

    #[test]
    fn background_is_grayish_and_bounded() {
        let mut rng = Rng::new(0);
        let img = background(&mut rng, 64);
        assert_eq!(img.len(), 64 * 64 * 3);
        for px in img.chunks(3) {
            assert!(px[0] >= 0.0 && px[0] <= 1.0);
            // Grayish: channels identical by construction.
            assert_eq!(px[0], px[1]);
            assert_eq!(px[1], px[2]);
        }
    }

    #[test]
    fn object_pixels_dominated_by_class_colour() {
        let mut rng = Rng::new(1);
        let spec = presets::tiny_clip(64, 1, 10.0, 0);
        for class_id in 0..3 {
            let mut t = TrackState::spawn(&mut rng, &spec, 0, true);
            t.class_id = class_id;
            t.x = 0.5;
            t.y = 0.5;
            t.w = 0.3;
            t.h = 0.3;
            t.shade = 1.0;
            let rgb = rasterize_frame(&mut rng, 64, &[t], (0.0, 0.0));
            // Sample the centre pixel.
            let idx = (32 * 64 + 32) * 3;
            let px = [rgb[idx] as f32, rgb[idx + 1] as f32, rgb[idx + 2] as f32];
            let dominant = px
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            let expected = CLASS_COLOUR[class_id]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(dominant, expected, "class {class_id}");
        }
    }

    #[test]
    fn rasterize_output_size() {
        let mut rng = Rng::new(2);
        let rgb = rasterize_frame(&mut rng, 32, &[], (0.0, 0.0));
        assert_eq!(rgb.len(), 32 * 32 * 3);
    }

    #[test]
    fn box_outline_stays_in_bounds() {
        let mut rgb = vec![0u8; 16 * 16 * 3];
        let b = crate::types::BBox::new(0.9, 0.9, 0.5, 0.5); // spills over edge
        draw_box_outline(&mut rgb, 16, &b, [255, 0, 0]);
        // No panic + some pixels set.
        assert!(rgb.iter().any(|&v| v == 255));
    }
}
