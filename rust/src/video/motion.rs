//! Object and camera kinematics for the synthetic clips.
//!
//! Objects follow a constant-velocity random-walk with soft bouncing at a
//! world margin; a moving-camera clip (ETH-Sunnyday analog) additionally
//! pans the whole view, which is what makes stale detections misalign
//! quickly in the paper's Figure 3.

use crate::types::BBox;
use crate::util::Rng;
use crate::video::ClipSpec;

/// Per-class aspect ratio h/w — shared contract with
/// `python/compile/scene.py::CLASS_APPEARANCE`.
pub const CLASS_ASPECT: [f64; 3] = [2.6, 1.1, 0.45];

/// Camera model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CameraMotion {
    /// Fixed camera (ADL-Rundle-6 analog).
    Static,
    /// Smooth panning camera with the given mean speed
    /// (normalised units/second; ETH-Sunnyday analog).
    Pan { speed: f64 },
}

/// Evolving camera offset.
#[derive(Debug, Clone)]
pub struct CameraState {
    motion: CameraMotion,
    off_x: f64,
    off_y: f64,
    vel_x: f64,
    vel_y: f64,
}

impl CameraState {
    pub fn new(rng: &mut Rng, motion: CameraMotion) -> CameraState {
        let (vel_x, vel_y) = match motion {
            CameraMotion::Static => (0.0, 0.0),
            CameraMotion::Pan { speed } => {
                let dir = if rng.chance(0.5) { 1.0 } else { -1.0 };
                (dir * speed, 0.15 * speed * rng.normal())
            }
        };
        CameraState {
            motion,
            off_x: 0.0,
            off_y: 0.0,
            vel_x,
            vel_y,
        }
    }

    pub fn step(&mut self, rng: &mut Rng, dt: f64) {
        if let CameraMotion::Pan { speed } = self.motion {
            // Small heading jitter; occasional direction reversal keeps the
            // pan bounded over long clips.
            self.vel_x += 0.3 * speed * rng.normal() * dt;
            self.vel_y += 0.1 * speed * rng.normal() * dt;
            let cap = 1.5 * speed;
            self.vel_x = self.vel_x.clamp(-cap, cap);
            self.vel_y = self.vel_y.clamp(-cap / 3.0, cap / 3.0);
            self.off_x += self.vel_x * dt;
            self.off_y += self.vel_y * dt;
        }
    }

    /// Current (x, y) view offset: subtracted from world coordinates.
    pub fn offset(&self) -> (f64, f64) {
        (self.off_x, self.off_y)
    }
}

/// One moving object (world coordinates relative to the camera's initial
/// view; the camera offset maps world -> view).
#[derive(Debug, Clone)]
pub struct TrackState {
    pub track_id: u32,
    pub class_id: usize,
    pub x: f64,
    pub y: f64,
    pub vx: f64,
    pub vy: f64,
    pub w: f64,
    pub h: f64,
    /// Per-object colour shade (raster detail).
    pub shade: f32,
}

impl TrackState {
    /// Spawn a new object. `initial` places it anywhere in view;
    /// respawns enter from the view margin.
    pub fn spawn(rng: &mut Rng, spec: &ClipSpec, track_id: u32, initial: bool) -> TrackState {
        let class_id = rng.below(CLASS_ASPECT.len() as u64) as usize;
        let h = rng.range(spec.min_height, spec.max_height);
        let w = h / CLASS_ASPECT[class_id];
        let speed = rng.range(spec.min_speed, spec.max_speed);
        let angle = rng.range(0.0, std::f64::consts::TAU);
        let (x, y) = if initial {
            (rng.range(0.12, 0.88), rng.range(0.15, 0.85))
        } else {
            // Enter from a random edge, slightly outside.
            match rng.below(4) {
                0 => (-0.05, rng.range(0.2, 0.8)),
                1 => (1.05, rng.range(0.2, 0.8)),
                2 => (rng.range(0.2, 0.8), -0.05),
                _ => (rng.range(0.2, 0.8), 1.05),
            }
        };
        TrackState {
            track_id,
            class_id,
            x,
            y,
            vx: speed * angle.cos(),
            vy: 0.35 * speed * angle.sin(), // mostly lateral motion (street view)
            w,
            h,
            shade: rng.range(0.75, 1.15) as f32,
        }
    }

    /// Advance one timestep with velocity jitter and soft world bounce.
    pub fn step(&mut self, rng: &mut Rng, dt: f64) {
        self.vx += 0.3 * self.vx.abs().max(0.02) * rng.normal() * dt;
        self.vy += 0.3 * self.vy.abs().max(0.02) * rng.normal() * dt;
        self.x += self.vx * dt;
        self.y += self.vy * dt;
        // Soft bounce at a generous world margin so objects stay around.
        if self.x < -0.2 {
            self.vx = self.vx.abs();
        }
        if self.x > 1.2 {
            self.vx = -self.vx.abs();
        }
        if self.y < -0.1 {
            self.vy = self.vy.abs();
        }
        if self.y > 1.1 {
            self.vy = -self.vy.abs();
        }
    }

    /// Bounding box in *view* coordinates for camera offset `cam`.
    pub fn view_box(&self, cam: (f64, f64)) -> ViewBox {
        ViewBox {
            cx: (self.x - cam.0) as f32,
            cy: (self.y - cam.1) as f32,
            w: self.w as f32,
            h: self.h as f32,
        }
    }
}

/// Box in view coordinates (may extend outside [0,1]²).
#[derive(Debug, Clone, Copy)]
pub struct ViewBox {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
}

impl ViewBox {
    pub fn as_bbox(&self) -> BBox {
        BBox::new(self.cx, self.cy, self.w, self.h)
    }

    pub fn visible_fraction(&self) -> f32 {
        self.as_bbox().visible_fraction()
    }

    /// Clip the box to the visible frame (MOT annotations clamp at image
    /// borders), preserving centre+size form.
    pub fn clamped_to_visible(&self) -> BBox {
        let (x0, y0, x1, y1) = self.as_bbox().corners();
        BBox::from_corners(x0.max(0.0), y0.max(0.0), x1.min(1.0), y1.min(1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::presets;

    #[test]
    fn static_camera_never_moves() {
        let mut rng = Rng::new(0);
        let mut cam = CameraState::new(&mut rng, CameraMotion::Static);
        for _ in 0..100 {
            cam.step(&mut rng, 0.1);
        }
        assert_eq!(cam.offset(), (0.0, 0.0));
    }

    #[test]
    fn pan_camera_moves() {
        let mut rng = Rng::new(1);
        let mut cam = CameraState::new(&mut rng, CameraMotion::Pan { speed: 0.1 });
        for _ in 0..50 {
            cam.step(&mut rng, 0.1);
        }
        let (x, _) = cam.offset();
        assert!(x.abs() > 1e-3, "pan offset {x}");
    }

    #[test]
    fn spawned_object_valid() {
        let mut rng = Rng::new(2);
        let spec = presets::eth_sunnyday(0);
        for i in 0..50 {
            let t = TrackState::spawn(&mut rng, &spec, i, i % 2 == 0);
            assert!(t.class_id < 3);
            assert!(t.h >= spec.min_height && t.h <= spec.max_height);
            let speed = (t.vx * t.vx + t.vy * t.vy).sqrt();
            assert!(speed <= spec.max_speed * 1.01);
        }
    }

    #[test]
    fn step_keeps_object_in_world_band() {
        let mut rng = Rng::new(3);
        let spec = presets::adl_rundle6(0);
        let mut t = TrackState::spawn(&mut rng, &spec, 0, true);
        for _ in 0..2_000 {
            t.step(&mut rng, 1.0 / 30.0);
            assert!(t.x > -2.0 && t.x < 3.0, "x diverged: {}", t.x);
            assert!(t.y > -2.0 && t.y < 3.0, "y diverged: {}", t.y);
        }
    }

    #[test]
    fn viewbox_clamps() {
        let vb = ViewBox {
            cx: 0.02,
            cy: 0.5,
            w: 0.2,
            h: 0.2,
        };
        let clamped = vb.clamped_to_visible();
        let (x0, ..) = clamped.corners();
        assert!(x0 >= 0.0);
    }
}
