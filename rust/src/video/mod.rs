//! Synthetic benchmark video substrate.
//!
//! The paper evaluates on two MOT-15 clips (ETH-Sunnyday, ADL-Rundle-6)
//! we cannot redistribute; this module generates statistically analogous
//! clips (DESIGN.md §3): textured backgrounds, moving objects of the three
//! shared classes with exact per-frame ground truth, optional global
//! camera motion, at the paper's exact frame rates / counts / resolutions.
//!
//! Two fidelity levels share one ground-truth trajectory engine:
//! * **metadata-only** frames (no pixels) for the virtual-time experiments
//!   driving the calibrated quality-model detector, and
//! * **rastered** frames (RGB8, matching `python/compile/scene.py`'s
//!   appearance contract) for the real PJRT-served TinyDet.

pub mod motion;
pub mod raster;
pub mod presets;

use crate::types::{Frame, GtBox};
use crate::util::Rng;
use motion::{CameraMotion, TrackState};

/// Full description of a synthetic clip; generation is deterministic in
/// `(spec, seed)`.
#[derive(Debug, Clone)]
pub struct ClipSpec {
    pub name: String,
    /// Capture rate λ (frames/second).
    pub fps: f64,
    pub num_frames: u32,
    pub width: u32,
    pub height: u32,
    pub camera: CameraMotion,
    /// Number of simultaneously visible objects.
    pub min_objects: u32,
    pub max_objects: u32,
    /// Object speed range, normalised image units per second.
    pub min_speed: f64,
    pub max_speed: f64,
    /// Object height range (normalised).
    pub min_height: f64,
    pub max_height: f64,
    pub seed: u64,
}

impl ClipSpec {
    /// Stream duration in seconds.
    pub fn duration(&self) -> f64 {
        self.num_frames as f64 / self.fps
    }
}

/// A generated clip: spec + frames (with ground truth; pixels optional).
#[derive(Debug, Clone)]
pub struct Clip {
    pub spec: ClipSpec,
    pub frames: Vec<Frame>,
}

impl Clip {
    pub fn fps(&self) -> f64 {
        self.spec.fps
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Ground-truth table: frame -> gt boxes (borrowed view).
    pub fn ground_truth(&self) -> Vec<&[GtBox]> {
        self.frames.iter().map(|f| f.ground_truth.as_slice()).collect()
    }
}

/// Generate a clip. `rasterize` controls whether RGB8 pixels are produced
/// (at `raster_size`² resolution — the detector input size — rather than
/// the nominal clip resolution, since the serving path resizes anyway and
/// the nominal 1920×1080 raster would only burn memory).
pub fn generate(spec: &ClipSpec, rasterize: Option<u32>) -> Clip {
    let mut rng = Rng::new(spec.seed);
    let mut tracks: Vec<TrackState> = Vec::new();
    let mut next_track_id = 0u32;

    let initial = rng.int_in(spec.min_objects as i64, spec.max_objects as i64) as usize;
    for _ in 0..initial {
        tracks.push(TrackState::spawn(&mut rng, spec, next_track_id, true));
        next_track_id += 1;
    }

    let dt = 1.0 / spec.fps;
    let mut camera = motion::CameraState::new(&mut rng, spec.camera);
    let mut frames = Vec::with_capacity(spec.num_frames as usize);

    for fid in 0..spec.num_frames {
        // Advance world.
        if fid > 0 {
            camera.step(&mut rng, dt);
            for t in tracks.iter_mut() {
                t.step(&mut rng, dt);
            }
            // Respawn tracks that wandered fully out of view, keeping the
            // visible population inside [min_objects, max_objects].
            let cam = camera.offset();
            for t in tracks.iter_mut() {
                if t.view_box(cam).visible_fraction() < 0.05 {
                    *t = TrackState::spawn(&mut rng, spec, next_track_id, false);
                    next_track_id += 1;
                }
            }
        }

        let cam = camera.offset();
        let ground_truth: Vec<GtBox> = tracks
            .iter()
            .filter_map(|t| {
                let vb = t.view_box(cam);
                // Only annotate objects meaningfully in view (MOT-style).
                if vb.visible_fraction() >= 0.25 {
                    Some(GtBox {
                        bbox: vb.clamped_to_visible(),
                        class_id: t.class_id,
                        track_id: t.track_id,
                    })
                } else {
                    None
                }
            })
            .collect();

        let pixels = match rasterize {
            Some(size) => raster::rasterize_frame(&mut rng, size, &tracks, cam),
            None => Vec::new(),
        };
        let (w, h) = match rasterize {
            Some(size) => (size, size),
            None => (spec.width, spec.height),
        };

        frames.push(Frame {
            id: fid as u64,
            ts: fid as f64 * dt,
            width: w,
            height: h,
            pixels,
            ground_truth,
        });
    }

    Clip {
        spec: spec.clone(),
        frames,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::presets;

    #[test]
    fn deterministic_generation() {
        let spec = presets::tiny_clip(64, 20, 10.0, 1);
        let a = generate(&spec, None);
        let b = generate(&spec, None);
        for (fa, fb) in a.frames.iter().zip(&b.frames) {
            assert_eq!(fa.ground_truth.len(), fb.ground_truth.len());
            for (ga, gb) in fa.ground_truth.iter().zip(&fb.ground_truth) {
                assert_eq!(ga.track_id, gb.track_id);
                assert_eq!(ga.bbox, gb.bbox);
            }
        }
    }

    #[test]
    fn frame_count_and_timestamps() {
        let spec = presets::eth_sunnyday(7);
        let clip = generate(&spec, None);
        assert_eq!(clip.len(), 354);
        assert!((clip.frames[1].ts - 1.0 / 14.0).abs() < 1e-9);
        assert!((clip.spec.duration() - 354.0 / 14.0).abs() < 1e-9);
    }

    #[test]
    fn ground_truth_boxes_visible_and_in_range() {
        let spec = presets::adl_rundle6(3);
        let clip = generate(&spec, None);
        let mut total = 0usize;
        for f in &clip.frames {
            for gt in &f.ground_truth {
                total += 1;
                assert!(gt.bbox.visible_fraction() > 0.0);
                assert!(gt.class_id < crate::types::CLASSES.len());
            }
        }
        // Scenes are populated.
        assert!(total as f64 / clip.len() as f64 >= 1.0);
    }

    #[test]
    fn objects_actually_move() {
        let spec = presets::eth_sunnyday(11);
        let clip = generate(&spec, None);
        // Track one identity across 10 frames and require net motion.
        let first = &clip.frames[0].ground_truth[0];
        let id = first.track_id;
        let mut last = first.bbox;
        let mut moved = 0.0f32;
        for f in &clip.frames[1..10] {
            if let Some(gt) = f.ground_truth.iter().find(|g| g.track_id == id) {
                moved += (gt.bbox.cx - last.cx).abs() + (gt.bbox.cy - last.cy).abs();
                last = gt.bbox;
            }
        }
        assert!(moved > 0.0, "object never moved");
    }

    #[test]
    fn rasterized_frames_have_pixels() {
        let spec = presets::tiny_clip(32, 4, 10.0, 5);
        let clip = generate(&spec, Some(32));
        for f in &clip.frames {
            assert_eq!(f.pixels.len(), 32 * 32 * 3);
        }
        let clip2 = generate(&spec, None);
        assert!(clip2.frames[0].pixels.is_empty());
    }
}
