//! Minimal JSON parser + writer (RFC 8259 subset, no external crates).
//!
//! Used to read `artifacts/manifest.json` produced by the python AOT
//! pipeline and to emit machine-readable experiment results.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as f64 (the manifest only carries
/// shapes/counts well inside the 2^53 exact-integer range).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialise compactly. (Deliberately an inherent method — `Json`
    /// does not implement `Display`; the allow keeps the gating clippy
    /// job honest about it.)
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates are replaced (manifest never emits them).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → 🚀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 🚀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":[{"grid":12,"name":"essd","ok":true,"x":1.25}],"z":null}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn writes_integers_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn parse_real_manifest_shape() {
        let src = r#"{
          "format": 1,
          "models": [
            {"name": "essd", "hlo": "essd.hlo.txt",
             "input_shape": [1, 96, 96, 3], "grid": 12,
             "num_classes": 3, "out_rows": 144, "out_cols": 8}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_i64(), Some(1));
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("grid").unwrap().as_i64(), Some(12));
        let shape: Vec<i64> = m
            .get("input_shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap())
            .collect();
        assert_eq!(shape, vec![1, 96, 96, 3]);
    }
}
