//! Minimal JSON parser + writer (RFC 8259 subset, no external crates).
//!
//! Used to read `artifacts/manifest.json` produced by the python AOT
//! pipeline and to emit machine-readable experiment results.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as f64 (the manifest only carries
/// shapes/counts well inside the 2^53 exact-integer range).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialise compactly. (Deliberately an inherent method — `Json`
    /// does not implement `Display`; the allow keeps the gating clippy
    /// job honest about it.)
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; emitting one would
                    // break the encode→parse round trip, so non-finite
                    // numbers serialise as null (wire consumers treat the
                    // field as absent).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape a string per RFC 8259 §7: `"` and `\` escaped, **every**
/// control character U+0000–U+001F escaped (short escapes where they
/// exist, `\u00XX` otherwise) — the wire format depends on arbitrary
/// strings surviving encode→parse (see the round-trip property tests).
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates are replaced (manifest never emits them).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → 🚀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 🚀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":[{"grid":12,"name":"essd","ok":true,"x":1.25}],"z":null}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn writes_integers_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn non_finite_numbers_serialise_as_null() {
        // JSON has no NaN/Infinity literal: emitting one would break the
        // encode→parse guarantee the wire format depends on.
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let v = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN)]);
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_arr().unwrap()[1], Json::Null);
    }

    #[test]
    fn every_control_character_escapes_and_roundtrips() {
        // All of U+0000..=U+001F, plus the quoted/escaped specials.
        let mut s = String::new();
        for cp in 0u32..0x20 {
            s.push(char::from_u32(cp).unwrap());
        }
        s.push('"');
        s.push('\\');
        s.push('é');
        let v = Json::Str(s.clone());
        let text = v.to_string();
        // The encoded form is pure ASCII up to the explicit unicode tail
        // and contains no raw control bytes.
        assert!(!text.bytes().any(|b| b < 0x20), "raw control byte in {text:?}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.as_str(), Some(s.as_str()));
    }

    fn random_string(rng: &mut crate::util::Rng) -> String {
        let len = rng.below(12) as usize;
        let mut s = String::new();
        for _ in 0..len {
            match rng.below(5) {
                // Control characters (the hardening target).
                0 => s.push(char::from_u32(rng.below(0x20) as u32).unwrap()),
                // The escape-relevant specials.
                1 => s.push(*rng.choose(&['"', '\\', '/', '\n', '\t', '\r'])),
                // Plain ASCII.
                2 | 3 => s.push((b'a' + rng.below(26) as u8) as char),
                // Multi-byte unicode.
                _ => s.push(*rng.choose(&['é', '→', '🚀', 'λ', '中'])),
            }
        }
        s
    }

    fn random_value(rng: &mut crate::util::Rng, depth: usize) -> Json {
        let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => {
                // Finite numbers only: ±1e12 with fractional part.
                let n = rng.range(-1e12, 1e12);
                Json::Num(if rng.chance(0.3) { n.trunc() } else { n })
            }
            3 => Json::Str(random_string(rng)),
            4 => {
                let n = rng.below(4) as usize;
                Json::Arr((0..n).map(|_| random_value(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(4) as usize;
                let mut o = BTreeMap::new();
                for _ in 0..n {
                    o.insert(random_string(rng), random_value(rng, depth - 1));
                }
                Json::Obj(o)
            }
        }
    }

    #[test]
    fn prop_strings_roundtrip_through_encode_parse() {
        use crate::util::prop::{check, Config};
        check("json string round-trip", Config::default(), |rng| {
            let s = random_string(rng);
            let text = Json::Str(s.clone()).to_string();
            match Json::parse(&text) {
                Ok(Json::Str(back)) if back == s => Ok(()),
                Ok(other) => Err(format!("{s:?} -> {text} -> {other:?}")),
                Err(e) => Err(format!("{s:?} -> {text} failed to parse: {e}")),
            }
        });
    }

    #[test]
    fn prop_values_roundtrip_through_encode_parse() {
        use crate::util::prop::{check, Config};
        check("json value round-trip", Config::default(), |rng| {
            let v = random_value(rng, 3);
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| format!("{text}: {e}"))?;
            if back == v {
                Ok(())
            } else {
                Err(format!("{v:?} -> {text} -> {back:?}"))
            }
        });
    }

    #[test]
    fn parse_real_manifest_shape() {
        let src = r#"{
          "format": 1,
          "models": [
            {"name": "essd", "hlo": "essd.hlo.txt",
             "input_shape": [1, 96, 96, 3], "grid": 12,
             "num_classes": 3, "out_rows": 144, "out_cols": 8}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_i64(), Some(1));
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("grid").unwrap().as_i64(), Some(12));
        let shape: Vec<i64> = m
            .get("input_shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap())
            .collect();
        assert_eq!(shape, vec![1, 96, 96, 3]);
    }
}
