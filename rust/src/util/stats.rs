//! Streaming statistics: running mean/variance, EWMA rate estimation,
//! fixed-capacity percentile sketches.
//!
//! Used by the coordinator metrics and by the performance-aware
//! proportional scheduler (§III-C of the paper) for its runtime weights.

/// Value of a step timeline `[(t, v)]` at time `t`: the last entry at or
/// before `t` (with a small tolerance), `None` before the first entry.
/// Shared by rung logs and device-count timelines so boundary semantics
/// cannot drift between copies.
pub fn timeline_at<T: Copy>(log: &[(f64, T)], t: f64) -> Option<T> {
    log.iter()
        .rev()
        .find(|&&(at, _)| at <= t + 1e-12)
        .map(|&(_, v)| v)
}

/// Welford running mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Running {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exponentially-weighted moving average (the proportional scheduler's
/// per-model service-rate estimator).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest sample.
    pub fn new(alpha: f64) -> Ewma {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Reservoir of samples with exact percentiles (capacity-bounded; fine for
/// per-run latency distributions of ≤ millions of frames).
#[derive(Debug, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Default for Percentiles {
    fn default() -> Self {
        Self::new()
    }
}

impl Percentiles {
    pub fn new() -> Percentiles {
        Percentiles {
            samples: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The raw samples, in push order (telemetry snapshots serialise
    /// and merge reservoirs through this).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Percentile in [0, 100], nearest-rank on the sorted samples.
    ///
    /// Takes `&self` so report accessors stay read-only: the already-
    /// sorted fast path indexes directly; otherwise a local sorted copy
    /// answers the query (queries happen at report granularity, so the
    /// copy is cheap relative to keeping every caller `&mut`).
    pub fn pct(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.samples.len() - 1) as f64).round() as usize;
        let rank = rank.min(self.samples.len() - 1);
        if self.sorted {
            return self.samples[rank];
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        sorted[rank]
    }

    pub fn p50(&self) -> f64 {
        self.pct(50.0)
    }
    pub fn p99(&self) -> f64 {
        self.pct(99.0)
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_moments() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn running_empty_is_zero() {
        let r = Running::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.variance(), 0.0);
    }

    #[test]
    fn ewma_tracks_and_smooths() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.push(10.0);
        assert_eq!(e.get(), Some(10.0));
        e.push(0.0);
        assert_eq!(e.get(), Some(5.0));
        e.push(0.0);
        assert_eq!(e.get(), Some(2.5));
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_zero_alpha() {
        Ewma::new(0.0);
    }

    #[test]
    fn ewma_empty_window_reads_the_default() {
        // The gate polls `get_or(raw)` before the first push settles:
        // an empty estimator must surface the caller's default, not 0.
        let e = Ewma::new(0.4);
        assert_eq!(e.get(), None);
        assert_eq!(e.get_or(3.25), 3.25);
        assert_eq!(e.get_or(f64::NEG_INFINITY), f64::NEG_INFINITY);
    }

    #[test]
    fn ewma_single_sample_is_the_sample_at_any_alpha() {
        // The first sample seeds the window verbatim — no phantom decay
        // toward zero regardless of alpha.
        for alpha in [0.01, 0.4, 1.0] {
            let mut e = Ewma::new(alpha);
            e.push(7.5);
            assert_eq!(e.get(), Some(7.5), "alpha {alpha}");
            assert_eq!(e.get_or(0.0), 7.5, "alpha {alpha}");
        }
    }

    #[test]
    fn percentiles_exact() {
        let mut p = Percentiles::new();
        for i in (1..=100).rev() {
            p.push(i as f64);
        }
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.p50() - 50.0).abs() <= 1.0);
        assert!((p.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentiles_empty() {
        let p = Percentiles::new();
        assert_eq!(p.p50(), 0.0);
        assert!(p.is_empty());
    }

    #[test]
    fn percentiles_answer_without_mutation() {
        // pct takes &self: unsorted reservoirs answer from a local copy
        // and the stored push order is untouched.
        let mut p = Percentiles::new();
        for x in [3.0, 1.0, 2.0] {
            p.push(x);
        }
        let p = p; // freeze: queries must not need &mut
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 3.0);
        assert_eq!(p.samples(), &[3.0, 1.0, 2.0]);
    }
}
