//! Zero-dependency substrates.
//!
//! The build environment is fully offline (only the `xla` and `anyhow`
//! crates are vendored), so the usual ecosystem pieces — PRNG, JSON,
//! CLI parsing, table rendering, property testing, micro-benchmarking —
//! are implemented here as first-class, tested modules.

pub mod rng;
pub mod json;
pub mod cli;
pub mod table;
pub mod prop;
pub mod benchkit;
pub mod stats;

pub use rng::Rng;
