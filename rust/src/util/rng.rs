//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component in EVA-RS (video synthesis, detector noise,
//! service-time jitter, property tests) draws from this generator so that
//! whole experiments are reproducible from a single `u64` seed.

/// xoshiro256** 1.0 (Blackman & Vigna), seeded with SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Different seeds give independent
    /// streams; the same seed gives the same stream forever.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-object / per-device RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's nearly-divisionless bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fast approximately-normal sample (triangular: sum of two uniforms,
    /// scaled to unit variance). ~3x cheaper than Box–Muller (no ln/cos);
    /// used on the per-pixel raster hot path where only the noise
    /// *texture statistics* matter, not exact normality.
    #[inline]
    pub fn fast_normalish(&mut self) -> f64 {
        (self.f64() + self.f64() - 1.0) * 2.449_489_742_783_178
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn int_in_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1_000 {
            let v = r.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(29);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
