//! Miniature property-testing driver (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`crate::util::Rng`]; the driver
//! runs it for many cases and, on failure, reports the failing seed so the
//! case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this image;
//! // the same driver is exercised for real by this module's unit tests)
//! use eva::util::prop::{check, Config};
//! check("sum is commutative", Config::default(), |rng| {
//!     let a = rng.int_in(-1000, 1000);
//!     let b = rng.int_in(-1000, 1000);
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            base_seed: 0xE7A_BA5E,
        }
    }
}

/// Run a property for `config.cases` seeds; panics with the failing seed
/// and the property's message on the first failure.
pub fn check<F>(name: &str, config: Config, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..config.cases {
        let seed = config.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {i} (replay seed {seed}): {msg}"
            );
        }
    }
}

/// Replay a single failing seed (used when debugging a reported failure).
pub fn replay<F>(seed: u64, mut property: F) -> Result<(), String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    property(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", Config { cases: 10, base_seed: 1 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("fails", Config { cases: 5, base_seed: 9 }, |rng| {
            let v = rng.below(10);
            if v < 10 {
                Err(format!("v = {v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn replay_reproduces() {
        // Find a value with a fresh rng, then replay the same seed.
        let mut first = None;
        let _ = replay(1234, |rng| {
            first = Some(rng.below(1000));
            Ok(())
        });
        let mut second = None;
        let _ = replay(1234, |rng| {
            second = Some(rng.below(1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
