//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `benches/*.rs` are built with `harness = false` and use this module:
//! warmup, timed iterations, mean / p50 / p99, and a one-line report that
//! `cargo bench` prints. A `black_box` prevents the optimiser from
//! deleting the measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-exported optimisation barrier.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// items/second if `items_per_iter` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.mean.as_secs_f64().max(1e-12))
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>10.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {:>10.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} mean {:>12} p50 {:>12} p99  x{}{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
            self.iters,
            tp
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with fixed warmup and iteration counts.
pub struct Bench {
    warmup: u32,
    iters: u32,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(warmup: u32, iters: u32) -> Bench {
        assert!(iters > 0);
        Bench {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Standard config: honors `EVA_BENCH_FAST=1` for smoke runs.
    pub fn standard() -> Bench {
        if std::env::var("EVA_BENCH_FAST").as_deref() == Ok("1") {
            Bench::new(1, 5)
        } else {
            Bench::new(3, 30)
        }
    }

    /// Time `f` and record the measurement. `items_per_iter` enables
    /// throughput reporting.
    pub fn run<F, R>(&mut self, name: &str, items_per_iter: Option<f64>, mut f: F) -> &Measurement
    where
        F: FnMut() -> R,
    {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let m = Measurement {
            name: name.to_string(),
            iters: self.iters,
            mean: total / self.iters,
            p50: samples[samples.len() / 2],
            p99: samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)],
            min: samples[0],
            max: *samples.last().unwrap(),
            items_per_iter,
        };
        println!("{}", m.report_line());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(1, 5);
        let m = b.run("spin", Some(1000.0), || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(m.mean > Duration::ZERO);
        assert!(m.throughput().unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
