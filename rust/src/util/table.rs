//! Plain-text table rendering for the experiment harness.
//!
//! The bench binaries print paper-style tables (Tables IV–X) with this;
//! it also emits CSV for downstream plotting.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render with unicode-free ASCII framing (stable in logs).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV rendering (header + rows; RFC-4180 quoting where needed).
    pub fn to_csv(&self) -> String {
        fn cell(s: &str) -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| cell(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| cell(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (helper for table cells).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Format a percentage with one decimal, e.g. `86.9`.
pub fn pct(v: f64) -> String {
    format!("{:.1}", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["long".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        // All framed lines have the same width.
        let w = lines[1].len();
        assert!(lines[1..].iter().all(|l| l.len() == w));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_quotes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "pl\"ain".into()]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().nth(1).unwrap(), "\"x,y\",\"pl\"\"ain\"");
    }

    #[test]
    fn float_helpers() {
        assert_eq!(f(3.14159, 2), "3.14");
        assert_eq!(pct(0.869), "86.9");
    }
}
