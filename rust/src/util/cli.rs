//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and automatic usage text.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    // Option names the user wrote on the command line, as opposed to
    // values filled in from spec defaults: `opts` cannot distinguish
    // the two, and applicability gating must only fire on user intent.
    provided: Vec<String>,
}

/// Option/flag declaration used for usage text and validation.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse raw args (without argv[0]). `specs` drives which `--name`s
    /// take a value; unknown options are an error.
    pub fn parse(raw: &[String], specs: &[Spec]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    out.provided.push(name.clone());
                    out.opts.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults.
        for s in specs {
            if s.takes_value && !out.opts.contains_key(s.name) {
                if let Some(d) = s.default {
                    out.opts.insert(s.name.to_string(), d.to_string());
                }
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// True only if the user wrote `--name` on the command line —
    /// whether value-taking or boolean. A value filled in from a spec
    /// default does *not* count, which is what makes this the right
    /// predicate for "does this flag apply to this subcommand" gating.
    pub fn passed(&self, name: &str) -> bool {
        self.provided.iter().any(|p| p == name) || self.flag(name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        Ok(self.parse_as::<u64>(name)?.unwrap_or(default))
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        Ok(self.parse_as::<f64>(name)?.unwrap_or(default))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        Ok(self.parse_as::<usize>(name)?.unwrap_or(default))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render usage text from specs.
pub fn usage(program: &str, about: &str, specs: &[Spec]) -> String {
    let mut s = format!("{program} — {about}\n\noptions:\n");
    for spec in specs {
        let head = if spec.takes_value {
            format!("  --{} <v>", spec.name)
        } else {
            format!("  --{}", spec.name)
        };
        let pad = 26usize.saturating_sub(head.len());
        s.push_str(&head);
        s.push_str(&" ".repeat(pad));
        s.push_str(spec.help);
        if let Some(d) = spec.default {
            s.push_str(&format!(" [default: {d}]"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![
            Spec { name: "model", takes_value: true, help: "model name", default: Some("eyolo") },
            Spec { name: "n", takes_value: true, help: "replicas", default: None },
            Spec { name: "verbose", takes_value: false, help: "chatty", default: None },
        ]
    }

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&raw(&["--model", "essd", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get("model"), Some("essd"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&raw(&["--n=5"]), &specs()).unwrap();
        assert_eq!(a.u64_or("n", 1).unwrap(), 5);
    }

    #[test]
    fn applies_defaults() {
        let a = Args::parse(&raw(&[]), &specs()).unwrap();
        assert_eq!(a.get("model"), Some("eyolo"));
        assert_eq!(a.get("n"), None);
    }

    #[test]
    fn defaults_do_not_count_as_passed() {
        let a = Args::parse(&raw(&["--verbose"]), &specs()).unwrap();
        assert_eq!(a.get("model"), Some("eyolo"), "default still readable");
        assert!(!a.passed("model"), "spec default must not register as user intent");
        assert!(a.passed("verbose"));
        let b = Args::parse(&raw(&["--model", "essd"]), &specs()).unwrap();
        assert!(b.passed("model"));
        assert!(!b.passed("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&raw(&["--bogus"]), &specs()).is_err());
        assert!(Args::parse(&raw(&["--n"]), &specs()).is_err());
        assert!(Args::parse(&raw(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let a = Args::parse(&raw(&["--n", "abc"]), &specs()).unwrap();
        assert!(a.u64_or("n", 1).is_err());
    }

    #[test]
    fn usage_contains_options() {
        let u = usage("eva", "edge video analytics", &specs());
        assert!(u.contains("--model"));
        assert!(u.contains("default: eyolo"));
    }
}
