//! Calibrated detector quality model.
//!
//! Emulates a well-trained detector's *output statistics* on a frame whose
//! ground truth is known: per-object detection with localisation jitter,
//! misses, class confusion, plus background false positives. The four
//! (model × video) parameter sets are calibrated so the zero-drop mAP
//! measured by [`crate::eval::evaluate_map`] lands near the paper's
//! baselines (ETH: YOLO 86.9 % / SSD 74.5 %; ADL: YOLO 62.5 % / SSD
//! 54.4 %) — see EXPERIMENTS.md §Calibration for measured values.
//!
//! Everything downstream (dropping, stale reuse, synchronisation, mAP) is
//! computed by the real pipeline; only the per-frame detector response is
//! modelled.

use crate::detector::Detector;
use crate::device::DetectorModelId;
use crate::types::{Detection, Frame, CLASSES};
use crate::util::Rng;

/// Statistical response parameters of one detector on one video domain.
#[derive(Debug, Clone)]
pub struct QualityProfile {
    pub name: String,
    /// Probability a ground-truth object is missed entirely.
    pub miss_rate: f64,
    /// Expected background false positives per frame (Poisson-ish).
    pub fp_per_frame: f64,
    /// Localisation jitter, std as a fraction of box size.
    pub pos_jitter: f64,
    /// Size jitter, std as a fraction of box size.
    pub size_jitter: f64,
    /// Probability a detected object gets the wrong class label.
    pub confusion_rate: f64,
    /// True-positive confidence range.
    pub tp_score: (f32, f32),
    /// False-positive confidence range (overlaps the TP range from below;
    /// the overlap shapes the PR curve).
    pub fp_score: (f32, f32),
}

impl QualityProfile {
    /// Calibrated profile for a paper model on a paper video.
    /// `video` is matched by preset name (`eth_sunnyday` / `adl_rundle6`).
    pub fn calibrated(model: DetectorModelId, video: &str) -> QualityProfile {
        let eth = video.starts_with("eth");
        match (model, eth) {
            // ETH-Sunnyday: 640×480, large objects — easy domain.
            (DetectorModelId::Yolov3, true) => QualityProfile {
                name: "yolov3@eth".into(),
                miss_rate: 0.11,
                fp_per_frame: 0.40,
                pos_jitter: 0.05,
                size_jitter: 0.05,
                confusion_rate: 0.01,
                tp_score: (0.55, 0.99),
                fp_score: (0.30, 0.62),
            },
            (DetectorModelId::Ssd300, true) => QualityProfile {
                name: "ssd300@eth".into(),
                miss_rate: 0.17,
                fp_per_frame: 0.60,
                pos_jitter: 0.07,
                size_jitter: 0.07,
                confusion_rate: 0.02,
                tp_score: (0.50, 0.97),
                fp_score: (0.32, 0.68),
            },
            // ADL-Rundle-6: 1080p crowded scene — harder domain.
            (DetectorModelId::Yolov3, false) => QualityProfile {
                name: "yolov3@adl".into(),
                miss_rate: 0.32,
                fp_per_frame: 1.1,
                pos_jitter: 0.07,
                size_jitter: 0.07,
                confusion_rate: 0.02,
                tp_score: (0.50, 0.97),
                fp_score: (0.33, 0.70),
            },
            (DetectorModelId::Ssd300, false) => QualityProfile {
                name: "ssd300@adl".into(),
                miss_rate: 0.36,
                fp_per_frame: 1.4,
                pos_jitter: 0.085,
                size_jitter: 0.085,
                confusion_rate: 0.03,
                tp_score: (0.45, 0.95),
                fp_score: (0.33, 0.72),
            },
        }
    }

    /// Tiny (edge-quantised, pruned-backbone) variant of a calibrated
    /// profile: [`QualityProfile::tiny_speedup`]× faster inference
    /// bought with a higher miss rate and noisier boxes. These are the
    /// lower rungs of the autoscale model ladder
    /// (`crate::autoscale::ladder`), the SSD300 ↔ YOLOv3 ↔ TinyDet
    /// trade-off from the quality-aware admission design.
    pub fn tiny(model: DetectorModelId, video: &str) -> QualityProfile {
        let mut p = Self::calibrated(model, video);
        p.name = format!("tiny-{}", p.name);
        p.miss_rate = (p.miss_rate * 1.9 + 0.06).min(0.9);
        p.fp_per_frame *= 1.5;
        p.pos_jitter *= 1.6;
        p.size_jitter *= 1.6;
        p.confusion_rate = (p.confusion_rate * 2.0).min(0.2);
        p.tp_score = (p.tp_score.0 * 0.9, p.tp_score.1);
        p
    }

    /// Service-rate multiplier of the tiny variant relative to its full
    /// parent model (smaller input, pruned backbone; in the spirit of
    /// YOLOv3-tiny's published speedups on edge accelerators).
    pub fn tiny_speedup(model: DetectorModelId) -> f64 {
        match model {
            DetectorModelId::Yolov3 => 2.6,
            DetectorModelId::Ssd300 => 3.2,
        }
    }
}

/// One detector replica driven by the quality model.
pub struct QualityModelDetector {
    profile: QualityProfile,
    rng: Rng,
}

impl QualityModelDetector {
    pub fn new(profile: QualityProfile, seed: u64) -> QualityModelDetector {
        QualityModelDetector {
            profile,
            rng: Rng::new(seed),
        }
    }

    fn sample_fp(&mut self) -> Detection {
        let class_id = self.rng.below(CLASSES.len() as u64) as usize;
        let h = self.rng.range(0.08, 0.35) as f32;
        let w = h * self.rng.range(0.4, 1.2) as f32;
        Detection {
            bbox: crate::types::BBox::new(
                self.rng.range(0.05, 0.95) as f32,
                self.rng.range(0.05, 0.95) as f32,
                w,
                h,
            ),
            class_id,
            score: self
                .rng
                .range(self.profile.fp_score.0 as f64, self.profile.fp_score.1 as f64)
                as f32,
        }
    }
}

impl Detector for QualityModelDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        let p = self.profile.clone();
        let mut out = Vec::with_capacity(frame.ground_truth.len() + 2);

        for gt in &frame.ground_truth {
            if self.rng.chance(p.miss_rate) {
                continue;
            }
            let b = gt.bbox;
            let dx = (p.pos_jitter * b.w as f64 * self.rng.normal()) as f32;
            let dy = (p.pos_jitter * b.h as f64 * self.rng.normal()) as f32;
            let sw = (1.0 + p.size_jitter * self.rng.normal()).max(0.5) as f32;
            let sh = (1.0 + p.size_jitter * self.rng.normal()).max(0.5) as f32;
            let class_id = if self.rng.chance(p.confusion_rate) {
                self.rng.below(CLASSES.len() as u64) as usize
            } else {
                gt.class_id
            };
            out.push(Detection {
                bbox: crate::types::BBox::new(b.cx + dx, b.cy + dy, b.w * sw, b.h * sh)
                    .clamped(),
                class_id,
                score: self.rng.range(p.tp_score.0 as f64, p.tp_score.1 as f64) as f32,
            });
        }

        // Poisson(fp_per_frame) false positives via thinning.
        let mut lambda = p.fp_per_frame;
        while lambda > 0.0 {
            if lambda >= 1.0 {
                out.push(self.sample_fp());
                lambda -= 1.0;
            } else {
                if self.rng.chance(lambda) {
                    out.push(self.sample_fp());
                }
                break;
            }
        }

        out
    }

    fn label(&self) -> String {
        format!("quality-model({})", self.profile.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::evaluate_map;
    use crate::types::GtBox;
    use crate::video::{generate, presets};

    fn run_zero_drop_map(model: DetectorModelId, video: &str, seed: u64) -> f64 {
        let spec = match video {
            "eth" => presets::eth_sunnyday(seed),
            _ => presets::adl_rundle6(seed),
        };
        let clip = generate(&spec, None);
        let mut det =
            QualityModelDetector::new(QualityProfile::calibrated(model, &spec.name), seed + 99);
        let dets: Vec<Vec<Detection>> = clip.frames.iter().map(|f| det.detect(f)).collect();
        let gt: Vec<&[GtBox]> = clip.frames.iter().map(|f| f.ground_truth.as_slice()).collect();
        evaluate_map(&dets, &gt, CLASSES.len(), 0.5).map
    }

    #[test]
    fn zero_drop_map_near_paper_eth_yolo() {
        let map = run_zero_drop_map(DetectorModelId::Yolov3, "eth", 1);
        assert!((map - 0.869).abs() < 0.08, "eth yolo map {map}");
    }

    #[test]
    fn zero_drop_map_near_paper_eth_ssd() {
        let map = run_zero_drop_map(DetectorModelId::Ssd300, "eth", 2);
        assert!((map - 0.745).abs() < 0.09, "eth ssd map {map}");
    }

    #[test]
    fn zero_drop_map_near_paper_adl_yolo() {
        let map = run_zero_drop_map(DetectorModelId::Yolov3, "adl", 3);
        assert!((map - 0.625).abs() < 0.09, "adl yolo map {map}");
    }

    #[test]
    fn zero_drop_map_near_paper_adl_ssd() {
        let map = run_zero_drop_map(DetectorModelId::Ssd300, "adl", 4);
        assert!((map - 0.544).abs() < 0.10, "adl ssd map {map}");
    }

    #[test]
    fn quality_ordering_yolo_beats_ssd() {
        let yolo = run_zero_drop_map(DetectorModelId::Yolov3, "eth", 7);
        let ssd = run_zero_drop_map(DetectorModelId::Ssd300, "eth", 7);
        assert!(yolo > ssd, "yolo {yolo} vs ssd {ssd}");
    }

    #[test]
    fn detector_is_deterministic_per_seed() {
        let spec = presets::eth_sunnyday(5);
        let clip = generate(&spec, None);
        let prof = QualityProfile::calibrated(DetectorModelId::Yolov3, "eth_sunnyday");
        let mut a = QualityModelDetector::new(prof.clone(), 11);
        let mut b = QualityModelDetector::new(prof, 11);
        for f in clip.frames.iter().take(20) {
            assert_eq!(a.detect(f), b.detect(f));
        }
    }

    #[test]
    fn tiny_variant_is_strictly_worse_but_valid() {
        for model in [DetectorModelId::Yolov3, DetectorModelId::Ssd300] {
            for video in ["eth_sunnyday", "adl_rundle6"] {
                let full = QualityProfile::calibrated(model, video);
                let tiny = QualityProfile::tiny(model, video);
                assert!(tiny.miss_rate > full.miss_rate);
                assert!(tiny.miss_rate < 1.0);
                assert!(tiny.fp_per_frame > full.fp_per_frame);
                assert!(tiny.confusion_rate >= full.confusion_rate);
                assert!(tiny.name.starts_with("tiny-"), "{}", tiny.name);
                assert!(QualityProfile::tiny_speedup(model) > 1.5);
            }
        }
    }

    #[test]
    fn tiny_map_lands_below_full_model() {
        let spec = presets::eth_sunnyday(9);
        let clip = generate(&spec, None);
        let mut full = QualityModelDetector::new(
            QualityProfile::calibrated(DetectorModelId::Yolov3, &spec.name),
            101,
        );
        let mut tiny = QualityModelDetector::new(
            QualityProfile::tiny(DetectorModelId::Yolov3, &spec.name),
            101,
        );
        let full_dets: Vec<Vec<Detection>> = clip.frames.iter().map(|f| full.detect(f)).collect();
        let tiny_dets: Vec<Vec<Detection>> = clip.frames.iter().map(|f| tiny.detect(f)).collect();
        let gt: Vec<&[GtBox]> = clip.frames.iter().map(|f| f.ground_truth.as_slice()).collect();
        let full_map = evaluate_map(&full_dets, &gt, CLASSES.len(), 0.5).map;
        let tiny_map = evaluate_map(&tiny_dets, &gt, CLASSES.len(), 0.5).map;
        assert!(
            tiny_map < full_map - 0.05,
            "tiny {tiny_map} vs full {full_map}"
        );
        // Still a usable detector, not a degenerate one.
        assert!(tiny_map > 0.35, "tiny map {tiny_map}");
    }

    #[test]
    fn empty_frame_yields_only_fps() {
        let prof = QualityProfile::calibrated(DetectorModelId::Yolov3, "eth_sunnyday");
        let mut det = QualityModelDetector::new(prof, 3);
        let frame = Frame {
            id: 0,
            ts: 0.0,
            width: 640,
            height: 480,
            pixels: vec![],
            ground_truth: vec![],
        };
        let mut total = 0;
        for _ in 0..200 {
            total += det.detect(&frame).len();
        }
        // fp_per_frame = 0.25 -> ~50 FPs over 200 frames.
        assert!(total > 20 && total < 100, "total {total}");
    }
}
