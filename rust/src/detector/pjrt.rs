//! PJRT-backed TinyDet detector: real inference on frame pixels.
//!
//! The AOT artifact performs backbone + head + in-graph decode (L1 Pallas
//! matmul inside); this wrapper converts pixels, runs the executable and
//! applies threshold + NMS — the only post-processing on the Rust side.

use anyhow::Result;

use crate::detector::Detector;
use crate::eval::nms::postprocess;
use crate::runtime::{ModelRuntime, ModelSpec};
use crate::types::{BBox, Detection, Frame};

/// `Send + Clone` factory: worker threads call [`PjrtDetectorFactory::build`]
/// to get their own thread-local detector (PJRT clients are not `Send`).
#[derive(Debug, Clone)]
pub struct PjrtDetectorFactory {
    pub spec: ModelSpec,
    pub score_thresh: f32,
    pub nms_iou: f32,
    /// Pad each `detect` to at least this long — emulates an NCS2-class
    /// accelerator's service time on hardware we don't have (DESIGN.md
    /// §3), so live serving exhibits the paper's λ ≫ μ regime while the
    /// inference itself stays real.
    pub min_service: Option<std::time::Duration>,
}

impl PjrtDetectorFactory {
    pub fn new(spec: ModelSpec) -> PjrtDetectorFactory {
        PjrtDetectorFactory {
            spec,
            score_thresh: 0.5,
            nms_iou: 0.45,
            min_service: None,
        }
    }

    /// Emulate a slow edge accelerator (e.g. 400 ms ≈ one NCS2 at 2.5 FPS).
    pub fn with_min_service(mut self, d: std::time::Duration) -> Self {
        self.min_service = Some(d);
        self
    }

    pub fn build(&self) -> Result<PjrtDetector> {
        Ok(PjrtDetector {
            runtime: self.spec.build()?,
            score_thresh: self.score_thresh,
            nms_iou: self.nms_iou,
            min_service: self.min_service,
        })
    }
}

/// One PJRT-served detector replica.
pub struct PjrtDetector {
    runtime: ModelRuntime,
    score_thresh: f32,
    nms_iou: f32,
    min_service: Option<std::time::Duration>,
}

impl PjrtDetector {
    pub fn runtime(&self) -> &ModelRuntime {
        &self.runtime
    }

    /// Decode flat model output rows into raw detections (before NMS).
    /// Score = objectness × best-class probability, class = argmax.
    pub fn decode_rows(out: &[f32], cols: usize) -> Vec<Detection> {
        let mut dets = Vec::new();
        for row in out.chunks(cols) {
            let obj = row[0];
            let (mut best_c, mut best_p) = (0usize, f32::MIN);
            for (c, &p) in row[5..].iter().enumerate() {
                if p > best_p {
                    best_p = p;
                    best_c = c;
                }
            }
            let score = obj * best_p;
            if score > 1e-3 {
                dets.push(Detection {
                    bbox: BBox::new(row[1], row[2], row[3], row[4]),
                    class_id: best_c,
                    score,
                });
            }
        }
        dets
    }
}

impl Detector for PjrtDetector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
        let started = std::time::Instant::now();
        debug_assert_eq!(
            (frame.width, frame.height),
            (
                self.runtime.meta().input_size,
                self.runtime.meta().input_size
            ),
            "frame must be rastered at the model input size"
        );
        let input = match self.runtime.pixels_to_input(&frame.pixels) {
            Ok(i) => i,
            Err(_) => return Vec::new(),
        };
        let out = match self.runtime.infer(&input) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("[pjrt] inference failed on frame {}: {e}", frame.id);
                return Vec::new();
            }
        };
        let raw = Self::decode_rows(&out, self.runtime.meta().out_cols as usize);
        let dets = postprocess(raw, self.score_thresh, self.nms_iou);
        if let Some(min) = self.min_service {
            let elapsed = started.elapsed();
            if elapsed < min {
                std::thread::sleep(min - elapsed);
            }
        }
        dets
    }

    fn label(&self) -> String {
        format!("pjrt({})", self.runtime.meta().name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::load_manifest;
    use crate::video::{generate, presets};
    use std::path::PathBuf;

    fn factory(name: &str) -> Option<PjrtDetectorFactory> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = load_manifest(&dir).unwrap();
        Some(PjrtDetectorFactory::new(ModelSpec::new(
            manifest.get(name)?.clone(),
        )))
    }

    #[test]
    fn decode_rows_picks_argmax_class() {
        // One row: obj=0.8, box, classes [0.1, 0.7, 0.2]
        let row = vec![0.8, 0.5, 0.5, 0.2, 0.3, 0.1, 0.7, 0.2];
        let dets = PjrtDetector::decode_rows(&row, 8);
        assert_eq!(dets.len(), 1);
        assert_eq!(dets[0].class_id, 1);
        assert!((dets[0].score - 0.8 * 0.7).abs() < 1e-6);
    }

    #[test]
    fn decode_rows_skips_near_zero() {
        let row = vec![0.0, 0.5, 0.5, 0.2, 0.3, 1.0, 0.0, 0.0];
        assert!(PjrtDetector::decode_rows(&row, 8).is_empty());
    }

    #[test]
    fn detects_objects_on_synthetic_clip() {
        let Some(f) = factory("essd") else { return };
        let mut det = f.build().unwrap();
        let size = det.runtime().meta().input_size;
        let spec = presets::tiny_clip(size, 6, 10.0, 42);
        let clip = generate(&spec, Some(size));
        let mut detected_frames = 0;
        let mut matched = 0usize;
        let mut total_gt = 0usize;
        for frame in &clip.frames {
            let dets = det.detect(frame);
            if !dets.is_empty() {
                detected_frames += 1;
            }
            total_gt += frame.ground_truth.len();
            for gt in &frame.ground_truth {
                if dets
                    .iter()
                    .any(|d| d.class_id == gt.class_id && d.bbox.iou(&gt.bbox) >= 0.4)
                {
                    matched += 1;
                }
            }
        }
        // The build-time-trained TinyDet must find objects in rust-rastered
        // frames: demand detections on most frames and >=40% loose recall.
        assert!(detected_frames >= clip.len() - 1, "{detected_frames}/{}", clip.len());
        assert!(
            matched as f64 >= 0.4 * total_gt as f64,
            "matched {matched}/{total_gt}"
        );
    }
}
