//! Detector backends.
//!
//! Two implementations of one trait feed the same coordinator:
//!
//! * [`quality::QualityModelDetector`] — calibrated statistical model of a
//!   well-trained detector (jitter / misses / false positives / class
//!   confusion), used for paper-scale experiments where we do not own the
//!   authors' SSD300/YOLOv3 weights (DESIGN.md §3). It needs only frame
//!   *geometry* (ground truth), so metadata-only frames suffice and whole
//!   tables run in milliseconds of virtual time.
//! * [`pjrt::PjrtDetector`] — real TinyDet inference through the XLA PJRT
//!   runtime (L1 Pallas kernels inside), used by the live serving path.
//!
//! Either way, mAP under frame dropping is *computed* downstream by
//! [`crate::eval`], never assumed.

pub mod quality;
pub mod pjrt;

use crate::types::{Detection, Frame};

/// A detector replica: consumes one frame, produces detections.
/// `&mut self` because backends keep per-replica RNG / buffers.
///
/// Deliberately NOT `Send`: the PJRT backend wraps an `Rc`-based client.
/// Serving workers construct their detector *inside* the worker thread
/// from a `Send + Clone` factory instead of moving detectors across
/// threads (see [`crate::server`]).
pub trait Detector {
    fn detect(&mut self, frame: &Frame) -> Vec<Detection>;

    /// Human-readable backend label (metrics/logs).
    fn label(&self) -> String;
}
