//! `eva` — the EVA-RS command-line launcher.
//!
//! Subcommands:
//!   serve        run the real-time PJRT serving pipeline on a synthetic clip
//!   offline      zero-drop offline detection (Figure 1a reference)
//!   fleet        multi-stream serving over a shared device pool (virtual time)
//!   autoscale    closed-loop device scaling + model-ladder sweeps (step|diurnal|failure)
//!   shard        stream sharding across fleet instances (split|skew|failure|autoscale|churn|run|transport|scale)
//!   shard-server serve one shard on a real socket (--listen host:port|unix:<path>, --token auth)
//!   forecast     forecast-fused control: diurnal pre-ramp sweep + deployment-space search
//!   gate         motion-gated detection vs always-detect (lobby|highway|sports|all)
//!   trace        end-to-end telemetry: p99 stage budgets, origin attribution, overhead
//!   table        regenerate a paper table/figure (1,2,3,4,5,6,7,8,9,10,fig5,fig23)
//!   nselect      recommend the parallel-detection parameter n (§III-B)
//!   visualize    dump Figure 2/3-style PPM frames with box overlays
//!   inspect      print video/model/device registries
//!
//! The flag table, the exit-2 usage contract and the shared value
//! parsers live in [`args`]; Python never runs here: `make artifacts`
//! must have produced `artifacts/*.hlo.txt` + `manifest.json` for the
//! PJRT paths.

mod args;

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::args::usage_error;
use eva::coordinator::nselect;
use eva::detector::pjrt::PjrtDetectorFactory;
use eva::detector::Detector;
use eva::device::DeviceInstance;
use eva::experiments;
use eva::fleet::{run_fleet_with, AdmissionPolicy, Scenario, StreamSpec};
use eva::runtime::{load_manifest, ModelSpec};
use eva::server::{serve, ServeConfig};
use eva::telemetry::RunTelemetry;
use eva::util::cli::Args;
use eva::video::{generate, presets, raster};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = args::parse_argv(&raw);
    args::check_applicability(&cmd, &args);
    if let Err(e) = run(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "serve" => cmd_serve(args, false),
        "offline" => cmd_serve(args, true),
        "fleet" => cmd_fleet(args),
        "autoscale" => cmd_autoscale(args),
        "shard" => cmd_shard(args),
        "shard-server" => cmd_shard_server(args),
        "forecast" => cmd_forecast(args),
        "gate" => cmd_gate(args),
        "trace" => cmd_trace(args),
        "table" => cmd_table(args),
        "nselect" => cmd_nselect(args),
        "visualize" => cmd_visualize(args),
        "inspect" => cmd_inspect(args),
        other => bail!("unknown subcommand {other:?} (try --help)"),
    }
}

fn pjrt_factory(args: &Args) -> Result<PjrtDetectorFactory> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let manifest = load_manifest(&dir)?;
    let model = args.str_or("model", "essd");
    let meta = manifest
        .get(&model)
        .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?
        .clone();
    Ok(PjrtDetectorFactory::new(ModelSpec::new(meta)))
}

fn cmd_serve(args: &Args, offline: bool) -> Result<()> {
    let factory = pjrt_factory(args).map_err(|e| anyhow!("{e} (run `make artifacts`)"))?;
    let size = factory.spec.meta.input_size;
    let frames = args.u64_or("frames", 60).map_err(|e| anyhow!(e))? as u32;
    let fps = args.f64_or("fps", 10.0).map_err(|e| anyhow!(e))?;
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let workers = if offline {
        1
    } else {
        args.usize_or("workers", 2).map_err(|e| anyhow!(e))?
    };

    println!(
        "[eva] generating clip: {frames} frames @ {fps} FPS, {size}x{size}, seed {seed}"
    );
    let clip = generate(&presets::tiny_clip(size, frames, fps, seed), Some(size));

    let cfg = ServeConfig {
        workers,
        window: None,
        paced: !offline && !args.flag("saturated"),
    };
    println!(
        "[eva] mode: {} | workers: {workers} | model: {}",
        if cfg.paced { "paced (online)" } else { "saturated" },
        factory.spec.meta.name
    );
    let report = serve(&clip, &cfg, |w| {
        let det = factory.build()?;
        println!("[worker {w}] detector ready: {}", det.label());
        Ok(Box::new(det) as Box<dyn Detector>)
    })?;

    let metrics = report.metrics;
    println!("[eva] {}", metrics.summary());
    let dets: Vec<Vec<eva::types::Detection>> =
        report.records.iter().map(|r| r.detections.clone()).collect();
    let map = experiments::common::map_against(&clip, &dets);
    println!("[eva] mAP over all frames: {:.1}%", map * 100.0);
    for (w, (frames, mean)) in report.worker_stats.iter().enumerate() {
        println!(
            "[eva] worker {w}: {frames} frames, mean inference {:.1} ms",
            mean * 1e3
        );
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let streams = args.usize_or("streams", 8).map_err(|e| anyhow!(e))?;
    let fps = args.f64_or("stream-fps", 5.0).map_err(|e| anyhow!(e))?;
    let frames = args.u64_or("frames", 300).map_err(|e| anyhow!(e))?;
    let window = args.usize_or("window", 4).map_err(|e| anyhow!(e))?;
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let rates = args::parse_rates(args)?;
    let admission = if args.flag("no-admission") {
        AdmissionPolicy::admit_all()
    } else {
        AdmissionPolicy::default()
    };

    let devices = args::device_pool(&rates);
    let specs: Vec<StreamSpec> = (0..streams)
        .map(|s| StreamSpec::new(&format!("stream{s}"), fps, frames).with_window(window))
        .collect();

    let offered = fps * streams as f64;
    let pool: f64 = rates.iter().sum();
    // The banner stays off the --json path: stdout must be exactly one
    // parseable document there (CI uploads it as BENCH_fleet.json).
    if !args.flag("json") {
        println!(
            "[fleet] {streams} streams × {fps} FPS (offered {offered:.1}) vs {} devices (Σμ {pool:.1}), seed {seed}",
            rates.len()
        );
    }
    let scenario = Scenario::new(devices, specs)
        .with_admission(admission)
        .with_seed(seed);
    // `--metrics-out`/`--trace-out` flip span tracing on for this run;
    // without them the fleet runs untraced (identical virtual-time
    // outputs either way — tracing is a pure observer).
    let traced = args.get("metrics-out").is_some() || args.get("trace-out").is_some();
    let scenario = if traced { scenario.with_telemetry() } else { scenario };
    let out = run_fleet_with(&scenario, None);
    if let Some(tel) = out.telemetry.as_ref() {
        write_run_files(args, tel)?;
    }
    let report = out.report;
    if args.flag("json") {
        println!("{}", report.to_json().to_string());
        return Ok(());
    }
    print!("{}", report.stream_table().render());
    print!("{}", report.device_table().render());
    println!("[fleet] {}", report.summary());
    Ok(())
}

/// Write the optional `--metrics-out` (Prometheus text exposition) and
/// `--trace-out` (span-trace JSONL) artifacts for a traced run.
fn write_run_files(args: &Args, tel: &RunTelemetry) -> Result<()> {
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, tel.registry.text_exposition())
            .map_err(|e| anyhow!("--metrics-out {path:?}: {e}"))?;
    }
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, tel.traces_jsonl())
            .map_err(|e| anyhow!("--trace-out {path:?}: {e}"))?;
    }
    Ok(())
}

fn cmd_autoscale(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let scenario = args.str_or("scenario", "step");
    if args.flag("json") {
        let json = experiments::autoscale::autoscale_json(seed, &scenario)
            .ok_or_else(|| anyhow!("unknown autoscale scenario {scenario:?} (step|diurnal|failure|all)"))?;
        println!("{}", json.to_string());
        return Ok(());
    }
    match scenario.as_str() {
        "step" => {
            let (table, _) = experiments::autoscale::step_load(seed);
            print!("{}", table.render());
        }
        "diurnal" => {
            let (table, _, out) = experiments::autoscale::diurnal(seed);
            print!("{}", table.render());
            println!(
                "[autoscale] {} controller actions ({} device, {} rung)",
                out.control_log
                    .iter()
                    .filter(|r| r.origin == eva::control::ControlOrigin::Controller)
                    .count(),
                out.controller_device_actions(),
                out.rung_actions,
            );
        }
        "failure" => {
            let (table, _) = experiments::autoscale::device_failure(seed);
            print!("{}", table.render());
        }
        "all" => {
            let (t1, _) = experiments::autoscale::step_load(seed);
            let (t2, _, _) = experiments::autoscale::diurnal(seed);
            let (t3, _) = experiments::autoscale::device_failure(seed);
            print!("{}", t1.render());
            print!("{}", t2.render());
            print!("{}", t3.render());
        }
        other => bail!("unknown autoscale scenario {other:?} (step|diurnal|failure|all)"),
    }
    Ok(())
}

fn cmd_forecast(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    // Stdout on the --json path must be exactly one parseable document
    // (CI uploads it as BENCH_forecast.json).
    if args.flag("json") {
        println!("{}", experiments::forecast::forecast_json(seed).to_string());
        return Ok(());
    }
    let (t1, _) = experiments::forecast::diurnal_sweep(seed);
    let (t2, _) = experiments::forecast::deployment_search(seed);
    print!("{}", t1.render());
    print!("{}", t2.render());
    Ok(())
}

fn cmd_shard(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    // `--scenario` is shared with `eva autoscale`, whose default is
    // "step" — not a shard sweep, so it reads as "run everything".
    let raw_scenario = args.str_or("scenario", "all");
    let scenario_defaulted = raw_scenario == "step";
    let mut scenario = if scenario_defaulted {
        "all".to_string()
    } else {
        raw_scenario
    };
    // `eva shard --autoscale` (no explicit scenario) selects the
    // autoscale overload sweep; with `--scenario run` the flag embeds an
    // AutoscaleController in every shard instead. Anywhere else the flag
    // would be silently ignored or reinterpreted, and the CLI contract
    // is that nothing is — an *explicit* `--scenario all --autoscale`
    // bails rather than quietly dropping the split/skew/failure sweeps.
    let autoscale = args.flag("autoscale");
    if autoscale && scenario == "all" {
        if scenario_defaulted {
            scenario = "autoscale".to_string();
        } else {
            bail!("--autoscale with --scenario all is ambiguous: use --scenario autoscale (the overload sweep) or --scenario run (embed controllers)");
        }
    }
    if autoscale && !matches!(scenario.as_str(), "run" | "autoscale") {
        bail!("--autoscale applies to --scenario run (local scaling) or the autoscale sweep");
    }
    // `--transport` only steers `--scenario run` (the sweeps fix their
    // own transports); anything else would be silently ignored, and this
    // PR's CLI contract is that nothing is.
    if scenario != "run" && args.str_or("transport", "inproc") != "inproc" {
        bail!("--transport applies only to --scenario run (the transport sweep runs all of them)");
    }
    // `--token` authenticates the dial side of a socket run; an
    // in-process run has no session to authenticate, so a token there
    // would be a silent no-op.
    let token = args.get("token").map(str::to_string);
    if token.is_some() && (scenario != "run" || args.str_or("transport", "inproc") == "inproc") {
        bail!("--token applies to --scenario run with --transport tcp|uds (sessions to authenticate)");
    }
    // `--forecast` arms the per-stream arrival forecaster on the one-off
    // run: the predicted Σλ rides every gossip digest and fuses into the
    // migration planner, the autoscaler floor and the admission hold.
    // The dedicated sweeps (`eva forecast`) arm it themselves.
    let forecast = args.flag("forecast");
    if forecast && scenario != "run" {
        bail!("--forecast applies only to --scenario run (`eva forecast` runs the fused sweeps)");
    }
    // `--metrics-out` only applies to `--scenario run`: the sweeps run
    // many co-simulations, each with its own registry, so there is no
    // single snapshot to write.
    let telemetry = args.get("metrics-out").is_some();
    if telemetry && scenario != "run" {
        bail!("--metrics-out applies only to --scenario run (sweeps aggregate many co-simulations)");
    }
    // `--codec` picks the control-plane wire encoding for `--scenario
    // run`; every other sweep fixes its own codecs (the scale sweep
    // measures both), so a stray flag is a usage error, not a no-op.
    let codec = match args.get("codec") {
        None => eva::transport::Codec::Json,
        Some(name) => {
            if scenario != "run" {
                usage_error("--codec applies only to --scenario run (the scale sweep measures both codecs itself)");
            }
            eva::transport::Codec::parse(name)
                .unwrap_or_else(|| usage_error(&format!("unknown codec {name:?} (json|binary)")))
        }
    };
    // `--groups` switches the rebalancer to two-level planning; like
    // `--codec` it only has meaning on the one-off run.
    let groups = match args.get("groups") {
        None => None,
        Some(_) => {
            if scenario != "run" {
                usage_error("--groups applies only to --scenario run (the scale sweep derives its own group size)");
            }
            Some(args.usize_or("groups", 1).map_err(|e| anyhow!(e))?.max(1))
        }
    };

    if scenario == "scale" {
        // Coordinator-cost sweep: flat vs grouped planning and JSON vs
        // binary digests over a synthetic 100k-stream fleet. Stdout on
        // the --json path must be exactly one parseable document (CI
        // uploads it as BENCH_coordinator_scale.json).
        if args.flag("json") {
            println!("{}", experiments::scale::scale_json(seed).to_string());
            return Ok(());
        }
        let (table, _) = experiments::scale::coordinator_scale(seed);
        print!("{}", table.render());
        return Ok(());
    }

    if scenario == "run" {
        // One-off run from CLI parameters: `--shards` pools of `--rates`
        // devices each, `--streams` × `--stream-fps` streams.
        let shards = args.usize_or("shards", 2).map_err(|e| anyhow!(e))?.max(1);
        let streams = args.usize_or("streams", 8).map_err(|e| anyhow!(e))?;
        let fps = args.f64_or("stream-fps", 5.0).map_err(|e| anyhow!(e))?;
        let frames = args.u64_or("frames", 300).map_err(|e| anyhow!(e))?;
        let window = args.usize_or("window", 4).map_err(|e| anyhow!(e))?;
        let gossip = args.f64_or("gossip", 5.0).map_err(|e| anyhow!(e))?;
        let rates = args::parse_rates(args)?;
        let policy_name = args.str_or("policy", "least-loaded");
        let policy = eva::shard::PlacementPolicy::parse(&policy_name)
            .ok_or_else(|| anyhow!("unknown placement policy {policy_name:?} (least-loaded|hash|round-robin)"))?;
        let admission = if args.flag("no-admission") {
            AdmissionPolicy::admit_all()
        } else {
            AdmissionPolicy::default()
        };
        let pools: Vec<Vec<DeviceInstance>> =
            (0..shards).map(|_| args::device_pool(&rates)).collect();
        let specs: Vec<StreamSpec> = (0..streams)
            .map(|s| StreamSpec::new(&format!("stream{s}"), fps, frames).with_window(window))
            .collect();
        let transport = args.str_or("transport", "inproc");
        // `--autoscale`: every shard runs local capacity control with
        // template replicas shaped like the CLI pool (mean rate, up to
        // 4× the per-shard device count).
        let autoscale_cfg = autoscale.then(|| eva::autoscale::AutoscaleConfig {
            device_rate: rates.iter().sum::<f64>() / rates.len() as f64,
            max_devices: (rates.len() * 4).max(8),
            ..eva::autoscale::AutoscaleConfig::default()
        });
        let forecast_cfg = forecast.then(experiments::forecast::forecast_tuning);
        let offered = fps * streams as f64;
        let pool: f64 = rates.iter().sum::<f64>() * shards as f64;
        // The banner stays off the --json path: stdout must be exactly
        // one parseable document there (CI uploads it as BENCH_shard.json).
        if !args.flag("json") {
            println!(
                "[shard] {streams} streams × {fps} FPS (offered {offered:.1}) over {shards} shards (Σμ {pool:.1}), policy {}, gossip {gossip}s, transport {transport}, codec {}, autoscale {}, forecast {}, seed {seed}",
                policy.label(),
                codec.label(),
                if autoscale { "on" } else { "off" },
                if forecast { "on" } else { "off" },
            );
        }
        let report = match transport.as_str() {
            "inproc" => experiments::shard::custom_run(
                pools,
                specs,
                policy,
                admission,
                gossip,
                seed,
                autoscale_cfg,
                telemetry,
                codec,
                groups,
                forecast_cfg,
            ),
            "tcp" | "uds" => {
                let remote = if transport == "tcp" {
                    eva::shard::RemoteTransport::Tcp
                } else {
                    eva::shard::RemoteTransport::Uds
                };
                experiments::shard::custom_run_remote(
                    pools,
                    specs,
                    policy,
                    admission,
                    gossip,
                    seed,
                    autoscale_cfg,
                    telemetry,
                    codec,
                    groups,
                    token,
                    forecast_cfg,
                    remote,
                )?
            }
            other => bail!("unknown transport {other:?} (inproc|tcp|uds)"),
        };
        if let Some(path) = args.get("metrics-out") {
            std::fs::write(path, report.telemetry.text_exposition())
                .map_err(|e| anyhow!("--metrics-out {path:?}: {e}"))?;
        }
        if args.flag("json") {
            println!("{}", report.to_json().to_string());
            return Ok(());
        }
        print!("{}", report.stream_table().render());
        print!("{}", report.shard_table().render());
        println!(
            "[shard] delivered σ = {:.2} FPS, drop rate {:.1}%, {} migrations, {} scale actions over {} epochs",
            report.delivered_fps(),
            report.drop_rate() * 100.0,
            report.migrations,
            report.scale_actions(),
            report.epochs_run,
        );
        return Ok(());
    }

    if scenario == "autoscale" {
        // Local capacity control inside each shard: migrate-only vs
        // autoscale at 2× load, plus the exact-parity pin across
        // inproc/tcp/uds transports.
        if args.flag("json") {
            println!("{}", experiments::shard::autoscale_json(seed).to_string());
            return Ok(());
        }
        let (t1, _, _) = experiments::shard::autoscale_overload(seed);
        let (t2, _) = experiments::transport::autoscale_parity(seed);
        print!("{}", t1.render());
        print!("{}", t2.render());
        return Ok(());
    }

    if scenario == "churn" {
        // Rolling-restart chaos at 2× load: every shard dies and
        // rejoins once, in-process and over loopback TCP, against the
        // pinned delivered-FPS floor and the one-interval orphan
        // re-placement deadline. Stdout on the --json path must be
        // exactly one parseable document (CI uploads it as
        // BENCH_churn.json).
        if args.flag("json") {
            println!("{}", experiments::churn::churn_json(seed).to_string());
            return Ok(());
        }
        let (table, outcomes) = experiments::churn::churn_chaos(seed);
        print!("{}", table.render());
        for o in &outcomes {
            println!(
                "[churn] {}: {:.3}× baseline (floor {}), worst orphan gap {:.1}s",
                o.mode,
                o.fps_ratio,
                experiments::churn::CHURN_FPS_FLOOR,
                o.worst_gap,
            );
        }
        return Ok(());
    }

    if scenario == "transport" {
        // The cross-host sweeps: loopback-socket co-simulation vs the
        // in-process twin, connection-loss recovery, and the
        // sharded-autoscale parity pin (same coverage as the --json
        // bundle, which runs "all").
        if args.flag("json") {
            let json = experiments::transport::transport_json(seed, "all")
                .expect("transport sweep bundle");
            println!("{}", json.to_string());
            return Ok(());
        }
        let (t1, _) = experiments::transport::loopback_parity(seed);
        let (t2, _) = experiments::transport::connection_loss(seed);
        let (t3, _) = experiments::transport::autoscale_parity(seed);
        print!("{}", t1.render());
        print!("{}", t2.render());
        print!("{}", t3.render());
        return Ok(());
    }

    if args.flag("json") {
        let json = experiments::shard::shard_json(seed, &scenario).ok_or_else(|| {
            anyhow!("unknown shard scenario {scenario:?} (split|skew|failure|autoscale|churn|all|run|transport|scale)")
        })?;
        println!("{}", json.to_string());
        return Ok(());
    }
    match scenario.as_str() {
        "split" => {
            let (table, _) = experiments::shard::balanced_split(seed);
            print!("{}", table.render());
        }
        "skew" => {
            let (table, _) = experiments::shard::skewed_load(seed);
            print!("{}", table.render());
        }
        "failure" => {
            let (table, _) = experiments::shard::shard_failure(seed);
            print!("{}", table.render());
        }
        "all" => {
            let (t1, _) = experiments::shard::balanced_split(seed);
            let (t2, _) = experiments::shard::skewed_load(seed);
            let (t3, _) = experiments::shard::shard_failure(seed);
            print!("{}", t1.render());
            print!("{}", t2.render());
            print!("{}", t3.render());
        }
        other => bail!("unknown shard scenario {other:?} (split|skew|failure|autoscale|churn|all|run|transport|scale)"),
    }
    Ok(())
}

/// `eva shard-server`: serve one shard on a real socket — the
/// multi-machine deployment surface. `--listen host:port` binds TCP
/// (non-loopback binds are the point; `0.0.0.0:port` serves the LAN),
/// `unix:<path>` a Unix socket. `--token` arms session auth: a
/// handshake without the secret gets a typed reject, never a hang.
/// `--sessions` is how many coordinator sessions to serve before a
/// clean exit — a coordinator that redials after a crash is a new
/// session. `--probe` dials `--listen` instead of serving: handshake,
/// goodbye, exit 0 — the smoke-test surface.
fn cmd_shard_server(args: &Args) -> Result<()> {
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow!("--listen required (host:port, or unix:<path>)"))?;
    let endpoint = args::parse_endpoint(listen);
    let token = args.get("token");
    if args.flag("probe") {
        return probe_shard_server(&endpoint, token);
    }
    let rates = args::parse_rates(args)?;
    let sessions = args.usize_or("sessions", 1).map_err(|e| anyhow!(e))?.max(1);
    let mut shard = eva::shard::RemoteShard::new(0, args::device_pool(&rates));
    if let Some(t) = token {
        shard = shard.with_token(t);
    }
    let listener = eva::transport::Listener::bind(&endpoint)
        .map_err(|e| anyhow!("--listen {listen:?}: {e}"))?;
    let local = listener
        .local_endpoint()
        .map_err(|e| anyhow!("--listen {listen:?}: {e}"))?;
    println!(
        "[shard-server] shard 0 ({} devices, Σμ {:.1}) listening on {} — {} session(s), auth {}",
        rates.len(),
        rates.iter().sum::<f64>(),
        local.label(),
        sessions,
        if token.is_some() { "token" } else { "open" },
    );
    eva::shard::serve_shard_sessions(listener, shard, sessions)
        .map_err(|e| anyhow!("shard-server: {e}"))?;
    println!("[shard-server] served {sessions} session(s), exiting");
    Ok(())
}

/// Dial a running `shard-server`, handshake (with `--token` if given),
/// print the shard's advertised capacity and exit: 0 on a Welcome, 1 on
/// a typed reject or any transport error.
fn probe_shard_server(endpoint: &eva::transport::Endpoint, token: Option<&str>) -> Result<()> {
    use eva::transport::{connect_with_backoff, TransportMsg, TRANSPORT_VERSION};
    let mut conn = connect_with_backoff(endpoint, 20, std::time::Duration::from_millis(25))
        .map_err(|e| anyhow!("probe: cannot reach {}: {e}", endpoint.label()))?;
    let caps = eva::control::SessionCaps {
        token: token.map(str::to_string),
        ..eva::control::SessionCaps::default()
    };
    conn.send(&TransportMsg::Hello {
        shard: 0,
        protocol: TRANSPORT_VERSION,
        admission: AdmissionPolicy::default(),
        roster: Vec::new(),
        caps,
    })
    .map_err(|e| anyhow!("probe: handshake send: {e}"))?;
    match conn.recv().map_err(|e| anyhow!("probe: handshake reply: {e}"))? {
        TransportMsg::Welcome { shard, capacity } => {
            println!("[shard-server] probe ok: shard {shard}, capacity {capacity:.2} FPS");
            let _ = conn.send(&TransportMsg::Bye);
            Ok(())
        }
        TransportMsg::Reject { code, detail } => bail!("probe rejected ({code}): {detail}"),
        other => bail!("probe: unexpected reply {}", other.label()),
    }
}

fn cmd_gate(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    // `--scenario` is shared with `eva autoscale`, whose default is
    // "step" — not a gate preset, so it reads as "run everything".
    let raw_scenario = args.str_or("scenario", "all");
    let scenario = if raw_scenario == "step" {
        "all".to_string()
    } else {
        raw_scenario
    };
    // `--metrics-out`/`--trace-out` re-run one preset's gated cell with
    // span tracing on; "all" has no single run to dump.
    if args.get("metrics-out").is_some() || args.get("trace-out").is_some() {
        if scenario == "all" {
            bail!("--metrics-out/--trace-out need a single gate preset (lobby|highway|sports)");
        }
        let out = experiments::gate::traced_gated_run(&scenario, seed)
            .ok_or_else(|| anyhow!("unknown gate preset {scenario:?} (lobby|highway|sports|all)"))?;
        let tel = out.telemetry.as_ref().expect("traced gated run carries telemetry");
        write_run_files(args, tel)?;
    }
    if args.flag("json") {
        // Stdout must be exactly one parseable document here (CI
        // uploads it as BENCH_gate.json).
        let json = experiments::gate::gate_json(seed, &scenario)
            .ok_or_else(|| anyhow!("unknown gate preset {scenario:?} (lobby|highway|sports|all)"))?;
        println!("{}", json.to_string());
        return Ok(());
    }
    if !matches!(scenario.as_str(), "lobby" | "highway" | "sports" | "all") {
        bail!("unknown gate preset {scenario:?} (lobby|highway|sports|all)");
    }
    let (table, outcomes) = experiments::gate::content_sweep(seed);
    let selected: Vec<_> = outcomes
        .iter()
        .filter(|o| scenario == "all" || o.preset == scenario)
        .collect();
    if scenario == "all" {
        print!("{}", table.render());
    } else {
        for o in &selected {
            println!(
                "[gate] {} {}: σ {:.1} FPS, device eff {:.1} FPS, mAP {:.1}%, detect {:.1}%",
                o.preset,
                o.mode,
                o.delivered_fps,
                o.effective_device_fps,
                o.delivered_map * 100.0,
                o.detect_fraction * 100.0,
            );
        }
    }
    let gated: Vec<_> = selected.iter().filter(|o| o.mode == "gated").collect();
    let skips: u64 = gated.iter().map(|o| o.skips).sum();
    let refreshes: u64 = gated.iter().map(|o| o.refreshes).sum();
    let downrungs: u64 = gated.iter().map(|o| o.downrungs).sum();
    println!("[gate] {skips} skips, {refreshes} forced refreshes, {downrungs} down-rungs across gated runs");
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    // `--metrics-out`/`--trace-out` dump the peak-load sweep cell (the
    // 2.0× overload run) rather than the whole sweep: one run, one
    // registry, one trace stream.
    if args.get("metrics-out").is_some() || args.get("trace-out").is_some() {
        let out = experiments::telemetry::traced_run(seed);
        let tel = out.telemetry.as_ref().expect("traced run carries telemetry");
        write_run_files(args, tel)?;
    }
    if args.flag("json") {
        // Stdout must be exactly one parseable document here (CI
        // uploads it as BENCH_telemetry.json).
        println!("{}", experiments::telemetry::telemetry_json(seed).to_string());
        return Ok(());
    }
    let (t1, _) = experiments::telemetry::overload_sweep(seed);
    let (t2, _) = experiments::telemetry::attribution(seed);
    let (t3, overhead) = experiments::telemetry::tracing_overhead(seed);
    print!("{}", t1.render());
    print!("{}", t2.render());
    print!("{}", t3.render());
    println!(
        "[trace] virtual-time outputs {} under tracing; wall overhead {:.2}% over {} frames",
        if overhead.virtual_identical { "identical" } else { "DIVERGED" },
        overhead.wall_overhead * 100.0,
        overhead.frames,
    );
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args
        .get("id")
        .ok_or_else(|| anyhow!("--id required (1..10|fig5|fig23|ablation|links|energy-frame|fleet|fleet-saturation)"))?;
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let csv = args.flag("csv");
    let table = match id {
        "1" => experiments::configs::table1(),
        "2" => experiments::configs::table2(),
        "3" => experiments::configs::table3(),
        "4" => experiments::parallel::table4(seed).0,
        "5" => experiments::parallel::table5(seed).0,
        "6" => experiments::energy::table6().0,
        "7" => experiments::sched::table7(seed).0,
        "8" => experiments::configs::table8(),
        "9" => experiments::links::table9(seed).0,
        "10" => experiments::lang::table10(seed).0,
        "fig5" => experiments::parallel::fig5(seed).0,
        "fig23" => experiments::dropping::fig2_3(seed).0,
        "ablation" => experiments::sched::scheduler_ablation(seed).0,
        "links" => experiments::links::link_projection(seed).0,
        "energy-frame" => experiments::energy::joules_per_frame_comparison().0,
        "fleet" => experiments::fleet::scaling(seed).0,
        "fleet-saturation" => experiments::fleet::saturation_sweep(seed).0,
        other => bail!("unknown table id {other:?}"),
    };
    if csv {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.render());
    }
    Ok(())
}

fn cmd_nselect(args: &Args) -> Result<()> {
    let lambda = args.f64_or("lambda", 14.0).map_err(|e| anyhow!(e))?;
    let mu = args.f64_or("mu", 2.5).map_err(|e| anyhow!(e))?;
    let range = nselect::recommended_range(lambda, mu);
    println!("λ = {lambda} FPS, μ = {mu} FPS");
    println!("conservative n = {}", nselect::conservative_n(lambda, mu));
    println!(
        "recommended band n ∈ [{}, {}] (σ_P = {:.1}..{:.1} FPS)",
        range.lo,
        range.hi,
        nselect::ideal_sigma_p(range.lo, mu),
        nselect::ideal_sigma_p(range.hi, mu),
    );
    Ok(())
}

fn cmd_visualize(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.str_or("out", "/tmp/eva_frames"));
    std::fs::create_dir_all(&out)?;
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let size = 256u32;
    // Small ETH-like clip, rastered, frames 60..70 dumped with overlays.
    let mut spec = presets::eth_sunnyday(seed);
    spec.num_frames = 80;
    let clip = generate(&spec, Some(size));
    for fid in 60..70usize {
        let frame = &clip.frames[fid];
        let mut rgb = frame.pixels.clone();
        for gt in &frame.ground_truth {
            raster::draw_box_outline(&mut rgb, size as usize, &gt.bbox, [255, 255, 0]);
        }
        let path = out.join(format!("frame_{fid:04}.ppm"));
        raster::write_ppm(&path, size, size, &rgb)?;
    }
    println!("wrote frames 60..70 to {}", out.display());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    print!("{}", experiments::configs::table1().render());
    print!("{}", experiments::configs::table2().render());
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if let Some(t) = experiments::configs::table2_tinydet(&dir) {
        print!("{}", t.render());
    }
    print!("{}", experiments::configs::table3().render());
    print!("{}", experiments::configs::table8().render());
    Ok(())
}
