//! # EVA-RS — parallel object detection for edge video analytics
//!
//! Rust + JAX + Pallas reproduction of *"Parallel Detection for Efficient
//! Video Analytics at the Edge"* (Wu, Liu, Kompella; 2021).
//!
//! The crate is organised in layers (see `DESIGN.md`):
//!
//! * [`util`] — zero-dependency substrates: PRNG, JSON, CLI parsing,
//!   table rendering, property-testing and micro-benchmark harnesses.
//! * [`types`] — frames, boxes, detections, time.
//! * [`video`] — synthetic benchmark clip generator (MOT-15 analogs).
//! * [`eval`] — IoU / NMS / VOC-style mAP evaluation.
//! * [`detector`] — detector backends: calibrated quality model and the
//!   PJRT-served TinyDet.
//! * [`device`] — edge device / link / USB-hub / energy models.
//! * [`sim`] — discrete-event engine (virtual time).
//! * [`coordinator`] — the paper's contribution: parallel detection
//!   schedulers, sequence synchronizer, n-selection, drop policy, metrics.
//! * [`runtime`] — PJRT client wrapper loading `artifacts/*.hlo.txt`.
//! * [`server`] — real-time serving pipeline (threads; python-free).
//! * [`fleet`] — multi-stream serving over a shared heterogeneous device
//!   pool: per-stream paced sources/windows/synchronizers, weighted
//!   max-min admission control (admit/degrade/reject, stride or
//!   model-swap degradation), dynamic stream/device attach-detach, and
//!   fleet metrics (per-stream σ, latency percentiles, device
//!   utilisation, Jain fairness) — in both virtual-time (DES) and
//!   wall-clock (threaded) modes.
//! * [`control`] — the serialisable control plane: one vocabulary for
//!   everything that steers a running fleet (membership actions, model
//!   swaps, admission outcomes), a versioned JSON wire codec
//!   (`WireEvent` over [`util::json`]) and a replayable `EventLog`.
//!   Scenario scripts, the autoscale controller and the shard placement
//!   layer all speak this layer, so control decisions can cross a
//!   process boundary. `control::binary` is the compact hot-path twin
//!   of the JSON codec (varints, interned strings, adaptive floats):
//!   same events, a fraction of the bytes, exact-parity pinned — JSON
//!   stays the audit/debug format.
//! * [`autoscale`] — closed-loop adaptation above the fleet: windowed
//!   per-stream signals drive a generalised-nselect device controller
//!   (attach/detach replicas with hysteresis + cooldown) and a
//!   quality controller walking a model ladder (SSD300 ↔ YOLOv3 ↔ tiny
//!   variants, an accuracy–rate Pareto frontier), replacing scripted
//!   control events with feedback control.
//! * [`shard`] — stream sharding across fleet instances: a placement
//!   layer (least-loaded / hash / round-robin) partitions N streams over
//!   M shards, each wrapping its own registry and device pool; a
//!   periodic capacity gossip exchanges per-shard headroom (the §III-B
//!   Σμ-vs-Σλ band) and drives stream migration — and shard-loss
//!   re-placement — via serialised detach→attach control events.
//!   `shard::remote` runs the same co-simulation with every fleet
//!   instance behind a real socket; a dropped connection is shard loss,
//!   and a scripted rejoin redials with backoff, re-handshakes as a
//!   fresh session and re-enters gossip — the planner re-levels onto
//!   the returning shard, and `ShardScenario::handover` charges
//!   detach→attach migrations a window-refill toll so frames in
//!   flight price the move. Scenarios are built through one surface,
//!   `ShardScenario::builder(..)`.
//!   `shard::autoscale` embeds the closed loop *inside* each shard:
//!   capacity grows locally before the gossip migrates load away,
//!   digests advertise post-scale headroom, and every scale action
//!   rides the wire into the coordinator's audit log. At scale the
//!   coordinator goes hierarchical: `shard::group` aggregates member
//!   digests into shard-group summaries with delta-encoded digest
//!   streams (changed shards only, periodic full resync), and
//!   `shard::plan` is the extracted migration planner — flat or
//!   two-level over those group aggregates, descending into members
//!   only on imbalance, with deterministic read counters benches pin.
//! * [`transport`] — the cross-host seam under all of it: a
//!   length-prefixed, versioned frame codec for `WireEvent` traffic
//!   over blocking TCP / Unix-domain sockets (split frames, oversized
//!   lengths — with a configurable payload cap — version mismatch and
//!   peer loss handled explicitly), a dial-with-backoff client, and a
//!   remote `fleet::serve` consumer driven by a decoded `EventLog`
//!   stream instead of in-process calls. The frame version byte selects
//!   the payload codec (JSON or `control::binary`), and connections
//!   mirror whatever codec the peer last spoke. Sessions open with a
//!   versioned capability set (`control::SessionCaps` on `Hello`:
//!   autoscale, gate, telemetry, shared-secret auth token) under one
//!   forward-compat contract; a bad token or protocol skew gets a
//!   typed `Reject` frame, never a hang, and `eva shard-server
//!   --listen <addr>` serves a shard on a real (non-loopback) bind.
//! * [`gate`] — per-frame motion-gated detection: a per-stream motion
//!   energy signal (frame-diff MSE over rastered clips, or calibrated
//!   content-dynamics models for pixel-free paths) feeds a transprecision
//!   controller that skips quiet frames (stale boxes stand in via a
//!   constant-velocity tracker proxy), down-rungs budget-pressured
//!   frames along the model ladder, and always re-detects on scene
//!   cuts. Verdicts ride the control plane as origin-tagged
//!   `WireEvent`s, so gated runs replay — locally and across shards.
//! * [`telemetry`] — end-to-end observability: a zero-dependency
//!   metrics registry (labelled counters/gauges, log-scale latency
//!   histograms with exact percentiles, Prometheus-style exposition,
//!   JSON snapshots that merge across shards) and per-frame span
//!   tracing (capture → admit/gate → queue → detect → deliver) in both
//!   engines. Stage durations partition the capture→emit latency
//!   exactly, traces join against the replayable `EventLog` to
//!   attribute latency to the control class that caused it, and remote
//!   shards ship cumulative snapshots over the wire each epoch.
//! * [`forecast`] — the predicted-Σλ layer over all three control
//!   loops: per-stream EWMA + seasonal-decomposition rate forecasters
//!   ([`util::stats::Ewma`] substrate) learn the diurnal shape from
//!   repeated windows, aggregate per shard, and publish a
//!   confidence-gated forecast-Σλ slot in the gossip digest (forward-
//!   compatible in both codecs: legacy digests decode with the slot
//!   absent). The migration planner places against
//!   `max(committed, forecast)` so load sheds ahead of predicted
//!   ramps, the per-shard autoscaler attaches ahead of the step when
//!   the band is tight, and admission holds (rather than degrades)
//!   transient bursts the forecast says will clear within a window.
//! * [`experiments`] — table/figure reproduction drivers shared by the
//!   bench binaries and the CLI. `experiments::scale` is the
//!   coordinator-cost sweep: flat vs grouped planning reads, JSON vs
//!   binary digest bytes and delta vs snapshot streams at 100k+
//!   simulated streams (EXPERIMENTS.md §Scale). `experiments::churn`
//!   is the rolling-restart chaos sweep: every shard down in turn at
//!   2× load with handover costs armed, pinned to a delivered-FPS
//!   floor and a one-interval orphan re-placement deadline
//!   (EXPERIMENTS.md §Churn).

pub mod util;
pub mod types;
pub mod video;
pub mod eval;
pub mod detector;
pub mod device;
pub mod sim;
pub mod coordinator;
pub mod runtime;
pub mod server;
pub mod control;
pub mod transport;
pub mod fleet;
pub mod autoscale;
pub mod shard;
pub mod gate;
pub mod telemetry;
pub mod forecast;
pub mod experiments;
