//! Energy accounting (Table VI): TDP-based power model with busy/idle
//! tracking per device.

use crate::device::DeviceKind;

/// Accumulates busy time per device and converts to energy.
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    entries: Vec<EnergyEntry>,
}

#[derive(Debug, Clone)]
pub struct EnergyEntry {
    pub kind: DeviceKind,
    pub busy_seconds: f64,
}

impl EnergyMeter {
    pub fn new(kinds: &[DeviceKind]) -> EnergyMeter {
        EnergyMeter {
            entries: kinds
                .iter()
                .map(|&kind| EnergyEntry {
                    kind,
                    busy_seconds: 0.0,
                })
                .collect(),
        }
    }

    pub fn record_busy(&mut self, device: usize, seconds: f64) {
        self.entries[device].busy_seconds += seconds;
    }

    /// Energy burned while busy, in joules (TDP × busy time).
    pub fn busy_joules(&self) -> f64 {
        self.entries
            .iter()
            .map(|e| e.kind.tdp_watts() * e.busy_seconds)
            .sum()
    }

    /// Worst-case energy over a wall-clock window (all devices at TDP the
    /// whole time — the paper's TDP-based comparison).
    pub fn window_joules(&self, wall_seconds: f64) -> f64 {
        self.entries
            .iter()
            .map(|e| e.kind.tdp_watts() * wall_seconds)
            .sum()
    }

    pub fn total_busy_seconds(&self) -> f64 {
        self.entries.iter().map(|e| e.busy_seconds).sum()
    }

    pub fn entries(&self) -> &[EnergyEntry] {
        &self.entries
    }
}

/// Table VI's figure of merit: detection FPS per watt of TDP.
pub fn fps_per_watt(fps: f64, kind: DeviceKind) -> f64 {
    fps / kind.tdp_watts()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_fps_per_watt() {
        // Paper: NCS2 1.25, slow CPU 0.03, fast CPU 0.11, GPU 0.14.
        assert!((fps_per_watt(2.5, DeviceKind::Ncs2) - 1.25).abs() < 1e-9);
        assert!((fps_per_watt(0.4, DeviceKind::SlowCpu) - 0.0267).abs() < 0.002);
        assert!((fps_per_watt(13.5, DeviceKind::FastCpu) - 0.108).abs() < 0.002);
        assert!((fps_per_watt(35.0, DeviceKind::TitanX) - 0.14).abs() < 1e-9);
    }

    #[test]
    fn ncs2_most_efficient() {
        let eff = [
            fps_per_watt(2.5, DeviceKind::Ncs2),
            fps_per_watt(0.4, DeviceKind::SlowCpu),
            fps_per_watt(13.5, DeviceKind::FastCpu),
            fps_per_watt(35.0, DeviceKind::TitanX),
        ];
        assert!(eff[0] > eff[1] && eff[0] > eff[2] && eff[0] > eff[3]);
    }

    #[test]
    fn meter_accumulates() {
        let mut m = EnergyMeter::new(&[DeviceKind::Ncs2, DeviceKind::Ncs2]);
        m.record_busy(0, 10.0);
        m.record_busy(1, 5.0);
        assert_eq!(m.total_busy_seconds(), 15.0);
        assert_eq!(m.busy_joules(), 2.0 * 15.0);
        assert_eq!(m.window_joules(10.0), 2.0 * 2.0 * 10.0);
    }
}
