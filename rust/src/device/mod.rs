//! Edge device, AI-accelerator, link and energy models.
//!
//! Calibration constants come straight from the paper (DESIGN.md §7):
//! service rates from Tables IV–VII, TDP from Table VI, link bandwidths
//! from Table VIII, and the USB 2.0 *effective* bandwidth is derived from
//! Table IX's single-stick slowdown (2.5 -> 1.9 FPS for YOLOv3 implies
//! ≈126 ms of extra per-frame transfer, i.e. ≈66 Mbps effective for the
//! 1 MB FP16 YOLO payload — which then also predicts the n≈5 plateau).

pub mod link;
pub mod energy;

use crate::util::Rng;

/// Kinds of compute devices in the paper's testbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Intel Neural Compute Stick 2 (Movidius VPU, via USB).
    Ncs2,
    /// Fast edge server CPU (Intel i7-10700K).
    FastCpu,
    /// Slow edge server CPU (AMD A6-9225).
    SlowCpu,
    /// Discrete GPU (GTX Titan X) — energy comparison only.
    TitanX,
}

impl DeviceKind {
    pub fn label(&self) -> &'static str {
        match self {
            DeviceKind::Ncs2 => "Intel NCS2",
            DeviceKind::FastCpu => "Fast CPU (i7-10700K)",
            DeviceKind::SlowCpu => "Slow CPU (A6-9225)",
            DeviceKind::TitanX => "GPU (GTX TITAN X)",
        }
    }

    /// Thermal design power in watts (Table VI).
    pub fn tdp_watts(&self) -> f64 {
        match self {
            DeviceKind::Ncs2 => 2.0,
            DeviceKind::FastCpu => 125.0,
            DeviceKind::SlowCpu => 15.0,
            DeviceKind::TitanX => 250.0,
        }
    }

    /// Whether frames must cross an external link (USB hub) to reach the
    /// device. CPUs consume frames from host memory.
    pub fn needs_link(&self) -> bool {
        matches!(self, DeviceKind::Ncs2)
    }

    /// Calibrated zero-drop detection rate μ (frames/second) for a model
    /// (Tables IV–VII). `None` if the paper gives no figure and the
    /// combination is unused.
    pub fn service_rate(&self, model: DetectorModelId) -> f64 {
        use DetectorModelId::*;
        match (self, model) {
            (DeviceKind::Ncs2, Ssd300) => 2.3,
            (DeviceKind::Ncs2, Yolov3) => 2.5,
            (DeviceKind::FastCpu, Yolov3) => 13.5,
            // SSD300 ≈ 0.92× YOLOv3's per-frame cost ratio on CPU (derived
            // from the NCS2 ratio 2.3/2.5); not reported in the paper.
            (DeviceKind::FastCpu, Ssd300) => 12.4,
            (DeviceKind::SlowCpu, Yolov3) => 0.4,
            (DeviceKind::SlowCpu, Ssd300) => 0.37,
            (DeviceKind::TitanX, Yolov3) => 35.0,
            (DeviceKind::TitanX, Ssd300) => 46.0,
        }
    }
}

/// The two paper models (paper-scale profiles; the PJRT TinyDet variants
/// `essd`/`eyolo` stand in for them on the live path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DetectorModelId {
    Ssd300,
    Yolov3,
}

impl DetectorModelId {
    pub fn label(&self) -> &'static str {
        match self {
            DetectorModelId::Ssd300 => "SSD300",
            DetectorModelId::Yolov3 => "YOLOv3",
        }
    }

    /// Square input size in pixels (Table II).
    pub fn input_size(&self) -> u32 {
        match self {
            DetectorModelId::Ssd300 => 300,
            DetectorModelId::Yolov3 => 416,
        }
    }

    /// Bytes shipped to the accelerator per frame: FP16 blob (Table II's
    /// models are FP16-quantised for NCS2).
    pub fn wire_bytes(&self) -> u64 {
        crate::types::Frame::wire_bytes(self.input_size(), 2)
    }

    /// Model file size in MB (Table II).
    pub fn model_size_mb(&self) -> u32 {
        match self {
            DetectorModelId::Ssd300 => 51,
            DetectorModelId::Yolov3 => 119,
        }
    }

    pub fn backbone(&self) -> &'static str {
        match self {
            DetectorModelId::Ssd300 => "VGG-16",
            DetectorModelId::Yolov3 => "DarkNet-53",
        }
    }

    pub fn parse(s: &str) -> Option<DetectorModelId> {
        match s.to_ascii_lowercase().as_str() {
            "ssd" | "ssd300" | "essd" => Some(DetectorModelId::Ssd300),
            "yolo" | "yolov3" | "eyolo" => Some(DetectorModelId::Yolov3),
            _ => None,
        }
    }
}

/// One concrete device instance in a fleet (e.g. "NCS2 stick #3").
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceInstance {
    pub kind: DeviceKind,
    pub model: DetectorModelId,
    /// Index within the fleet (stable replica id).
    pub replica: usize,
    /// Service-time jitter coefficient of variation (0 = deterministic).
    pub jitter_cv: f64,
    /// Overrides the calibrated `service_rate` when set (used e.g. by the
    /// Table X language-runtime experiment, whose prototype ran faster
    /// per-stick than the Table V configuration).
    pub rate_override: Option<f64>,
}

impl DeviceInstance {
    pub fn new(kind: DeviceKind, model: DetectorModelId, replica: usize) -> DeviceInstance {
        DeviceInstance {
            kind,
            model,
            replica,
            jitter_cv: 0.015,
            rate_override: None,
        }
    }

    /// Device with an explicit service rate (frames/second).
    pub fn with_rate(kind: DeviceKind, model: DetectorModelId, replica: usize, rate: f64) -> DeviceInstance {
        let mut d = DeviceInstance::new(kind, model, replica);
        d.rate_override = Some(rate);
        d
    }

    /// Effective service rate μ (frames/second).
    pub fn rate(&self) -> f64 {
        self.rate_override
            .unwrap_or_else(|| self.kind.service_rate(self.model))
    }

    /// Mean per-frame compute time (excludes link transfer).
    pub fn mean_service_time(&self) -> f64 {
        1.0 / self.rate()
    }

    /// Draw one service time (lognormal-ish jitter around the mean).
    pub fn sample_service_time(&self, rng: &mut Rng) -> f64 {
        let mean = self.mean_service_time();
        if self.jitter_cv <= 0.0 {
            return mean;
        }
        let noisy = mean * (1.0 + self.jitter_cv * rng.normal());
        noisy.max(0.25 * mean)
    }
}

/// A fleet: the devices participating in parallel detection, plus the
/// shared link (if any) that frames traverse to reach USB devices.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub devices: Vec<DeviceInstance>,
    pub hub: Option<link::LinkProfile>,
}

impl Fleet {
    /// `n` homogeneous NCS2 sticks behind a hub (the paper's baseline).
    pub fn ncs2_sticks(n: usize, model: DetectorModelId, hub: link::LinkProfile) -> Fleet {
        Fleet {
            devices: (0..n)
                .map(|i| DeviceInstance::new(DeviceKind::Ncs2, model, i))
                .collect(),
            hub: Some(hub),
        }
    }

    /// CPU + `n` NCS2 sticks (Table VII's heterogeneous setup).
    pub fn cpu_plus_sticks(
        cpu: DeviceKind,
        n: usize,
        model: DetectorModelId,
        hub: link::LinkProfile,
    ) -> Fleet {
        let mut devices = vec![DeviceInstance::new(cpu, model, 0)];
        devices.extend((0..n).map(|i| DeviceInstance::new(DeviceKind::Ncs2, model, i + 1)));
        Fleet {
            devices,
            hub: Some(hub),
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Mean single-model service rate μ across the fleet (used by the
    /// n-selection rule when devices are homogeneous).
    pub fn mean_rate(&self) -> f64 {
        if self.devices.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .devices
            .iter()
            .map(|d| d.rate())
            .sum();
        sum / self.devices.len() as f64
    }

    /// Aggregate ideal rate Σμᵢ (§III-B's σ_P upper bound).
    pub fn aggregate_rate(&self) -> f64 {
        self.devices
            .iter()
            .map(|d| d.rate())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::link::LinkProfile;

    #[test]
    fn table6_tdp_values() {
        assert_eq!(DeviceKind::Ncs2.tdp_watts(), 2.0);
        assert_eq!(DeviceKind::SlowCpu.tdp_watts(), 15.0);
        assert_eq!(DeviceKind::FastCpu.tdp_watts(), 125.0);
        assert_eq!(DeviceKind::TitanX.tdp_watts(), 250.0);
    }

    #[test]
    fn calibrated_rates_match_paper() {
        assert_eq!(DeviceKind::Ncs2.service_rate(DetectorModelId::Yolov3), 2.5);
        assert_eq!(DeviceKind::Ncs2.service_rate(DetectorModelId::Ssd300), 2.3);
        assert_eq!(DeviceKind::FastCpu.service_rate(DetectorModelId::Yolov3), 13.5);
        assert_eq!(DeviceKind::SlowCpu.service_rate(DetectorModelId::Yolov3), 0.4);
        assert_eq!(DeviceKind::TitanX.service_rate(DetectorModelId::Yolov3), 35.0);
    }

    #[test]
    fn table2_model_specs() {
        assert_eq!(DetectorModelId::Yolov3.input_size(), 416);
        assert_eq!(DetectorModelId::Ssd300.input_size(), 300);
        assert_eq!(DetectorModelId::Yolov3.wire_bytes(), 2 * 519_168);
        assert_eq!(DetectorModelId::Yolov3.model_size_mb(), 119);
        assert_eq!(DetectorModelId::Ssd300.model_size_mb(), 51);
    }

    #[test]
    fn service_time_sampling_positive_and_near_mean() {
        let d = DeviceInstance::new(DeviceKind::Ncs2, DetectorModelId::Yolov3, 0);
        let mut rng = Rng::new(5);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| d.sample_service_time(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 0.4).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fleet_builders() {
        let f = Fleet::ncs2_sticks(7, DetectorModelId::Yolov3, LinkProfile::usb3());
        assert_eq!(f.len(), 7);
        assert!((f.aggregate_rate() - 17.5).abs() < 1e-9);
        assert!((f.mean_rate() - 2.5).abs() < 1e-9);

        let h = Fleet::cpu_plus_sticks(
            DeviceKind::FastCpu,
            7,
            DetectorModelId::Yolov3,
            LinkProfile::usb3(),
        );
        assert_eq!(h.len(), 8);
        assert!((h.aggregate_rate() - (13.5 + 17.5)).abs() < 1e-9);
    }

    #[test]
    fn parse_model_names() {
        assert_eq!(DetectorModelId::parse("YOLOv3"), Some(DetectorModelId::Yolov3));
        assert_eq!(DetectorModelId::parse("ssd"), Some(DetectorModelId::Ssd300));
        assert_eq!(DetectorModelId::parse("resnet"), None);
    }
}
