//! Connection-interface model: Table VIII's bandwidth registry and the
//! shared-hub transfer behaviour behind Table IX.
//!
//! A USB hub is a *shared, serialising* resource: all sticks' frame
//! transfers are queued on one bus. Effective bandwidth is nominal ×
//! efficiency; the USB 2.0 efficiency is back-solved from Table IX's
//! single-stick slowdown (see module docs in [`crate::device`]).

/// A (possibly shared) transfer link.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkProfile {
    pub name: &'static str,
    /// Nominal bandwidth in bits/second (marketing number, Table VIII).
    pub nominal_bps: f64,
    /// Achievable fraction of nominal for bulk frame payloads.
    pub efficiency: f64,
    /// Fixed per-transfer overhead in seconds (setup/ack).
    pub per_transfer_overhead: f64,
}

impl LinkProfile {
    pub fn effective_bps(&self) -> f64 {
        self.nominal_bps * self.efficiency
    }

    /// Time for one frame payload to cross the link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.per_transfer_overhead + (bytes as f64 * 8.0) / self.effective_bps()
    }

    /// USB 2.0: 480 Mbps nominal; ≈66 Mbps effective for OpenVINO-style
    /// inference payloads (back-solved from Table IX: YOLOv3 2.5 -> 1.9
    /// FPS at n = 1 ⇒ ≈126 ms extra per 8.3 Mb frame).
    pub fn usb2() -> LinkProfile {
        LinkProfile {
            name: "USB 2.0",
            nominal_bps: 480e6,
            efficiency: 0.1375, // -> 66 Mbps effective
            per_transfer_overhead: 0.0,
        }
    }

    /// USB 3.0: 5 Gbps nominal; bulk transfers reach ~80 %.
    pub fn usb3() -> LinkProfile {
        LinkProfile {
            name: "USB 3.0",
            nominal_bps: 5e9,
            efficiency: 0.8,
            per_transfer_overhead: 0.0,
        }
    }

    pub fn ethernet_1g() -> LinkProfile {
        LinkProfile {
            name: "Ethernet",
            nominal_bps: 1e9,
            efficiency: 0.9,
            per_transfer_overhead: 0.0002,
        }
    }

    pub fn ethernet_10g() -> LinkProfile {
        LinkProfile {
            name: "10 Gigabit Ethernet",
            nominal_bps: 10e9,
            efficiency: 0.9,
            per_transfer_overhead: 0.0002,
        }
    }

    pub fn wifi6() -> LinkProfile {
        LinkProfile {
            name: "WiFi 6",
            nominal_bps: 10e9,
            efficiency: 0.35,
            per_transfer_overhead: 0.001,
        }
    }

    pub fn cellular_4g() -> LinkProfile {
        LinkProfile {
            name: "4G (peak)",
            nominal_bps: 1e9,
            efficiency: 0.25,
            per_transfer_overhead: 0.01,
        }
    }

    pub fn cellular_5g() -> LinkProfile {
        LinkProfile {
            name: "5G (peak)",
            nominal_bps: 20e9,
            efficiency: 0.4,
            per_transfer_overhead: 0.002,
        }
    }

    /// Table VIII's full registry, in the paper's column order.
    pub fn registry() -> Vec<LinkProfile> {
        vec![
            LinkProfile::usb2(),
            LinkProfile::usb3(),
            LinkProfile::ethernet_1g(),
            LinkProfile::ethernet_10g(),
            LinkProfile::wifi6(),
            LinkProfile::cellular_4g(),
            LinkProfile::cellular_5g(),
        ]
    }

    pub fn by_name(name: &str) -> Option<LinkProfile> {
        match name.to_ascii_lowercase().as_str() {
            "usb2" | "usb2.0" | "usb 2.0" => Some(LinkProfile::usb2()),
            "usb3" | "usb3.0" | "usb 3.0" => Some(LinkProfile::usb3()),
            "eth" | "ethernet" => Some(LinkProfile::ethernet_1g()),
            "10gbe" | "eth10g" => Some(LinkProfile::ethernet_10g()),
            "wifi6" => Some(LinkProfile::wifi6()),
            "4g" => Some(LinkProfile::cellular_4g()),
            "5g" => Some(LinkProfile::cellular_5g()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DetectorModelId;

    #[test]
    fn table8_nominal_bandwidths() {
        assert_eq!(LinkProfile::usb2().nominal_bps, 480e6);
        assert_eq!(LinkProfile::usb3().nominal_bps, 5e9);
        assert_eq!(LinkProfile::ethernet_10g().nominal_bps, 10e9);
        assert_eq!(LinkProfile::cellular_5g().nominal_bps, 20e9);
        assert_eq!(LinkProfile::registry().len(), 7);
    }

    #[test]
    fn usb2_reproduces_single_stick_slowdown() {
        // YOLOv3 FP16 payload over USB 2.0 must cost ≈126 ms so that
        // 1 / (0.4 + 0.126) ≈ 1.9 FPS (Table IX, n = 1).
        let t = LinkProfile::usb2().transfer_time(DetectorModelId::Yolov3.wire_bytes());
        let fps = 1.0 / (0.4 + t);
        assert!((t - 0.126).abs() < 0.005, "transfer {t}");
        assert!((fps - 1.9).abs() < 0.05, "fps {fps}");
    }

    #[test]
    fn usb2_ssd_single_stick() {
        // SSD300: 1 / (1/2.3 + transfer) ≈ 2.0 FPS (Table IX, n = 1).
        let t = LinkProfile::usb2().transfer_time(DetectorModelId::Ssd300.wire_bytes());
        let fps = 1.0 / (1.0 / 2.3 + t);
        assert!((fps - 2.0).abs() < 0.06, "fps {fps}");
    }

    #[test]
    fn usb2_saturation_rate_near_8fps_for_yolo() {
        // Bus capacity / per-frame bits ⇒ the Table IX plateau (~8 FPS).
        let link = LinkProfile::usb2();
        let cap = link.effective_bps() / (DetectorModelId::Yolov3.wire_bytes() as f64 * 8.0);
        assert!((cap - 7.95).abs() < 0.2, "cap {cap}");
    }

    #[test]
    fn usb3_transfer_negligible() {
        let t = LinkProfile::usb3().transfer_time(DetectorModelId::Yolov3.wire_bytes());
        assert!(t < 0.003, "usb3 transfer {t}");
    }

    #[test]
    fn lookup() {
        assert_eq!(LinkProfile::by_name("usb2").unwrap().name, "USB 2.0");
        assert!(LinkProfile::by_name("carrier-pigeon").is_none());
    }
}
