//! Closed-loop adaptation: feedback-driven device scaling and
//! quality-aware model swap.
//!
//! The paper picks the parallelism degree *n* once, offline, from the
//! §III-B nselect band; the fleet layer reacts only to scripted control
//! events. This subsystem closes the loop: per-stream signals observed
//! at runtime drive [`crate::control::ControlAction`]s through the
//! [`crate::fleet::sim::FleetController`] seam, and every applied action
//! lands in the serialisable [`crate::control::EventLog`].
//!
//! * [`signals`] — sliding-window observers per stream (p99 output
//!   latency, drop rate, delivered FPS) fed from the engines' emitted
//!   records.
//! * [`ladder`] — the model ladder: an accuracy–rate Pareto frontier
//!   over SSD300 / YOLOv3 and their tiny variants, built from the
//!   calibrated [`crate::detector::quality`] profiles, plus the
//!   staleness model that prices stale-box reuse.
//! * [`policy`] — the controllers: a generalised-nselect device
//!   controller (attach/detach replicas to hold Σμ inside the
//!   `[Σ⌈floor(λ)⌉, Σλ]/util` band, with hysteresis and cooldown) and a
//!   per-stream quality controller that walks the ladder so overload
//!   trades mAP for rate *before* falling back to stride subsampling.
//! * [`runner`] — end-to-end drivers: deterministic virtual time
//!   ([`runner::run_autoscale_sim`]) and wall clock at epoch
//!   granularity ([`runner::run_autoscale_serve`]).
//!
//! Quality-aware admission itself lives in
//! [`crate::fleet::admission::DegradeMode::ModelSwap`]: re-levelling on
//! any membership or capacity change walks streams down and up the
//! ladder; the controllers here add the feedback that changes membership
//! (devices) and overrides rungs from observed signals.

pub mod ladder;
pub mod policy;
pub mod runner;
pub mod signals;

pub use ladder::{quality_estimate, staleness_factor, ModelLadder, Rung, STALENESS_TAU};
pub use policy::{capacity_band, device_band, floor_demand, AutoscaleConfig, AutoscaleController};
pub use runner::{run_autoscale_serve, run_autoscale_sim, AutoscaleOutcome, EpochPoint};
pub use signals::{FleetSignals, StreamWindow};
