//! Windowed per-stream / per-pool signal observers.
//!
//! The controllers in [`crate::autoscale::policy`] act on *recent*
//! behaviour, not whole-run aggregates: each stream gets a sliding
//! window of output-record observations (fed from the engine via
//! [`crate::fleet::sim::FleetController::observe`], i.e. the same
//! records that back [`crate::fleet::metrics`]), from which the
//! controller reads p99 output latency, drop rate and effective
//! delivered FPS over the last `window` seconds of fleet time.
//!
//! Windows are small (λ·window samples, tens of entries), so queries
//! sort a scratch copy — no sketch machinery needed at control-loop
//! rates.

use crate::types::OutputRecord;
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy)]
struct Sample {
    t: f64,
    latency: f64,
    dropped: bool,
}

/// Sliding-window observer for one stream.
#[derive(Debug, Clone)]
pub struct StreamWindow {
    window: f64,
    samples: VecDeque<Sample>,
}

impl StreamWindow {
    pub fn new(window: f64) -> StreamWindow {
        assert!(window > 0.0, "signal window must be positive");
        StreamWindow {
            window,
            samples: VecDeque::new(),
        }
    }

    /// Record one emitted output record observed at fleet time `now`.
    pub fn observe_record(&mut self, now: f64, record: &OutputRecord) {
        self.observe(
            now,
            (record.emit_ts - record.capture_ts).max(0.0),
            record.was_dropped(),
        );
    }

    /// Record a raw `(latency, dropped)` observation at time `now`.
    pub fn observe(&mut self, now: f64, latency: f64, dropped: bool) {
        self.samples.push_back(Sample { t: now, latency, dropped });
        self.evict(now);
    }

    fn evict(&mut self, now: f64) {
        while let Some(s) = self.samples.front() {
            if s.t < now - self.window {
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }

    /// Observations currently inside the window (as of time `now`).
    pub fn sample_count(&mut self, now: f64) -> usize {
        self.evict(now);
        self.samples.len()
    }

    /// Forget everything — used when the observed stream's operating
    /// point changes (re-levelled stride/rung): samples from the old
    /// regime must not drive decisions about the new one.
    pub fn clear(&mut self) {
        self.samples.clear();
    }

    /// p99 output latency over the window (0 when empty) — nearest-rank
    /// over all records, dropped ones included: a stale record's latency
    /// is real output staleness the consumer sees.
    pub fn p99(&mut self, now: f64) -> f64 {
        self.pct(now, 99.0)
    }

    /// Nearest-rank percentile over the window's latencies.
    pub fn pct(&mut self, now: f64, p: f64) -> f64 {
        self.evict(now);
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut xs: Vec<f64> = self.samples.iter().map(|s| s.latency).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rank = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        xs[rank.min(xs.len() - 1)]
    }

    /// `(dropped, total)` record counts in the window.
    pub fn drop_counts(&mut self, now: f64) -> (usize, usize) {
        self.evict(now);
        let total = self.samples.len();
        let dropped = self.samples.iter().filter(|s| s.dropped).count();
        (dropped, total)
    }

    /// Fraction of windowed records that were dropped (0 when empty).
    pub fn drop_rate(&mut self, now: f64) -> f64 {
        let (dropped, total) = self.drop_counts(now);
        if total == 0 {
            0.0
        } else {
            dropped as f64 / total as f64
        }
    }

    /// Processed (non-dropped) records per second over the window. The
    /// denominator is the observed span, not the full window width, so a
    /// window that has not filled yet (stream just attached) does not
    /// read as phantom underload; a floor of a tenth of the window keeps
    /// a lone first sample from reading as a rate spike instead.
    pub fn processed_fps(&mut self, now: f64) -> f64 {
        self.evict(now);
        let Some(first) = self.samples.front() else {
            return 0.0;
        };
        let span = (now - first.t).min(self.window).max(self.window * 0.1);
        let processed = self.samples.iter().filter(|s| !s.dropped).count();
        processed as f64 / span
    }
}

/// Per-stream windows for a whole fleet, indexed by `StreamId`; grows on
/// demand as streams attach mid-run.
#[derive(Debug, Clone)]
pub struct FleetSignals {
    window: f64,
    streams: Vec<StreamWindow>,
}

impl FleetSignals {
    pub fn new(window: f64) -> FleetSignals {
        assert!(window > 0.0, "signal window must be positive");
        FleetSignals {
            window,
            streams: Vec::new(),
        }
    }

    pub fn window(&self) -> f64 {
        self.window
    }

    /// Feed one emitted record of stream `sid`.
    pub fn observe(&mut self, now: f64, sid: usize, record: &OutputRecord) {
        self.stream_mut(sid).observe_record(now, record);
    }

    /// The window for stream `sid` (created empty on first touch).
    pub fn stream_mut(&mut self, sid: usize) -> &mut StreamWindow {
        while self.streams.len() <= sid {
            self.streams.push(StreamWindow::new(self.window));
        }
        &mut self.streams[sid]
    }

    /// Worst per-stream p99 across `sids` (the stream that governs
    /// scale-up pressure).
    pub fn worst_p99(&mut self, now: f64, sids: &[usize]) -> f64 {
        sids.iter()
            .map(|&sid| self.stream_mut(sid).p99(now))
            .fold(0.0, f64::max)
    }

    /// Aggregate `(dropped, total)` record counts across `sids`.
    pub fn drop_counts(&mut self, now: f64, sids: &[usize]) -> (usize, usize) {
        let mut dropped = 0;
        let mut total = 0;
        for &sid in sids {
            let (d, t) = self.stream_mut(sid).drop_counts(now);
            dropped += d;
            total += t;
        }
        (dropped, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(fid: u64, capture: f64, emit: f64, dropped: bool) -> OutputRecord {
        OutputRecord {
            frame_id: fid,
            capture_ts: capture,
            emit_ts: emit,
            detections: vec![],
            stale_from: if dropped { Some(fid) } else { None },
            processed_by: if dropped { None } else { Some(0) },
        }
    }

    #[test]
    fn window_evicts_old_samples() {
        let mut w = StreamWindow::new(2.0);
        w.observe(0.0, 0.1, false);
        w.observe(1.0, 0.2, false);
        w.observe(3.5, 0.3, false);
        // t=3.5: the t=0 and t=1 samples are out of the 2 s window.
        assert_eq!(w.sample_count(3.5), 1);
        assert!((w.p99(3.5) - 0.3).abs() < 1e-12);
        // A later query time alone evicts, too.
        assert_eq!(w.sample_count(10.0), 0);
        assert_eq!(w.p99(10.0), 0.0);
    }

    #[test]
    fn percentiles_and_drop_rate_over_window() {
        let mut w = StreamWindow::new(10.0);
        for i in 0..100 {
            w.observe(i as f64 * 0.05, i as f64 * 0.01, i % 4 == 0);
        }
        let p99 = w.p99(5.0);
        assert!(p99 >= 0.97 && p99 <= 0.99, "p99 {p99}");
        assert!((w.drop_rate(5.0) - 0.25).abs() < 1e-9);
        let (d, t) = w.drop_counts(5.0);
        assert_eq!((d, t), (25, 100));
        // 75 processed over the observed 5 s span (the window has not
        // filled yet — the denominator must not be the full 10 s).
        assert!((w.processed_fps(5.0) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn processed_fps_spans_are_sane_at_the_edges() {
        let mut w = StreamWindow::new(4.0);
        assert_eq!(w.processed_fps(1.0), 0.0);
        // A lone fresh sample is rate-floored, not a spike.
        w.observe(1.0, 0.01, false);
        assert!((w.processed_fps(1.0) - 1.0 / 0.4).abs() < 1e-9);
        // A full window divides by the window width.
        for i in 0..40 {
            w.observe(1.0 + i as f64 * 0.25, 0.01, false);
        }
        let fps = w.processed_fps(11.0);
        // Samples older than now-4 are evicted; ~16 remain over 4 s.
        assert!(fps > 3.0 && fps < 4.5, "fps {fps}");
    }

    #[test]
    fn observe_record_derives_latency_and_fate() {
        let mut w = StreamWindow::new(5.0);
        w.observe_record(1.0, &rec(0, 0.4, 1.0, false));
        w.observe_record(1.2, &rec(1, 0.5, 1.2, true));
        assert_eq!(w.sample_count(1.2), 2);
        assert!((w.pct(1.2, 100.0) - 0.7).abs() < 1e-9);
        assert!((w.drop_rate(1.2) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fleet_signals_grow_on_demand_and_aggregate() {
        let mut sig = FleetSignals::new(4.0);
        sig.observe(1.0, 0, &rec(0, 0.5, 1.0, false));
        sig.observe(1.0, 3, &rec(0, 0.0, 1.0, true));
        assert!((sig.worst_p99(1.0, &[0, 3]) - 1.0).abs() < 1e-9);
        assert_eq!(sig.drop_counts(1.0, &[0, 1, 2, 3]), (1, 2));
        // Untouched streams read as empty, not as errors.
        assert_eq!(sig.stream_mut(2).sample_count(1.0), 0);
    }
}
