//! The model ladder: an accuracy–rate Pareto frontier over detector
//! variants, built from the calibrated [`crate::detector::quality`]
//! profiles.
//!
//! Quality-aware degradation swaps an overloaded stream onto a faster,
//! lower-mAP rung *before* falling back to frame-stride subsampling:
//! frames keep flowing (fresh boxes at reduced fidelity) instead of
//! being replaced by stale reuse (full fidelity of the wrong moment).
//! Candidates are the paper's two full models (SSD300, YOLOv3) plus
//! their tiny variants ([`QualityProfile::tiny`]); dominated candidates
//! — slower *and* less accurate, e.g. SSD300 next to YOLOv3 on NCS2 —
//! are pruned, leaving a ladder that is strictly faster and strictly
//! less accurate rung by rung.
//!
//! The intrinsic rung quality is an analytic mAP proxy derived from the
//! profile statistics ([`quality_estimate`]); EXPERIMENTS.md §Autoscale
//! records how it tracks the measured zero-drop mAPs.

use crate::detector::quality::QualityProfile;
use crate::device::DetectorModelId;

/// Staleness decay timescale τ (seconds). A dropped frame reuses boxes
/// captured `age` seconds earlier; its delivered quality is scaled by
/// `max(0, 1 − age/τ)`. Calibrated against the paper's §II-B data point:
/// λ = 14 FPS on one 2.5-FPS stick drops ≈ 82 % of frames (mean stale
/// age ≈ 0.16 s) and lands at mAP 66.1 % from a 86.9 % baseline — a
/// 0.76× factor, giving τ ≈ 0.7 s.
pub const STALENESS_TAU: f64 = 0.7;

/// Quality multiplier of a detection result reused `age_seconds` after
/// its source frame was captured.
pub fn staleness_factor(age_seconds: f64) -> f64 {
    (1.0 - age_seconds.max(0.0) / STALENESS_TAU).max(0.0)
}

/// One rung of the ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct Rung {
    pub name: String,
    /// Relative service rate of the rung's model, in any consistent
    /// unit — only ratios matter ([`ModelLadder::speedups`] normalises
    /// so rung 0 reads 1.0).
    pub speedup: f64,
    /// Intrinsic zero-drop quality (mAP proxy, 0..1).
    pub quality: f64,
}

/// The Pareto frontier, rung 0 = highest quality, ascending speedup.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelLadder {
    pub rungs: Vec<Rung>,
}

/// Analytic zero-drop mAP proxy for a profile: recall × label accuracy
/// × a false-positive precision penalty (assuming the paper videos'
/// ≈ 4 ground-truth objects per frame). Tracks the measured calibration
/// mAPs to within a few points — good enough to order rungs and weight
/// delivered quality; it is NOT an mAP measurement.
pub fn quality_estimate(profile: &QualityProfile) -> f64 {
    const OBJECTS_PER_FRAME: f64 = 4.0;
    let recall = 1.0 - profile.miss_rate;
    let label_acc = 1.0 - profile.confusion_rate;
    let fp_penalty = 1.0 - 0.25 * (profile.fp_per_frame / OBJECTS_PER_FRAME).min(1.0);
    (recall * label_acc * fp_penalty).clamp(0.0, 1.0)
}

impl ModelLadder {
    /// Build the ladder for a video domain (matched by preset name,
    /// `eth_sunnyday` / `adl_rundle6`) from the calibrated full and tiny
    /// profiles of both paper models. Rung 0 is whatever survives Pareto
    /// pruning as the most accurate variant (YOLOv3-full on both paper
    /// videos), and [`ModelLadder::speedups`] normalises to it — the
    /// internal NCS2 reference rates cancel out.
    pub fn from_profiles(video: &str) -> ModelLadder {
        let mut candidates = Vec::new();
        for model in [DetectorModelId::Yolov3, DetectorModelId::Ssd300] {
            let rate = crate::device::DeviceKind::Ncs2.service_rate(model);
            let full = QualityProfile::calibrated(model, video);
            candidates.push(Rung {
                name: full.name.clone(),
                speedup: rate,
                quality: quality_estimate(&full),
            });
            let tiny = QualityProfile::tiny(model, video);
            candidates.push(Rung {
                name: tiny.name.clone(),
                speedup: rate * QualityProfile::tiny_speedup(model),
                quality: quality_estimate(&tiny),
            });
        }
        ModelLadder::pareto(candidates)
    }

    /// Keep only the non-dominated candidates (no other rung is at least
    /// as fast *and* strictly better, or faster and at least as good),
    /// sorted by ascending speedup / descending quality.
    pub fn pareto(mut candidates: Vec<Rung>) -> ModelLadder {
        candidates.sort_by(|a, b| {
            a.speedup
                .partial_cmp(&b.speedup)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(
                    b.quality
                        .partial_cmp(&a.quality)
                        .unwrap_or(std::cmp::Ordering::Equal),
                )
        });
        let mut rungs: Vec<Rung> = Vec::new();
        for c in candidates {
            let dominated = rungs.iter().any(|r| {
                (r.speedup >= c.speedup - 1e-12 && r.quality > c.quality + 1e-12)
                    || (r.speedup > c.speedup + 1e-12 && r.quality >= c.quality - 1e-12)
            });
            if dominated {
                continue;
            }
            // A new, faster candidate can retro-dominate slower rungs
            // with no quality edge.
            rungs.retain(|r| {
                !(c.speedup >= r.speedup - 1e-12 && c.quality > r.quality + 1e-12)
                    && !(c.speedup > r.speedup + 1e-12 && c.quality >= r.quality - 1e-12)
            });
            rungs.push(c);
        }
        rungs.sort_by(|a, b| {
            a.speedup
                .partial_cmp(&b.speedup)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        ModelLadder { rungs }
    }

    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rungs.is_empty()
    }

    /// Speedup vector for [`crate::fleet::admission::DegradeMode::ModelSwap`]
    /// (normalised so rung 0 is 1.0).
    pub fn speedups(&self) -> Vec<f64> {
        let base = self.rungs.first().map(|r| r.speedup).unwrap_or(1.0);
        self.rungs.iter().map(|r| r.speedup / base).collect()
    }

    /// Intrinsic quality of `rung` (clamped to the deepest rung; 0.0 for
    /// an empty ladder).
    pub fn quality(&self, rung: usize) -> f64 {
        if self.rungs.is_empty() {
            return 0.0;
        }
        self.rungs[rung.min(self.rungs.len() - 1)].quality
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staleness_decay_matches_paper_anchor() {
        assert_eq!(staleness_factor(0.0), 1.0);
        // §II-B anchor: mean stale age ≈ 0.16 s -> factor ≈ 0.76.
        let f = staleness_factor(0.164);
        assert!((f - 0.766).abs() < 0.02, "factor {f}");
        // Decay hits zero at τ and stays there.
        assert_eq!(staleness_factor(STALENESS_TAU), 0.0);
        assert_eq!(staleness_factor(10.0), 0.0);
        assert_eq!(staleness_factor(-1.0), 1.0);
    }

    #[test]
    fn staleness_decay_is_monotone_and_clamped() {
        // The gate's tracker proxy divides reuse ages by its stretch
        // factor, so the decay must be monotone non-increasing over the
        // whole age axis (negative ages clamp to fresh, ages past τ to
        // zero) — otherwise a longer skip run could *gain* quality.
        let ages: Vec<f64> = (0..=40).map(|i| -0.2 + i as f64 * 0.03).collect();
        for w in ages.windows(2) {
            let (a, b) = (staleness_factor(w[0]), staleness_factor(w[1]));
            assert!(b <= a + 1e-12, "ages {:?}: {a} -> {b}", w);
            assert!((0.0..=1.0).contains(&a), "age {}: {a}", w[0]);
        }
        // Strictly decreasing inside (0, τ).
        assert!(staleness_factor(0.2) > staleness_factor(0.4));
    }

    #[test]
    fn quality_estimate_tracks_calibrated_maps() {
        // The proxy must land near the paper baselines the profiles were
        // calibrated to (± 5 points).
        let cases = [
            (DetectorModelId::Yolov3, "eth_sunnyday", 0.869),
            (DetectorModelId::Ssd300, "eth_sunnyday", 0.745),
            (DetectorModelId::Yolov3, "adl_rundle6", 0.625),
            (DetectorModelId::Ssd300, "adl_rundle6", 0.544),
        ];
        for (model, video, map) in cases {
            let q = quality_estimate(&QualityProfile::calibrated(model, video));
            assert!(
                (q - map).abs() < 0.05,
                "{model:?}@{video}: proxy {q:.3} vs paper {map}"
            );
        }
    }

    #[test]
    fn eth_ladder_is_a_strict_frontier() {
        let ladder = ModelLadder::from_profiles("eth_sunnyday");
        assert!(ladder.len() >= 2, "ladder {:?}", ladder.rungs);
        // Rung 0 is the full base model.
        assert_eq!(ladder.rungs[0].name, "yolov3@eth");
        assert!((ladder.speedups()[0] - 1.0).abs() < 1e-12);
        // Strictly faster and strictly worse, rung by rung.
        for w in ladder.rungs.windows(2) {
            assert!(w[1].speedup > w[0].speedup + 1e-9, "{:?}", ladder.rungs);
            assert!(w[1].quality < w[0].quality - 1e-9, "{:?}", ladder.rungs);
        }
        // SSD300-full is dominated by YOLOv3-full on NCS2 (slower and
        // less accurate) and must be pruned.
        assert!(
            !ladder.rungs.iter().any(|r| r.name == "ssd300@eth"),
            "{:?}",
            ladder.rungs
        );
    }

    #[test]
    fn pareto_prunes_dominated_candidates() {
        let ladder = ModelLadder::pareto(vec![
            Rung { name: "a".into(), speedup: 1.0, quality: 0.9 },
            Rung { name: "dominated".into(), speedup: 0.9, quality: 0.7 },
            Rung { name: "b".into(), speedup: 2.0, quality: 0.6 },
            Rung { name: "also-dominated".into(), speedup: 2.0, quality: 0.5 },
        ]);
        let names: Vec<&str> = ladder.rungs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
        assert_eq!(ladder.speedups(), vec![1.0, 2.0]);
        assert!((ladder.quality(0) - 0.9).abs() < 1e-12);
        assert!((ladder.quality(7) - 0.6).abs() < 1e-12); // clamps deep
    }

    #[test]
    fn empty_ladder_is_harmless() {
        let ladder = ModelLadder::pareto(Vec::new());
        assert!(ladder.is_empty());
        assert_eq!(ladder.quality(0), 0.0);
        assert!(ladder.speedups().is_empty());
    }
}
