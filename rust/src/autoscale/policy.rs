//! The closed-loop policies: a generalised-nselect **device controller**
//! and a per-stream **quality (ladder) controller**, packaged as one
//! [`AutoscaleController`] implementing
//! [`crate::fleet::sim::FleetController`].
//!
//! ## Device controller
//!
//! §III-B picks the parallelism degree once, offline, from the band
//! `n ∈ [⌈10/μ⌉, ⌈λ/μ⌉]`. Generalised to a fleet, the band becomes a
//! pool-capacity target: Σμ should sit inside
//! `[Σ_s floor(λ_s), Σ_s λ_s] / util`, where `floor(λ)` relaxes to the
//! 10-FPS perception floor for fast streams (λ > 12) and stays λ for
//! slow ones ([`capacity_band`]). The controller attaches a template
//! replica when the observed worst-stream p99 or excess drop rate
//! breaches its bound (or capacity is below the band floor), and
//! detaches one only when signals are healthy *and* the remaining
//! capacity still clears the floor with a hysteresis margin — the
//! asymmetric thresholds plus a cooldown between actions are what
//! prevent flapping.
//!
//! ## Quality controller
//!
//! Per stream, walks the model ladder from observed signals: a p99 or
//! drop breach steps the stream one rung down (faster, lower mAP)
//! before any extra stride would be needed; sustained health steps it
//! back up — but only when the restored rung would not reintroduce a
//! stride, so it never fights the admission-computed operating point.
//! A step-up that breaches again within two cooldowns doubles the
//! stream's re-probe delay (bounded), damping limit-cycle flapping
//! under stationary overload.

use crate::control::ControlAction;
use crate::coordinator::nselect;
use crate::coordinator::nselect::NRange;
use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::registry::FleetRegistry;
use crate::fleet::sim::FleetController;
use crate::fleet::stream::StreamId;
use crate::types::OutputRecord;

use crate::autoscale::ladder::ModelLadder;
use crate::autoscale::signals::FleetSignals;

/// Per-stream demand floor for the capacity band: the §III-B relaxation
/// (10-FPS perception floor applies only to streams faster than the
/// 12-FPS threshold).
pub fn floor_demand(lambda: f64) -> f64 {
    if lambda > nselect::RELAXATION_THRESHOLD_FPS {
        nselect::PERCEPTION_FLOOR_FPS
    } else {
        lambda
    }
}

/// Generalised §III-B band in pool-capacity terms:
/// `[Σ floor(λ_s), Σ λ_s] / util`.
pub fn capacity_band(demands: &[f64], util: f64) -> (f64, f64) {
    let u = util.max(1e-6);
    let hi: f64 = demands.iter().sum::<f64>() / u;
    let lo: f64 = demands.iter().map(|&d| floor_demand(d)).sum::<f64>() / u;
    (lo.min(hi), hi)
}

/// The same band as a device count for homogeneous `mu`-rate replicas —
/// the literal generalised nselect `n ∈ [⌈Σfloor(λ)/μ⌉, ⌈Σλ/μ⌉]`
/// (utilisation-adjusted).
pub fn device_band(demands: &[f64], mu: f64, util: f64) -> NRange {
    let (lo, hi) = capacity_band(demands, util);
    let m = mu.max(1e-9);
    let hi_n = ((hi / m).ceil() as usize).max(1);
    let lo_n = ((lo / m).ceil() as usize).max(1).min(hi_n);
    NRange { lo: lo_n, hi: hi_n }
}

/// Autoscale policy parameters.
///
/// Serialisable: [`crate::control::wire::autoscale_config_to_json`]
/// round-trips the whole configuration (ladder included), so a
/// coordinator can ship it to a remote shard in the session handshake.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// Sliding signal window (seconds of fleet time).
    pub signal_window: f64,
    /// Control-loop period (seconds).
    pub tick: f64,
    /// Worst-stream p99 output-latency bound (seconds).
    pub p99_bound: f64,
    /// Excess drop rate (beyond admission-mandated strides) that counts
    /// as a breach.
    pub max_drop_rate: f64,
    /// Minimum time between actions of the same controller (seconds).
    pub cooldown: f64,
    /// Scale-down margin: detach only if the remaining capacity still
    /// clears the band floor by this factor.
    pub hysteresis: f64,
    /// Health threshold for recovery steps, as a fraction of
    /// `p99_bound` (step up / detach only when p99 is below it).
    pub recovery_frac: f64,
    pub min_devices: usize,
    pub max_devices: usize,
    /// Template replica the device controller attaches on scale-up.
    pub device_kind: DeviceKind,
    pub device_model: DetectorModelId,
    /// Template replica service rate μ (frames/second).
    pub device_rate: f64,
    /// Model ladder for the quality controller; `None` scales devices
    /// only.
    pub ladder: Option<ModelLadder>,
    /// Pool-capacity fraction admission may commit (mirrors
    /// [`AdmissionPolicy::target_utilization`]).
    pub target_utilization: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            signal_window: 4.0,
            tick: 1.0,
            p99_bound: 1.5,
            max_drop_rate: 0.05,
            cooldown: 5.0,
            hysteresis: 1.25,
            recovery_frac: 0.4,
            min_devices: 1,
            max_devices: 16,
            device_kind: DeviceKind::Ncs2,
            device_model: DetectorModelId::Yolov3,
            device_rate: 2.5,
            ladder: None,
            target_utilization: 0.95,
        }
    }
}

impl AutoscaleConfig {
    pub fn with_ladder(mut self, ladder: ModelLadder) -> Self {
        self.ladder = Some(ladder);
        self
    }

    /// The admission policy this configuration implies: enforcing, with
    /// model-swap degradation when a ladder is present.
    pub fn admission(&self) -> AdmissionPolicy {
        let mut p = match &self.ladder {
            Some(l) if l.len() > 1 => AdmissionPolicy::with_ladder(l.speedups()),
            _ => AdmissionPolicy::default(),
        };
        p.target_utilization = self.target_utilization;
        p
    }
}

/// The closed-loop controller: windowed signals in, `ControlAction`s
/// out, on every engine tick.
pub struct AutoscaleController {
    pub cfg: AutoscaleConfig,
    signals: FleetSignals,
    last_device_action: f64,
    next_replica: usize,
    /// Forecast Σλ one horizon ahead (FPS of offered load), armed by the
    /// shard runner when the forecaster's confidence band is tight. Only
    /// a prediction *above* the current demand band moves the controller
    /// — see [`AutoscaleController::device_control`].
    forecast_hint: Option<f64>,
    // Per-stream quality-controller state (indexed by StreamId).
    last_rung_action: Vec<f64>,
    last_step_up: Vec<f64>,
    up_backoff: Vec<f64>,
    /// `(stride, rung)` each stream was last observed at; a change
    /// resets the stream's signal window (regime change).
    last_regime: Vec<(u64, usize)>,
}

impl AutoscaleController {
    pub fn new(cfg: AutoscaleConfig) -> AutoscaleController {
        let window = cfg.signal_window.max(1e-3);
        AutoscaleController {
            cfg,
            signals: FleetSignals::new(window),
            last_device_action: f64::NEG_INFINITY,
            next_replica: 0,
            forecast_hint: None,
            last_rung_action: Vec::new(),
            last_step_up: Vec::new(),
            up_backoff: Vec::new(),
            last_regime: Vec::new(),
        }
    }

    /// Device-controller continuity state: the cooldown clock and the
    /// replica-id counter. This is what distinguishes a *warm* rejoin
    /// from a cold join — see [`crate::shard::autoscale::ScalerState`].
    pub fn device_state(&self) -> (f64, usize) {
        (self.last_device_action, self.next_replica)
    }

    /// Restore continuity state captured by
    /// [`AutoscaleController::device_state`] on a fresh controller.
    pub fn restore_device_state(&mut self, last_device_action: f64, next_replica: usize) {
        self.last_device_action = last_device_action;
        self.next_replica = next_replica;
    }

    /// Arm (or clear) the forecast demand hint for subsequent ticks.
    /// The runner re-arms this each gossip epoch from the shard's
    /// [`crate::forecast::ShardForecast`]; `None` (no forecast, or a
    /// loose confidence band) restores pure reactive control.
    pub fn set_forecast_demand(&mut self, hint: Option<f64>) {
        self.forecast_hint = hint;
    }

    /// Epoch-slice boundary reset for drivers that feed the controller
    /// one sub-run at a time ([`crate::shard::autoscale`]): stream ids
    /// are slice-local and residency changes between slices, so signal
    /// windows and per-stream quality state must not carry across. The
    /// device-action cooldown clock, the replica-id counter, and the
    /// forecast hint *do* persist — a cooldown legitimately spans a
    /// gossip epoch, replica ids must stay fresh across the whole shard
    /// run, and the hint is epoch-scoped state the runner re-arms
    /// itself.
    pub fn begin_slice(&mut self) {
        self.signals = FleetSignals::new(self.cfg.signal_window.max(1e-3));
        self.last_rung_action.clear();
        self.last_step_up.clear();
        self.up_backoff.clear();
        self.last_regime.clear();
    }

    fn ensure_stream(&mut self, sid: StreamId) {
        while self.last_rung_action.len() <= sid {
            self.last_rung_action.push(f64::NEG_INFINITY);
            self.last_step_up.push(f64::NEG_INFINITY);
            self.up_backoff.push(self.cfg.cooldown);
            // Stride 0 is never a real operating point, so the first
            // sight of a stream registers its regime (and clears an
            // at-most-one-tick-old window).
            self.last_regime.push((0, 0));
        }
    }

    /// Drop windows whose stream changed operating point since the last
    /// tick: samples gathered under an old stride/rung (e.g. mandated
    /// drops of a relaxed stride) must not read as a breach of the new
    /// one.
    fn reset_changed_regimes(&mut self, reg: &FleetRegistry, active: &[StreamId]) {
        for &sid in active {
            self.ensure_stream(sid);
            let d = &reg.streams[sid].decision;
            let regime = (d.stride(), d.rung());
            if self.last_regime[sid] != regime {
                self.last_regime[sid] = regime;
                self.signals.stream_mut(sid).clear();
            }
        }
    }

    fn template(&mut self, reg: &FleetRegistry) -> DeviceInstance {
        // Stable-ish replica ids past any initial pool.
        self.next_replica = self.next_replica.max(reg.pool.len());
        let replica = self.next_replica;
        self.next_replica += 1;
        DeviceInstance::with_rate(
            self.cfg.device_kind,
            self.cfg.device_model,
            replica,
            self.cfg.device_rate,
        )
    }

    /// Streams that still generate load: attached, admitted, and not yet
    /// past their last frame.
    fn active_streams(&self, reg: &FleetRegistry) -> Vec<StreamId> {
        reg.streams
            .iter()
            .filter(|s| {
                !s.detached && s.decision.is_admitted() && s.arrived < s.spec.num_frames
            })
            .map(|s| s.id)
            .collect()
    }

    /// Admission-mandated drop fraction across `sids` (what the strides
    /// already promise to drop — not a signal of distress).
    fn mandated_drop_rate(&self, reg: &FleetRegistry, sids: &[StreamId]) -> f64 {
        let mut offered = 0.0;
        let mut kept = 0.0;
        for &sid in sids {
            let s = &reg.streams[sid];
            let lambda = s.spec.demand();
            offered += lambda;
            kept += lambda / s.decision.stride() as f64;
        }
        if offered <= 0.0 {
            0.0
        } else {
            1.0 - kept / offered
        }
    }

    fn device_control(
        &mut self,
        now: f64,
        reg: &FleetRegistry,
        active: &[StreamId],
        breach: bool,
        worst_p99: f64,
    ) -> Option<ControlAction> {
        if now - self.last_device_action < self.cfg.cooldown {
            return None;
        }
        let demands: Vec<f64> = active
            .iter()
            .map(|&sid| reg.streams[sid].spec.demand())
            .collect();
        let (mut cap_lo, mut cap_hi) = capacity_band(&demands, self.cfg.target_utilization);
        if let Some(hint) = self.forecast_hint {
            // Provision toward the predicted band, not the current one —
            // the attach then lands *before* the ramp instead of after
            // the p99 spike it would have caused, and a detach that the
            // forecast says would be regretted within a horizon is
            // blocked by the raised floor. Only a prediction strictly
            // above today's demand ceiling moves anything: a forecast
            // equal to committed load (constant-rate streams) leaves the
            // reactive band bit-identical.
            let predicted = hint / self.cfg.target_utilization.max(1e-6);
            if predicted > cap_hi + 1e-9 {
                cap_lo = cap_lo.max(predicted);
                cap_hi = predicted;
            }
        }
        let capacity = reg.pool.attached_rate();
        let n_attached = reg.pool.devices().iter().filter(|d| d.attached).count();

        if (breach || capacity + 1e-9 < cap_lo)
            && capacity + 1e-9 < cap_hi
            && n_attached < self.cfg.max_devices
        {
            let instance = self.template(reg);
            self.last_device_action = now;
            return Some(ControlAction::AttachDevice(instance));
        }

        if !breach
            && worst_p99 < self.cfg.recovery_frac * self.cfg.p99_bound
            && n_attached > self.cfg.min_devices
        {
            // Victim: the highest-slot attached device; only if what
            // remains still clears the band floor with margin.
            if let Some((dev, victim)) = reg
                .pool
                .devices()
                .iter()
                .enumerate()
                .rev()
                .find(|(_, d)| d.attached)
            {
                let remaining = capacity - victim.instance.rate();
                if remaining + 1e-9 >= cap_lo * self.cfg.hysteresis {
                    self.last_device_action = now;
                    return Some(ControlAction::DetachDevice(dev));
                }
            }
        }
        None
    }

    fn quality_control(
        &mut self,
        now: f64,
        reg: &FleetRegistry,
        active: &[StreamId],
    ) -> Vec<ControlAction> {
        let max_rung = reg.admission.max_rung();
        if max_rung == 0 {
            return Vec::new();
        }
        let mut actions = Vec::new();
        for &sid in active {
            self.ensure_stream(sid);
            let s = &reg.streams[sid];
            let rung = s.decision.rung();
            let stride = s.decision.stride();
            let w = self.signals.stream_mut(sid);
            if w.sample_count(now) == 0 {
                continue;
            }
            let p99 = w.p99(now);
            let drop = w.drop_rate(now);
            let delivered_fps = w.processed_fps(now);
            let mandated = 1.0 - 1.0 / stride as f64;
            let excess_drop = (drop - mandated).max(0.0);
            let overloaded = p99 > self.cfg.p99_bound || excess_drop > self.cfg.max_drop_rate;
            // Step back up only when the stream is demonstrably keeping
            // up at its current operating point: low tail latency, no
            // excess drops, and delivered FPS near the kept rate λ/stride.
            let kept_rate = s.spec.demand() / stride as f64;
            let healthy = p99 < self.cfg.recovery_frac * self.cfg.p99_bound
                && excess_drop <= self.cfg.max_drop_rate * 0.5
                && delivered_fps + 1e-9 >= 0.7 * kept_rate;

            if overloaded && rung < max_rung {
                if now - self.last_rung_action[sid] < self.cfg.cooldown {
                    continue;
                }
                // A breach shortly after a probe upward: back off the
                // next probe exponentially (bounded) — anti-flapping. A
                // breach long after the last probe is a fresh overload
                // episode, not a flap: the penalty resets so the
                // documented one-cooldown recovery holds per episode.
                if now - self.last_step_up[sid] < 2.0 * self.cfg.cooldown {
                    self.up_backoff[sid] =
                        (self.up_backoff[sid] * 2.0).min(16.0 * self.cfg.cooldown);
                } else {
                    self.up_backoff[sid] = self.cfg.cooldown;
                }
                self.last_rung_action[sid] = now;
                actions.push(ControlAction::SwapModel { stream: sid, rung: rung + 1 });
            } else if healthy && rung > 0 {
                if now - self.last_rung_action[sid] < self.up_backoff[sid] {
                    continue;
                }
                // Never step up into a stride: the restored rung must
                // still fit the stream's share at full frame rate.
                let Some(share) = s.decision.share() else {
                    continue;
                };
                let target = reg
                    .admission
                    .decision_at_rung(s.spec.demand(), share, rung - 1);
                if target.stride() > 1 {
                    continue;
                }
                self.last_rung_action[sid] = now;
                self.last_step_up[sid] = now;
                if rung == 1 {
                    // Fully recovered: the next episode probes at the
                    // base cadence again.
                    self.up_backoff[sid] = self.cfg.cooldown;
                }
                actions.push(ControlAction::SwapModel { stream: sid, rung: rung - 1 });
            }
        }
        actions
    }
}

impl FleetController for AutoscaleController {
    fn interval(&self) -> f64 {
        self.cfg.tick.max(1e-3)
    }

    fn observe(&mut self, now: f64, sid: StreamId, record: &OutputRecord) {
        self.signals.observe(now, sid, record);
    }

    fn act(&mut self, now: f64, reg: &FleetRegistry) -> Vec<ControlAction> {
        let active = self.active_streams(reg);
        if active.is_empty() {
            return Vec::new();
        }
        self.reset_changed_regimes(reg, &active);
        let worst_p99 = self.signals.worst_p99(now, &active);
        let (dropped, total) = self.signals.drop_counts(now, &active);
        let drop_rate = if total == 0 {
            0.0
        } else {
            dropped as f64 / total as f64
        };
        let mandated = self.mandated_drop_rate(reg, &active);
        let excess_drop = (drop_rate - mandated).max(0.0);
        let breach =
            worst_p99 > self.cfg.p99_bound || excess_drop > self.cfg.max_drop_rate;

        let mut actions = Vec::new();
        if let Some(a) = self.device_control(now, reg, &active, breach, worst_p99) {
            actions.push(a);
        }
        actions.extend(self.quality_control(now, reg, &active));
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_demand_applies_paper_relaxation() {
        assert_eq!(floor_demand(5.0), 5.0); // slow stream: no relaxation
        assert_eq!(floor_demand(12.0), 12.0); // at threshold: none
        assert_eq!(floor_demand(14.0), 10.0); // fast stream: 10-FPS floor
        assert_eq!(floor_demand(30.0), 10.0);
    }

    #[test]
    fn capacity_band_generalises_nselect() {
        // One 14-FPS stream, μ=2.5, util=1: the paper's §III-B example —
        // n ∈ [4, 6].
        let band = device_band(&[14.0], 2.5, 1.0);
        assert_eq!((band.lo, band.hi), (4, 6));
        // Slow streams collapse the band to the conservative point.
        let band = device_band(&[5.0, 5.0], 2.5, 1.0);
        assert_eq!((band.lo, band.hi), (4, 4));
        // Mixed fleet: floors add per stream.
        let (lo, hi) = capacity_band(&[14.0, 5.0], 1.0);
        assert!((lo - 15.0).abs() < 1e-12);
        assert!((hi - 19.0).abs() < 1e-12);
        // Utilisation headroom scales the band up.
        let (lo95, hi95) = capacity_band(&[14.0, 5.0], 0.95);
        assert!(lo95 > lo && hi95 > hi);
    }

    #[test]
    fn zero_device_pool_scales_up_and_respects_cooldown() {
        // A shard whose pool is empty (every device detached or a cold
        // start) must attach toward the band floor immediately — no
        // signal samples are needed, the capacity shortfall alone drives
        // the action — and then hold its cooldown.
        let cfg = AutoscaleConfig {
            target_utilization: 1.0,
            ..AutoscaleConfig::default()
        };
        let mut ctl = AutoscaleController::new(cfg.clone());
        let mut reg = crate::fleet::registry::FleetRegistry::new(
            Vec::new(),
            AdmissionPolicy::admit_all(),
        );
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("s0", 5.0, 100), 0.0);
        let actions = FleetController::act(&mut ctl, 0.0, &reg);
        assert_eq!(actions.len(), 1, "{actions:?}");
        assert!(matches!(actions[0], ControlAction::AttachDevice(_)));
        // Within the cooldown the controller stays quiet even though the
        // (unchanged) pool is still below the floor...
        assert!(FleetController::act(&mut ctl, cfg.cooldown * 0.5, &reg).is_empty());
        // ...and acts again once the cooldown has elapsed.
        let again = FleetController::act(&mut ctl, cfg.cooldown + 0.1, &reg);
        assert_eq!(again.len(), 1, "{again:?}");
        assert!(matches!(again[0], ControlAction::AttachDevice(_)));
    }

    #[test]
    fn band_exactly_met_takes_no_action() {
        // Σμ exactly equal to the band (lo == hi == 10): neither an
        // attach (capacity is not strictly below the ceiling) nor a
        // detach (the survivor capacity would not clear the floor with
        // the hysteresis margin) — the controller must not flap at the
        // fixed point.
        let cfg = AutoscaleConfig {
            target_utilization: 1.0,
            ..AutoscaleConfig::default()
        };
        let mut ctl = AutoscaleController::new(cfg.clone());
        let devices: Vec<DeviceInstance> = (0..4)
            .map(|i| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, 2.5)
            })
            .collect();
        let mut reg = crate::fleet::registry::FleetRegistry::new(
            devices,
            AdmissionPolicy::admit_all(),
        );
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("a", 5.0, 1000), 0.0);
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("b", 5.0, 1000), 0.0);
        let (lo, hi) = capacity_band(&[5.0, 5.0], cfg.target_utilization);
        assert_eq!((lo, hi), (10.0, 10.0));
        for t in [0.0, 6.0, 12.0, 30.0] {
            assert!(
                FleetController::act(&mut ctl, t, &reg).is_empty(),
                "unexpected action at t={t}"
            );
        }
    }

    #[test]
    fn scale_up_denied_at_pool_capacity_cap() {
        // Capacity far below the floor but the pool is already at
        // max_devices: the controller must deny the attach (and must not
        // detach either — the shard is starved, not over-provisioned).
        let cfg = AutoscaleConfig {
            target_utilization: 1.0,
            max_devices: 2,
            ..AutoscaleConfig::default()
        };
        let mut ctl = AutoscaleController::new(cfg);
        let devices: Vec<DeviceInstance> = (0..2)
            .map(|i| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, 2.5)
            })
            .collect();
        let mut reg = crate::fleet::registry::FleetRegistry::new(
            devices,
            AdmissionPolicy::admit_all(),
        );
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("s0", 10.0, 1000), 0.0);
        for t in [0.0, 10.0, 20.0] {
            assert!(
                FleetController::act(&mut ctl, t, &reg).is_empty(),
                "actions at t={t} despite max_devices cap"
            );
        }
    }

    #[test]
    fn begin_slice_keeps_cooldown_clock_and_replica_counter() {
        // The slice reset clears signal/quality state but must NOT clear
        // the device cooldown: an attach late in one epoch still blocks
        // an attach early in the next.
        let cfg = AutoscaleConfig {
            target_utilization: 1.0,
            ..AutoscaleConfig::default()
        };
        let mut ctl = AutoscaleController::new(cfg.clone());
        let mut reg = crate::fleet::registry::FleetRegistry::new(
            Vec::new(),
            AdmissionPolicy::admit_all(),
        );
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("s0", 5.0, 100), 0.0);
        let first = FleetController::act(&mut ctl, 9.0, &reg);
        assert_eq!(first.len(), 1);
        ctl.begin_slice();
        // t=10 is a new gossip epoch but only 1 s after the attach: the
        // cooldown (default 5 s) spans the epoch boundary.
        assert!(FleetController::act(&mut ctl, 10.0, &reg).is_empty());
        let later = FleetController::act(&mut ctl, 9.0 + cfg.cooldown + 0.1, &reg);
        assert_eq!(later.len(), 1, "{later:?}");
        // Replica ids keep advancing across the slice boundary.
        let ids: Vec<usize> = [&first[0], &later[0]]
            .iter()
            .map(|a| match a {
                ControlAction::AttachDevice(d) => d.replica,
                other => panic!("expected attach, got {other:?}"),
            })
            .collect();
        assert!(ids[1] > ids[0], "replica ids {ids:?}");
    }

    #[test]
    fn forecast_hint_attaches_ahead_of_the_ramp_and_blocks_detach() {
        let cfg = AutoscaleConfig {
            target_utilization: 1.0,
            ..AutoscaleConfig::default()
        };
        // Band exactly met (2 × 2.5 = Σλ = 5): reactively quiescent.
        let mut ctl = AutoscaleController::new(cfg.clone());
        let devices: Vec<DeviceInstance> = (0..2)
            .map(|i| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, 2.5)
            })
            .collect();
        let mut reg = crate::fleet::registry::FleetRegistry::new(
            devices,
            AdmissionPolicy::admit_all(),
        );
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("a", 5.0, 10_000), 0.0);
        assert!(FleetController::act(&mut ctl, 0.0, &reg).is_empty());
        // A tight forecast of 9 FPS raises the provisioning floor: the
        // attach fires now, one cooldown ahead of the ramp, with no
        // breach signal at all.
        ctl.set_forecast_demand(Some(9.0));
        let acted = FleetController::act(&mut ctl, cfg.cooldown + 0.1, &reg);
        assert_eq!(acted.len(), 1, "{acted:?}");
        assert!(matches!(acted[0], ControlAction::AttachDevice(_)));
        // A forecast equal to committed demand is a no-op: clearing back
        // to reactive control stays quiescent too.
        let mut ctl = AutoscaleController::new(cfg.clone());
        ctl.set_forecast_demand(Some(5.0));
        assert!(FleetController::act(&mut ctl, 0.0, &reg).is_empty());
        ctl.set_forecast_demand(None);
        assert!(FleetController::act(&mut ctl, cfg.cooldown + 0.1, &reg).is_empty());

        // Over-provisioned pool (4 × 2.5 = 10 against Σλ = 5): reactive
        // control sheds the idle replica…
        let devices: Vec<DeviceInstance> = (0..4)
            .map(|i| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, 2.5)
            })
            .collect();
        let mut reg = crate::fleet::registry::FleetRegistry::new(
            devices,
            AdmissionPolicy::admit_all(),
        );
        reg.attach_stream(crate::fleet::stream::StreamSpec::new("a", 5.0, 10_000), 0.0);
        let mut ctl = AutoscaleController::new(cfg.clone());
        let acted = FleetController::act(&mut ctl, 0.0, &reg);
        assert!(
            matches!(acted.as_slice(), [ControlAction::DetachDevice(_)]),
            "{acted:?}"
        );
        // …but a forecast of 8 FPS says the capacity is about to be
        // needed: the detach is blocked (and 10 ≥ 8, so no attach
        // either).
        let mut ctl = AutoscaleController::new(cfg);
        ctl.set_forecast_demand(Some(8.0));
        assert!(FleetController::act(&mut ctl, 0.0, &reg).is_empty());
    }

    #[test]
    fn config_admission_reflects_ladder() {
        let plain = AutoscaleConfig::default().admission();
        assert_eq!(plain.max_rung(), 0);
        let ladder = ModelLadder::pareto(vec![
            crate::autoscale::ladder::Rung { name: "full".into(), speedup: 1.0, quality: 0.86 },
            crate::autoscale::ladder::Rung { name: "tiny".into(), speedup: 2.6, quality: 0.69 },
        ]);
        let with = AutoscaleConfig::default().with_ladder(ladder).admission();
        assert_eq!(with.max_rung(), 1);
        assert!((with.rung_speedup(1) - 2.6).abs() < 1e-12);
    }
}
