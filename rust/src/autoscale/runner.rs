//! Drivers that run the closed loop end to end.
//!
//! * [`run_autoscale_sim`] — virtual time, deterministic: plugs an
//!   [`AutoscaleController`] into [`crate::fleet::sim::run_fleet_with`]
//!   and returns the report plus the control log and derived telemetry
//!   (device-count timeline, action counts). This is the engine behind
//!   `experiments::autoscale` and the integration tests.
//! * [`run_autoscale_serve`] — wall clock: the same feedback law at
//!   **epoch granularity** over [`crate::fleet::serve::serve_fleet`].
//!   Each epoch serves a slice of every stream's clip with the current
//!   worker count and (fleet-wide) ladder rung; between epochs the
//!   controller reads the epoch's report and adjusts. Per-job model
//!   switching inside a shared wall-clock worker is deliberately out of
//!   scope here (it belongs with stream sharding); the rung is uniform
//!   per epoch.

use anyhow::Result;

use crate::control::{ControlAction, ControlOrigin, ControlRecord, EventLog};
use crate::detector::Detector;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::serve::{serve_fleet, FleetServeConfig};
use crate::fleet::sim::{run_fleet_with, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::fleet::FleetReport;
use crate::video::Clip;

use crate::autoscale::policy::{AutoscaleConfig, AutoscaleController};

/// Everything a closed-loop virtual-time run produces.
pub struct AutoscaleOutcome {
    pub report: FleetReport,
    pub control_log: Vec<ControlRecord>,
    /// `(time, attached device count)` after every device action,
    /// starting with `(0, initial)`.
    pub device_timeline: Vec<(f64, usize)>,
    pub device_actions: usize,
    pub rung_actions: usize,
}

impl AutoscaleOutcome {
    /// Attached device count at fleet time `t`.
    pub fn devices_at(&self, t: f64) -> usize {
        crate::util::stats::timeline_at(&self.device_timeline, t)
            .or_else(|| self.device_timeline.first().map(|&(_, n)| n))
            .unwrap_or(0)
    }

    /// Final attached device count.
    pub fn final_devices(&self) -> usize {
        self.device_timeline.last().map(|&(_, n)| n).unwrap_or(0)
    }

    /// Controller (non-scripted) device actions only.
    pub fn controller_device_actions(&self) -> usize {
        self.control_log
            .iter()
            .filter(|r| {
                r.origin == ControlOrigin::Controller
                    && matches!(
                        r.action,
                        ControlAction::AttachDevice(_) | ControlAction::DetachDevice(_)
                    )
            })
            .count()
    }

    /// The run's control log as a serialisable wire log.
    pub fn wire_log(&self) -> EventLog {
        EventLog::from_records(&self.control_log)
    }
}

/// Run `scenario` under a fresh [`AutoscaleController`] built from
/// `cfg`. The scenario's admission policy should normally come from
/// [`AutoscaleConfig::admission`] so ladder speedups agree; this is not
/// enforced (experiments deliberately mix them for baselines).
pub fn run_autoscale_sim(scenario: &Scenario, cfg: &AutoscaleConfig) -> AutoscaleOutcome {
    let mut controller = AutoscaleController::new(cfg.clone());
    let out = run_fleet_with(scenario, Some(&mut controller));

    let mut devices = scenario.devices.len();
    let mut device_timeline = vec![(0.0, devices)];
    let mut device_actions = 0;
    let mut rung_actions = 0;
    for r in &out.control_log {
        match &r.action {
            ControlAction::AttachDevice(_) => {
                devices += 1;
                device_timeline.push((r.at, devices));
                device_actions += 1;
            }
            ControlAction::DetachDevice(_) => {
                devices = devices.saturating_sub(1);
                device_timeline.push((r.at, devices));
                device_actions += 1;
            }
            ControlAction::SwapModel { .. } => rung_actions += 1,
            _ => {}
        }
    }

    AutoscaleOutcome {
        report: out.report,
        control_log: out.control_log,
        device_timeline,
        device_actions,
        rung_actions,
    }
}

/// One wall-clock control epoch's observed state and applied knobs.
#[derive(Debug, Clone)]
pub struct EpochPoint {
    pub epoch: usize,
    /// Workers serving this epoch.
    pub workers: usize,
    /// Fleet-wide ladder rung this epoch (0 = full model).
    pub rung: usize,
    /// Worst per-stream p99 output latency observed (seconds).
    pub p99: f64,
    pub drop_rate: f64,
    pub processed: u64,
    pub frames: u64,
}

/// Wall-clock closed loop at epoch granularity: serve `epoch_frames` of
/// every stream per epoch, read the epoch report, adjust workers and the
/// fleet-wide rung for the next epoch. `factory(worker, rung)` builds a
/// detector for the given ladder rung (rung 0 = full model).
pub fn run_autoscale_serve<F>(
    streams: &[(&Clip, StreamSpec)],
    cfg: &AutoscaleConfig,
    initial_workers: usize,
    epoch_frames: u64,
    epochs: usize,
    factory: F,
) -> Result<Vec<EpochPoint>>
where
    F: Fn(usize, usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    assert!(epoch_frames > 0 && epochs > 0);
    let max_rung = cfg.ladder.as_ref().map(|l| l.len().saturating_sub(1)).unwrap_or(0);
    let mut workers = initial_workers.clamp(cfg.min_devices.max(1), cfg.max_devices.max(1));
    let mut rung = 0usize;
    let mut points = Vec::with_capacity(epochs);

    for epoch in 0..epochs {
        // Slice this epoch's frames out of every stream's clip.
        let mut epoch_clips: Vec<Clip> = Vec::with_capacity(streams.len());
        let mut epoch_specs: Vec<StreamSpec> = Vec::with_capacity(streams.len());
        for (clip, spec) in streams {
            let total = spec.num_frames.min(clip.len() as u64);
            let start = (epoch as u64 * epoch_frames).min(total);
            let end = (start + epoch_frames).min(total);
            epoch_clips.push(Clip {
                spec: clip.spec.clone(),
                frames: clip.frames[start as usize..end as usize].to_vec(),
            });
            let mut s = spec.clone();
            s.num_frames = end - start;
            epoch_specs.push(s);
        }
        let pairs: Vec<(&Clip, StreamSpec)> = epoch_clips
            .iter()
            .zip(epoch_specs.iter().cloned())
            .collect();
        if pairs.iter().all(|(c, _)| c.is_empty()) {
            break;
        }

        let serve_cfg = FleetServeConfig {
            admission: AdmissionPolicy::admit_all(),
            device_rates: vec![cfg.device_rate; workers],
            paced: true,
            gate: None,
        };
        let rung_now = rung;
        let report = serve_fleet(&pairs, &serve_cfg, |w| factory(w, rung_now))?;

        let mut p99 = 0.0f64;
        for s in report.streams.iter() {
            p99 = p99.max(s.metrics.latency.p99());
        }
        let drop_rate = report.drop_rate();
        points.push(EpochPoint {
            epoch,
            workers,
            rung,
            p99,
            drop_rate,
            processed: report.total_processed(),
            frames: report.total_frames(),
        });

        // Epoch-granularity feedback (cooldown is implicit: one action
        // per controller per epoch).
        let breach = p99 > cfg.p99_bound || drop_rate > cfg.max_drop_rate;
        let healthy = p99 < cfg.recovery_frac * cfg.p99_bound
            && drop_rate <= cfg.max_drop_rate * 0.5;
        if breach {
            if rung < max_rung {
                rung += 1;
            } else if workers < cfg.max_devices {
                workers += 1;
            }
        } else if healthy {
            if rung > 0 {
                rung -= 1;
            } else if workers > cfg.min_devices.max(1) {
                workers -= 1;
            }
        }
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
    use crate::types::{Detection, Frame};
    use crate::video::{generate, presets};
    use std::time::Duration;

    fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
        rates
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r)
            })
            .collect()
    }

    #[test]
    fn sim_runner_collects_device_timeline() {
        // Under-provisioned stationary load: 4 × 5-FPS streams (Σλ = 20)
        // on 2 × 2.5-FPS devices. The controller must attach toward the
        // band ⌈20 / (2.5·0.95)⌉ = 9 devices, one per cooldown.
        let cfg = AutoscaleConfig {
            cooldown: 5.0,
            max_devices: 12,
            ..AutoscaleConfig::default()
        };
        let streams: Vec<StreamSpec> = (0..4)
            .map(|i| StreamSpec::new(&format!("s{i}"), 5.0, 600).with_window(4))
            .collect();
        let scenario = Scenario::new(devices(&[2.5, 2.5]), streams)
            .with_admission(cfg.admission())
            .with_seed(3);
        let out = run_autoscale_sim(&scenario, &cfg);
        assert_eq!(out.device_timeline[0], (0.0, 2));
        assert_eq!(out.final_devices(), 9, "timeline {:?}", out.device_timeline);
        assert_eq!(out.device_actions, 7);
        assert_eq!(out.controller_device_actions(), 7);
        // Timeline lookup is monotone.
        assert_eq!(out.devices_at(0.0), 2);
        assert!(out.devices_at(30.0) > out.devices_at(2.0));
        // Everything the streams offered is eventually near-fully served.
        let total = out.report.total_frames();
        let processed = out.report.total_processed();
        assert!(
            processed as f64 > total as f64 * 0.55,
            "processed {processed}/{total}"
        );
    }

    /// Ground-truth echo with a rung-dependent delay.
    struct RungEcho {
        delay: Duration,
    }

    impl Detector for RungEcho {
        fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
            std::thread::sleep(self.delay);
            frame
                .ground_truth
                .iter()
                .map(|gt| Detection { bbox: gt.bbox, class_id: gt.class_id, score: 0.9 })
                .collect()
        }
        fn label(&self) -> String {
            "rung-echo".into()
        }
    }

    #[test]
    fn serve_runner_steps_down_ladder_under_overload() {
        // 2 × 25-FPS streams against one worker whose full model takes
        // 25 ms/frame (≈ 40 FPS capacity < 50 offered) and whose tiny
        // rung takes 5 ms. The epoch loop must step the rung down after
        // the overloaded first epoch and restore it once healthy.
        let clips: Vec<Clip> = (0..2)
            .map(|i| generate(&presets::tiny_clip(32, 60, 25.0, 50 + i), None))
            .collect();
        let streams: Vec<(&Clip, StreamSpec)> = clips
            .iter()
            .enumerate()
            .map(|(i, c)| {
                (c, StreamSpec::new(&format!("s{i}"), 25.0, 60).with_window(2))
            })
            .collect();
        let ladder = crate::autoscale::ladder::ModelLadder::pareto(vec![
            crate::autoscale::ladder::Rung { name: "full".into(), speedup: 1.0, quality: 0.86 },
            crate::autoscale::ladder::Rung { name: "tiny".into(), speedup: 5.0, quality: 0.6 },
        ]);
        let cfg = AutoscaleConfig {
            p99_bound: 0.25,
            max_drop_rate: 0.05,
            device_rate: 40.0,
            max_devices: 2,
            ..AutoscaleConfig::default()
        }
        .with_ladder(ladder);
        let points = run_autoscale_serve(&streams, &cfg, 1, 20, 3, |_, rung| {
            Ok(Box::new(RungEcho {
                delay: Duration::from_millis(if rung == 0 { 25 } else { 5 }),
            }) as Box<dyn Detector>)
        })
        .expect("serve loop");
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].rung, 0);
        // First epoch is overloaded (40 FPS capacity vs 50 offered).
        assert!(
            points[0].drop_rate > 0.05 || points[0].p99 > 0.25,
            "{:?}",
            points[0]
        );
        // The loop reacts: epoch 1 runs one rung down, with 5× capacity
        // headroom it serves cleanly...
        assert_eq!(points[1].rung, 1, "{points:?}");
        assert!(
            points[1].drop_rate < points[0].drop_rate,
            "{points:?}"
        );
        // ...and the healthy epoch restores the full model.
        assert_eq!(points[2].rung, 0, "{points:?}");
    }
}
