//! `artifacts/manifest.json` parsing (contract with `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Metadata for one AOT-compiled TinyDet variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    /// Absolute path of the HLO text artifact.
    pub hlo_path: PathBuf,
    pub input_size: u32,
    pub grid: u32,
    pub num_classes: u32,
    pub out_rows: u32,
    pub out_cols: u32,
    pub params: u64,
    pub flops_per_frame: u64,
}

impl ModelMeta {
    /// Flat f32 input length: 1 × S × S × 3.
    pub fn input_len(&self) -> usize {
        (self.input_size as usize) * (self.input_size as usize) * 3
    }

    /// Flat f32 output length: out_rows × out_cols.
    pub fn output_len(&self) -> usize {
        (self.out_rows as usize) * (self.out_cols as usize)
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: Vec<ModelMeta>,
}

impl Manifest {
    pub fn get(&self, name: &str) -> Option<&ModelMeta> {
        self.models.iter().find(|m| m.name == name)
    }
}

fn req_i64(obj: &Json, key: &str) -> Result<i64> {
    obj.get(key)
        .and_then(|v| v.as_i64())
        .ok_or_else(|| anyhow!("manifest: missing numeric field {key:?}"))
}

/// Load and validate `<dir>/manifest.json`.
pub fn load_manifest(dir: &Path) -> Result<Manifest> {
    let mpath = dir.join("manifest.json");
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("reading {} (run `make artifacts` first)", mpath.display()))?;
    let root = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;

    if root.get("format").and_then(|f| f.as_i64()) != Some(1) {
        bail!("manifest: unsupported format (want 1)");
    }
    let models_json = root
        .get("models")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| anyhow!("manifest: missing models array"))?;

    let mut models = Vec::with_capacity(models_json.len());
    for m in models_json {
        let name = m
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest: model without name"))?
            .to_string();
        let hlo_rel = m
            .get("hlo")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest: model {name} without hlo path"))?;
        let hlo_path = dir.join(hlo_rel);
        if !hlo_path.exists() {
            bail!("manifest: artifact {} missing", hlo_path.display());
        }
        let meta = ModelMeta {
            name: name.clone(),
            hlo_path,
            input_size: req_i64(m, "input_size")? as u32,
            grid: req_i64(m, "grid")? as u32,
            num_classes: req_i64(m, "num_classes")? as u32,
            out_rows: req_i64(m, "out_rows")? as u32,
            out_cols: req_i64(m, "out_cols")? as u32,
            params: req_i64(m, "params")? as u64,
            flops_per_frame: req_i64(m, "flops_per_frame")? as u64,
        };
        // Internal consistency.
        if meta.out_rows != meta.grid * meta.grid {
            bail!("manifest: model {name}: out_rows != grid²");
        }
        if meta.out_cols != 5 + meta.num_classes {
            bail!("manifest: model {name}: out_cols != 5 + classes");
        }
        models.push(meta);
    }
    Ok(Manifest { models })
}

/// Default artifact directory: `$EVA_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("EVA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("eva_manifest_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_valid_manifest() {
        let d = tmpdir("ok");
        std::fs::write(d.join("essd.hlo.txt"), "HloModule m").unwrap();
        write_manifest(
            &d,
            r#"{"format":1,"models":[{"name":"essd","hlo":"essd.hlo.txt",
                "input_size":96,"grid":12,"num_classes":3,
                "out_rows":144,"out_cols":8,"params":61032,
                "flops_per_frame":23371776}]}"#,
        );
        let m = load_manifest(&d).unwrap();
        let meta = m.get("essd").unwrap();
        assert_eq!(meta.input_len(), 96 * 96 * 3);
        assert_eq!(meta.output_len(), 144 * 8);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_missing_artifact_file() {
        let d = tmpdir("missing");
        write_manifest(
            &d,
            r#"{"format":1,"models":[{"name":"x","hlo":"x.hlo.txt",
                "input_size":96,"grid":12,"num_classes":3,
                "out_rows":144,"out_cols":8,"params":1,"flops_per_frame":1}]}"#,
        );
        assert!(load_manifest(&d).is_err());
    }

    #[test]
    fn rejects_inconsistent_geometry() {
        let d = tmpdir("geom");
        std::fs::write(d.join("x.hlo.txt"), "HloModule m").unwrap();
        write_manifest(
            &d,
            r#"{"format":1,"models":[{"name":"x","hlo":"x.hlo.txt",
                "input_size":96,"grid":12,"num_classes":3,
                "out_rows":100,"out_cols":8,"params":1,"flops_per_frame":1}]}"#,
        );
        let err = load_manifest(&d).unwrap_err().to_string();
        assert!(err.contains("grid"), "{err}");
    }

    #[test]
    fn rejects_wrong_format_version() {
        let d = tmpdir("ver");
        write_manifest(&d, r#"{"format":2,"models":[]}"#);
        assert!(load_manifest(&d).is_err());
    }

    #[test]
    fn real_artifacts_parse_when_present() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = load_manifest(&dir).unwrap();
            assert!(m.get("essd").is_some());
            assert!(m.get("eyolo").is_some());
            let eyolo = m.get("eyolo").unwrap();
            assert_eq!(eyolo.input_size, 128);
            assert_eq!(eyolo.grid, 16);
        }
    }
}
