//! PJRT execution engine: compile-once, execute-many model runtimes.

use anyhow::{bail, Result};

use crate::runtime::manifest::ModelMeta;

/// Cloneable, `Send` description from which a thread builds its own
/// [`ModelRuntime`] (the PJRT client itself is thread-local).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub meta: ModelMeta,
}

impl ModelSpec {
    pub fn new(meta: ModelMeta) -> ModelSpec {
        ModelSpec { meta }
    }

    /// Build the runtime: create a CPU PJRT client, parse the HLO text,
    /// compile. Expensive (~100 ms–1 s) — do it once per worker.
    pub fn build(&self) -> Result<ModelRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&self.meta.hlo_path)
            .map_err(|e| {
                anyhow::anyhow!("loading {}: {e:?}", self.meta.hlo_path.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", self.meta.name))?;
        Ok(ModelRuntime {
            meta: self.meta.clone(),
            exe,
        })
    }
}

/// A compiled TinyDet variant, ready to run frames.
pub struct ModelRuntime {
    meta: ModelMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl ModelRuntime {
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Run one frame.
    ///
    /// `input` is the flat NHWC f32 image, length `meta.input_len()`,
    /// values in [0, 1]. Returns the flat decoded detection rows,
    /// length `meta.output_len()` (`out_rows` × `out_cols`, row layout
    /// `[objectness, cx, cy, w, h, class_probs...]`).
    pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        if input.len() != self.meta.input_len() {
            bail!(
                "input length {} != expected {} for {}",
                input.len(),
                self.meta.input_len(),
                self.meta.name
            );
        }
        let s = self.meta.input_size as i64;
        let lit = xla::Literal::vec1(input)
            .reshape(&[1, s, s, 3])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1: {e:?}"))?;
        let values: Vec<f32> = out
            .to_vec()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        if values.len() != self.meta.output_len() {
            bail!(
                "output length {} != expected {} for {}",
                values.len(),
                self.meta.output_len(),
                self.meta.name
            );
        }
        Ok(values)
    }

    /// Convert an RGB8 frame raster (already at `input_size`²) to the
    /// model's flat f32 input.
    pub fn pixels_to_input(&self, rgb: &[u8]) -> Result<Vec<f32>> {
        if rgb.len() != self.meta.input_len() {
            bail!(
                "pixel buffer length {} != expected {} for {}",
                rgb.len(),
                self.meta.input_len(),
                self.meta.name
            );
        }
        Ok(rgb.iter().map(|&b| b as f32 / 255.0).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::load_manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime_for(name: &str) -> Option<ModelRuntime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = load_manifest(&dir).unwrap();
        let meta = manifest.get(name)?.clone();
        Some(ModelSpec::new(meta).build().unwrap())
    }

    #[test]
    fn essd_executes_and_decodes_in_range() {
        let Some(rt) = runtime_for("essd") else { return };
        let input = vec![0.5f32; rt.meta().input_len()];
        let out = rt.infer(&input).unwrap();
        assert_eq!(out.len(), rt.meta().output_len());
        let cols = rt.meta().out_cols as usize;
        for row in out.chunks(cols) {
            // objectness + geometry within [0,1]; class probs sum to 1.
            assert!((0.0..=1.0).contains(&row[0]), "obj {}", row[0]);
            for v in &row[1..5] {
                assert!((0.0..=1.0).contains(v), "geom {v}");
            }
            let psum: f32 = row[5..].iter().sum();
            assert!((psum - 1.0).abs() < 1e-3, "probs sum {psum}");
        }
    }

    #[test]
    fn infer_rejects_wrong_length() {
        let Some(rt) = runtime_for("essd") else { return };
        assert!(rt.infer(&[0.0; 10]).is_err());
    }

    #[test]
    fn inference_is_deterministic() {
        let Some(rt) = runtime_for("essd") else { return };
        let mut rng = crate::util::Rng::new(3);
        let input: Vec<f32> = (0..rt.meta().input_len()).map(|_| rng.f32()).collect();
        let a = rt.infer(&input).unwrap();
        let b = rt.infer(&input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn pixels_to_input_scales() {
        let Some(rt) = runtime_for("essd") else { return };
        let rgb = vec![255u8; rt.meta().input_len()];
        let inp = rt.pixels_to_input(&rgb).unwrap();
        assert!(inp.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }
}
