//! XLA PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange format is HLO **text** (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each serving worker thread
//! constructs its own [`ModelRuntime`] from a cloneable [`ModelSpec`] —
//! which also mirrors the paper's deployment (one model instance per AI
//! device).

pub mod manifest;
pub mod engine;

pub use engine::{ModelRuntime, ModelSpec};
pub use manifest::{load_manifest, Manifest, ModelMeta};
