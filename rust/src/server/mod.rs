//! Real-time serving pipeline: the same FCFS + bounded-window + sequence
//! synchronizer semantics as the virtual-time engine, but on OS threads
//! and wall-clock time, with detectors doing *real work* (PJRT TinyDet
//! inference). Python is never involved — the artifacts were compiled
//! once at build time.
//!
//! Topology (one process):
//!
//! ```text
//!  ingest (paces frames at λ) ──► bounded window (Mutex+Condvar)
//!                                    │ pull oldest (FCFS)
//!                     worker 0..n-1 ─┴─► detector.detect(frame)
//!                                    │ fates
//!                          collector ─► Synchronizer ─► OutputRecords
//! ```
//!
//! Dropping matches the paper: when the window is full, the oldest
//! unclaimed frame is evicted and later emitted with stale detections.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::sync::{Fate, Synchronizer};
use crate::detector::Detector;
use crate::device::energy::EnergyMeter;
use crate::types::{FrameId, OutputRecord};
use crate::util::stats::Percentiles;
use crate::video::Clip;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of parallel detector replicas (worker threads).
    pub workers: usize,
    /// Freshness window; defaults to `workers`. Any value (including
    /// `Some(w)` with `w < workers` or `Some(0)`) is safe — see
    /// [`ServeConfig::effective_window`] for the invariant.
    pub window: Option<usize>,
    /// Pace ingestion at the clip's fps (true) or feed saturated (false).
    pub paced: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            window: None,
            paced: true,
        }
    }
}

impl ServeConfig {
    /// The window size [`serve`] actually uses: `window` clamped to ≥ 1,
    /// defaulting to `workers`.
    ///
    /// # Liveness invariant
    ///
    /// A window smaller than the worker count (even 1 frame for many
    /// workers) **cannot deadlock** the pipeline, because the window
    /// bounds only *unclaimed* frames and every transition wakes a
    /// waiter:
    ///
    /// 1. each ingest push signals the condvar, and eviction (on
    ///    overflow) removes only frames no worker has pulled, so a
    ///    sleeping worker can never be holding the evicted frame;
    /// 2. workers re-check the queue in a loop after every wake, so a
    ///    worker that finds the window empty simply sleeps again —
    ///    excess workers starve (by design) but never block ingest;
    /// 3. end of stream sets `closed` and broadcasts, so every worker
    ///    observes the closed+empty state and exits.
    ///
    /// The clamp to ≥ 1 exists because a zero-size window could hold no
    /// frame at all: ingest would evict each frame at arrival and the
    /// workers would never run.
    pub fn effective_window(&self) -> usize {
        self.window.unwrap_or(self.workers.max(1)).max(1)
    }
}

/// Outcome of a serving run.
pub struct ServeReport {
    pub records: Vec<OutputRecord>,
    pub metrics: RunMetrics,
    /// Wall-clock duration of the run.
    pub wall: Duration,
    /// Per-worker (frames, mean inference seconds).
    pub worker_stats: Vec<(u64, f64)>,
}

struct Shared {
    state: Mutex<WindowState>,
    cond: Condvar,
}

struct WindowState {
    pending: VecDeque<FrameId>,
    closed: bool,
}

enum CollectorMsg {
    Processed {
        fid: FrameId,
        device: usize,
        detections: Vec<crate::types::Detection>,
        at: f64,
        service: f64,
    },
    Dropped {
        fid: FrameId,
        at: f64,
    },
}

/// Run the serving pipeline over a pre-generated clip.
///
/// `factory(worker_index)` is called **inside** each worker thread to
/// build its thread-local detector (PJRT clients are not `Send`).
pub fn serve<F>(clip: &Clip, config: &ServeConfig, factory: F) -> Result<ServeReport>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    let n = config.workers.max(1);
    let window = config.effective_window();
    let shared = Arc::new(Shared {
        state: Mutex::new(WindowState {
            pending: VecDeque::new(),
            closed: false,
        }),
        cond: Condvar::new(),
    });
    let (tx, rx) = mpsc::channel::<CollectorMsg>();
    let tx_ingest = tx.clone();
    let fps = clip.fps();

    // All workers finish (potentially expensive) detector construction —
    // e.g. PJRT compilation — before the stream clock starts; otherwise
    // the first seconds of video are dropped against an empty pool.
    let ready = Arc::new(std::sync::Barrier::new(n + 1));
    let t0_cell = Arc::new(Mutex::new(Instant::now()));

    std::thread::scope(|scope| -> Result<()> {
        // Workers.
        for w in 0..n {
            let shared = Arc::clone(&shared);
            let tx = tx.clone();
            let factory = &factory;
            let frames = &clip.frames;
            let ready = Arc::clone(&ready);
            let t0_cell = Arc::clone(&t0_cell);
            scope.spawn(move || {
                let mut detector = match factory(w) {
                    Ok(d) => Some(d),
                    Err(e) => {
                        eprintln!("[worker {w}] detector construction failed: {e}");
                        None
                    }
                };
                ready.wait();
                let Some(mut detector) = detector.take() else { return };
                // t0 is written by the ingest thread right after the
                // barrier; workers only read it once they hold a frame,
                // which requires ingest to have pushed one (after t0).
                loop {
                    // FCFS pull of the oldest pending frame.
                    let fid = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            if let Some(fid) = st.pending.pop_front() {
                                break Some(fid);
                            }
                            if st.closed {
                                break None;
                            }
                            st = shared.cond.wait(st).unwrap();
                        }
                    };
                    let Some(fid) = fid else { break };
                    let started = Instant::now();
                    let detections = detector.detect(&frames[fid as usize]);
                    let service = started.elapsed().as_secs_f64();
                    let at = t0_cell.lock().unwrap().elapsed().as_secs_f64();
                    let _ = tx.send(CollectorMsg::Processed {
                        fid,
                        device: w,
                        detections,
                        at,
                        service,
                    });
                }
            });
        }
        drop(tx);

        // Wait for every worker's detector, then start the stream clock.
        ready.wait();
        let t0 = Instant::now();
        *t0_cell.lock().unwrap() = t0;

        // Ingest: pace frames at λ (or flood), evicting the oldest when
        // the window is full. Evictions go straight to the collector
        // channel as drops.
        for fid in 0..clip.len() as u64 {
            if config.paced {
                let target = t0 + Duration::from_secs_f64(fid as f64 / fps);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
            }
            let evicted = {
                let mut st = shared.state.lock().unwrap();
                st.pending.push_back(fid);
                if st.pending.len() > window {
                    st.pending.pop_front()
                } else {
                    None
                }
            };
            if let Some(old) = evicted {
                let _ = tx_ingest.send(CollectorMsg::Dropped {
                    fid: old,
                    at: t0.elapsed().as_secs_f64(),
                });
            }
            shared.cond.notify_one();
        }
        // Close the window: workers drain what remains, then exit.
        {
            let mut st = shared.state.lock().unwrap();
            st.closed = true;
        }
        shared.cond.notify_all();
        drop(tx_ingest);
        Ok(())
    })?;

    // Collect all fates (workers have exited; all senders dropped).
    let fates: Vec<CollectorMsg> = rx.into_iter().collect();

    let wall = t0_cell.lock().unwrap().elapsed();
    Ok(assemble_report(clip, n, fates, wall))
}

fn assemble_report(
    clip: &Clip,
    n: usize,
    mut fates: Vec<CollectorMsg>,
    wall: Duration,
) -> ServeReport {
    let fps = clip.fps();
    // Feed the synchronizer in fate-time order for realistic emit times.
    fates.sort_by(|a, b| {
        let ta = match a {
            CollectorMsg::Processed { at, .. } => *at,
            CollectorMsg::Dropped { at, .. } => *at,
        };
        let tb = match b {
            CollectorMsg::Processed { at, .. } => *at,
            CollectorMsg::Dropped { at, .. } => *at,
        };
        ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
    });

    let mut sync = Synchronizer::new();
    let mut latency = Percentiles::new();
    let mut device_busy = vec![0.0f64; n];
    let mut device_frames = vec![0u64; n];
    let mut worker_service: Vec<Vec<f64>> = vec![Vec::new(); n];

    for msg in fates {
        let (fid, fate, at) = match msg {
            CollectorMsg::Processed {
                fid,
                device,
                detections,
                at,
                service,
            } => {
                device_busy[device] += service;
                device_frames[device] += 1;
                worker_service[device].push(service);
                (
                    fid,
                    Fate::Processed {
                        detections,
                        device,
                    },
                    at,
                )
            }
            CollectorMsg::Dropped { fid, at } => (fid, Fate::Dropped, at),
        };
        for r in sync.resolve(fid, fate, at, |f| f as f64 / fps) {
            latency.push((r.emit_ts - r.capture_ts).max(0.0));
        }
    }

    let records = sync.emitted().to_vec();
    let frames_processed = records.iter().filter(|r| !r.was_dropped()).count() as u64;
    let frames_total = clip.len() as u64;

    let metrics = RunMetrics {
        frames_total,
        frames_processed,
        frames_dropped: frames_total - frames_processed,
        makespan: wall.as_secs_f64(),
        stream_duration: clip.spec.duration(),
        device_busy,
        device_frames: device_frames.clone(),
        latency,
        max_reorder_depth: sync.max_pending(),
        energy: EnergyMeter::new(&vec![crate::device::DeviceKind::FastCpu; n]),
    };

    let worker_stats = worker_service
        .iter()
        .enumerate()
        .map(|(i, xs)| {
            let mean = if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            };
            (device_frames[i], mean)
        })
        .collect();

    ServeReport {
        records,
        metrics,
        wall,
        worker_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detector::Detector;
    use crate::types::{BBox, Detection, Frame};
    use crate::video::{generate, presets};

    /// Fast fake detector: echoes ground truth with a fixed delay.
    struct FakeDetector {
        delay: Duration,
    }

    impl Detector for FakeDetector {
        fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
            std::thread::sleep(self.delay);
            frame
                .ground_truth
                .iter()
                .map(|gt| Detection {
                    bbox: gt.bbox,
                    class_id: gt.class_id,
                    score: 0.9,
                })
                .collect()
        }

        fn label(&self) -> String {
            "fake".into()
        }
    }

    #[test]
    fn serves_all_frames_with_enough_workers() {
        // 30 frames at 50 FPS, 5ms service, 4 workers: capacity 800 FPS.
        let clip = generate(&presets::tiny_clip(32, 30, 50.0, 1), None);
        let cfg = ServeConfig {
            workers: 4,
            window: None,
            paced: true,
        };
        let report = serve(&clip, &cfg, |_| {
            Ok(Box::new(FakeDetector {
                delay: Duration::from_millis(5),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(report.records.len(), 30);
        assert_eq!(report.metrics.frames_dropped, 0);
        // Records in frame order.
        for (i, r) in report.records.iter().enumerate() {
            assert_eq!(r.frame_id, i as u64);
        }
    }

    #[test]
    fn overloaded_single_worker_drops() {
        // 40 frames at 100 FPS with 30 ms service: heavy dropping.
        let clip = generate(&presets::tiny_clip(32, 40, 100.0, 2), None);
        let cfg = ServeConfig {
            workers: 1,
            window: Some(1),
            paced: true,
        };
        let report = serve(&clip, &cfg, |_| {
            Ok(Box::new(FakeDetector {
                delay: Duration::from_millis(30),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(report.records.len(), 40);
        assert!(
            report.metrics.frames_dropped > 10,
            "dropped {}",
            report.metrics.frames_dropped
        );
        // Dropped frames carry stale sources.
        let any_stale = report
            .records
            .iter()
            .any(|r| r.was_dropped() && !r.detections.is_empty());
        assert!(any_stale);
    }

    #[test]
    fn effective_window_clamps_and_defaults() {
        let mut cfg = ServeConfig { workers: 4, window: None, paced: true };
        assert_eq!(cfg.effective_window(), 4);
        cfg.window = Some(0);
        assert_eq!(cfg.effective_window(), 1);
        cfg.window = Some(2); // smaller than workers: allowed, not clamped up
        assert_eq!(cfg.effective_window(), 2);
        cfg.workers = 0;
        cfg.window = None;
        assert_eq!(cfg.effective_window(), 1);
    }

    #[test]
    fn window_smaller_than_workers_terminates_and_records_everything() {
        // The liveness invariant from `ServeConfig::effective_window`:
        // 4 workers contending for a 1-frame window must neither deadlock
        // nor lose records — paced and saturated both.
        for paced in [true, false] {
            let clip = generate(&presets::tiny_clip(32, 40, 120.0, 9), None);
            let cfg = ServeConfig {
                workers: 4,
                window: Some(1),
                paced,
            };
            let report = serve(&clip, &cfg, |_| {
                Ok(Box::new(FakeDetector {
                    delay: Duration::from_millis(8),
                }) as Box<dyn Detector>)
            })
            .unwrap();
            assert_eq!(report.records.len(), 40, "paced={paced}");
            for (i, r) in report.records.iter().enumerate() {
                assert_eq!(r.frame_id, i as u64);
            }
            assert_eq!(
                report.metrics.frames_processed + report.metrics.frames_dropped,
                40
            );
        }
    }

    #[test]
    fn zero_window_is_clamped_not_deadlocked() {
        let clip = generate(&presets::tiny_clip(32, 10, 50.0, 4), None);
        let cfg = ServeConfig {
            workers: 2,
            window: Some(0),
            paced: true,
        };
        let report = serve(&clip, &cfg, |_| {
            Ok(Box::new(FakeDetector {
                delay: Duration::from_millis(2),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(report.records.len(), 10);
    }

    #[test]
    fn saturated_mode_processes_everything() {
        let clip = generate(&presets::tiny_clip(32, 25, 10.0, 3), None);
        let cfg = ServeConfig {
            workers: 3,
            window: Some(64),
            paced: false,
        };
        let report = serve(&clip, &cfg, |_| {
            Ok(Box::new(FakeDetector {
                delay: Duration::from_millis(2),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(report.metrics.frames_processed, 25);
    }
}
