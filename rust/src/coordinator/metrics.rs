//! Run metrics: processing rate σ/σ_P, drops, per-device utilisation,
//! output latency, energy.

use crate::device::energy::EnergyMeter;
use crate::types::{OutputRecord, Seconds};
use crate::util::stats::Percentiles;

/// Aggregated results of one online (or saturated) run.
#[derive(Debug)]
pub struct RunMetrics {
    pub frames_total: u64,
    pub frames_processed: u64,
    pub frames_dropped: u64,
    /// Virtual/wall time from first arrival to last fate resolution.
    pub makespan: Seconds,
    /// Nominal stream duration (frames / λ).
    pub stream_duration: Seconds,
    /// Per-device busy seconds.
    pub device_busy: Vec<Seconds>,
    /// Per-device processed-frame counts.
    pub device_frames: Vec<u64>,
    /// Output latency (emit − capture) distribution.
    pub latency: Percentiles,
    /// Reorder-buffer high-water mark.
    pub max_reorder_depth: usize,
    /// Energy meter (busy-time × TDP).
    pub energy: EnergyMeter,
}

impl RunMetrics {
    /// Detection processing throughput: processed frames over elapsed
    /// time. For saturated runs this is the capacity σ_P; for paced runs
    /// it is the achieved online processing rate σ.
    pub fn processing_fps(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.frames_processed as f64 / self.makespan
    }

    /// Fraction of input frames dropped.
    pub fn drop_rate(&self) -> f64 {
        if self.frames_total == 0 {
            return 0.0;
        }
        self.frames_dropped as f64 / self.frames_total as f64
    }

    /// Average number of dropped frames per processed frame — the paper's
    /// `⌈λ/σ − 1⌉` quantity, measured rather than derived.
    pub fn drops_per_processed(&self) -> f64 {
        if self.frames_processed == 0 {
            return self.frames_dropped as f64;
        }
        self.frames_dropped as f64 / self.frames_processed as f64
    }

    /// Utilisation of device `i` over the makespan.
    pub fn utilization(&self, device: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (self.device_busy[device] / self.makespan).min(1.0)
    }

    /// Energy per processed frame in joules (busy-energy accounting).
    pub fn joules_per_frame(&self) -> f64 {
        if self.frames_processed == 0 {
            return 0.0;
        }
        self.energy.busy_joules() / self.frames_processed as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let fps = self.processing_fps();
        let drop = self.drop_rate() * 100.0;
        let p50 = self.latency.p50();
        let p99 = self.latency.p99();
        format!(
            "processed {}/{} frames ({} dropped, {:.1}%), σ={:.2} FPS, \
             latency p50={:.0} ms p99={:.0} ms, reorder≤{}, energy {:.1} J",
            self.frames_processed,
            self.frames_total,
            self.frames_dropped,
            drop,
            fps,
            p50 * 1e3,
            p99 * 1e3,
            self.max_reorder_depth,
            self.energy.busy_joules(),
        )
    }
}

/// Extract per-frame detection lists (indexed by frame id) from ordered
/// output records — the evaluator's input.
pub fn detections_per_frame(records: &[OutputRecord]) -> Vec<Vec<crate::types::Detection>> {
    records.iter().map(|r| r.detections.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceKind;

    fn metrics() -> RunMetrics {
        let mut latency = Percentiles::new();
        latency.push(0.1);
        latency.push(0.2);
        RunMetrics {
            frames_total: 100,
            frames_processed: 80,
            frames_dropped: 20,
            makespan: 10.0,
            stream_duration: 10.0,
            device_busy: vec![8.0, 4.0],
            device_frames: vec![50, 30],
            latency,
            max_reorder_depth: 3,
            energy: EnergyMeter::new(&[DeviceKind::Ncs2, DeviceKind::Ncs2]),
        }
    }

    #[test]
    fn rates() {
        let m = metrics();
        assert!((m.processing_fps() - 8.0).abs() < 1e-9);
        assert!((m.drop_rate() - 0.2).abs() < 1e-9);
        assert!((m.drops_per_processed() - 0.25).abs() < 1e-9);
        assert!((m.utilization(0) - 0.8).abs() < 1e-9);
        assert!((m.utilization(1) - 0.4).abs() < 1e-9);
    }

    #[test]
    fn summary_contains_key_numbers() {
        let m = metrics();
        let s = m.summary();
        assert!(s.contains("80/100"));
        assert!(s.contains("8.00 FPS"));
    }

    #[test]
    fn zero_division_safe() {
        let mut m = metrics();
        m.makespan = 0.0;
        m.frames_processed = 0;
        m.frames_total = 0;
        assert_eq!(m.processing_fps(), 0.0);
        assert_eq!(m.drop_rate(), 0.0);
        assert_eq!(m.joules_per_frame(), 0.0);
    }
}
