//! Parallel-detection scheduling policies (§III-C).
//!
//! All four of the paper's schedulers implement [`SchedulePolicy`]:
//!
//! * [`RoundRobin`] — the paper's baseline. Calibrated against Table VII
//!   as a **lockstep/barrier** round: one frame per model per round, the
//!   next round starts when every model in the round finished. (This is
//!   the only reading consistent with the measured 20.1 FPS for
//!   FastCPU + 7×NCS2 — 8 frames per 0.4 s round — and with RR's collapse
//!   to 3.4 FPS behind a 0.4 FPS straggler.)
//! * [`WeightedRoundRobin`] — static weights ∝ configured device rates;
//!   device *i* receives wᵢ frames per round.
//! * [`Fcfs`] — work-conserving: the next frame goes to the first model
//!   that becomes available. The paper's default scheduler.
//! * [`Proportional`] — performance-aware: like WRR, but the weights are
//!   recomputed every round from EWMA-estimated service rates, adapting
//!   to runtime conditions rather than compile-time configuration.
//!
//! Policies receive the engine's device-idle view and the bounded frame
//! window, and return dispatch batches; per-device FIFO queues in the
//! engine let a policy hand one device several frames (WRR rounds).

use crate::coordinator::source::FrameWindow;
use crate::types::FrameId;
use crate::util::stats::Ewma;

/// Scheduler selector (CLI / experiment surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    RoundRobin,
    WeightedRoundRobin,
    Fcfs,
    Proportional,
}

impl SchedulerKind {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::RoundRobin => "round-robin",
            SchedulerKind::WeightedRoundRobin => "weighted-round-robin",
            SchedulerKind::Fcfs => "fcfs",
            SchedulerKind::Proportional => "proportional",
        }
    }

    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(SchedulerKind::RoundRobin),
            "wrr" | "weighted-round-robin" | "weighted" => Some(SchedulerKind::WeightedRoundRobin),
            "fcfs" | "first-come-first-serve" => Some(SchedulerKind::Fcfs),
            "prop" | "proportional" | "performance-aware" => Some(SchedulerKind::Proportional),
            _ => None,
        }
    }

    /// Instantiate a policy for a fleet with the given per-device
    /// configured rates.
    pub fn build(&self, rates: &[f64]) -> Box<dyn SchedulePolicy> {
        match self {
            SchedulerKind::RoundRobin => Box::new(RoundRobin::new(rates.len())),
            SchedulerKind::WeightedRoundRobin => Box::new(WeightedRoundRobin::new(rates)),
            SchedulerKind::Fcfs => Box::new(Fcfs::new(rates.len())),
            SchedulerKind::Proportional => Box::new(Proportional::new(rates.len())),
        }
    }
}

/// One frame-to-device assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dispatch {
    pub device: usize,
    pub fid: FrameId,
}

/// A scheduling policy. `idle[i]` is true iff device *i* has no current
/// frame **and** no engine-queued assignments.
pub trait SchedulePolicy: Send {
    fn kind(&self) -> SchedulerKind;

    /// Invoked by the engine after every state change (frame arrival,
    /// service completion). Pull frames from `window` and return the
    /// assignments to apply.
    fn poll(&mut self, now: f64, idle: &[bool], window: &mut FrameWindow) -> Vec<Dispatch>;

    /// Observation hook: device finished a frame in `service_time` secs.
    fn on_complete(&mut self, _device: usize, _service_time: f64, _now: f64) {}
}

// ------------------------------------------------------------------ RR --

/// Lockstep round-robin (see module docs for the Table VII calibration).
pub struct RoundRobin {
    n: usize,
    /// Rotation offset so assignment order rotates across rounds.
    next_start: usize,
}

impl RoundRobin {
    pub fn new(n: usize) -> RoundRobin {
        assert!(n > 0);
        RoundRobin { n, next_start: 0 }
    }
}

impl SchedulePolicy for RoundRobin {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::RoundRobin
    }

    fn poll(&mut self, _now: f64, idle: &[bool], window: &mut FrameWindow) -> Vec<Dispatch> {
        // Barrier: a new round starts only when the whole fleet is idle.
        if !idle.iter().all(|&i| i) || window.is_empty() {
            return Vec::new();
        }
        let frames = window.pull_up_to(self.n);
        let start = self.next_start;
        self.next_start = (self.next_start + frames.len()) % self.n;
        frames
            .into_iter()
            .enumerate()
            .map(|(k, fid)| Dispatch {
                device: (start + k) % self.n,
                fid,
            })
            .collect()
    }
}

// ----------------------------------------------------------------- WRR --

/// Static weighted round-robin: device *i* gets wᵢ frames per round,
/// wᵢ ∝ configured rate (min weight 1).
pub struct WeightedRoundRobin {
    weights: Vec<usize>,
}

impl WeightedRoundRobin {
    pub fn new(rates: &[f64]) -> WeightedRoundRobin {
        WeightedRoundRobin {
            weights: weights_from_rates(rates),
        }
    }

    pub fn weights(&self) -> &[usize] {
        &self.weights
    }
}

/// Integer weights ∝ rates, normalised so the slowest device gets 1.
/// Capped at 32 per device to bound round length behind extreme skew.
pub fn weights_from_rates(rates: &[f64]) -> Vec<usize> {
    assert!(!rates.is_empty());
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-9);
    rates
        .iter()
        .map(|r| ((r / min).round() as usize).clamp(1, 32))
        .collect()
}

impl SchedulePolicy for WeightedRoundRobin {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::WeightedRoundRobin
    }

    fn poll(&mut self, _now: f64, idle: &[bool], window: &mut FrameWindow) -> Vec<Dispatch> {
        if !idle.iter().all(|&i| i) || window.is_empty() {
            return Vec::new();
        }
        dispatch_weighted_round(&self.weights, window)
    }
}

/// Shared WRR/proportional round construction: interleave devices by
/// weight (largest-remaining-weight first) so early frames spread across
/// devices rather than piling onto device 0.
fn dispatch_weighted_round(weights: &[usize], window: &mut FrameWindow) -> Vec<Dispatch> {
    let total: usize = weights.iter().sum();
    let frames = window.pull_up_to(total);
    let mut remaining = weights.to_vec();
    let mut out = Vec::with_capacity(frames.len());
    for fid in frames {
        // Device with the most remaining quota (ties -> lowest index).
        let dev = remaining
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .unwrap();
        if remaining[dev] == 0 {
            break; // round quota exhausted
        }
        remaining[dev] -= 1;
        out.push(Dispatch { device: dev, fid });
    }
    out
}

// ---------------------------------------------------------------- FCFS --

/// First-come-first-serve: assign the oldest waiting frame to the
/// lowest-indexed idle device; work-conserving, no barrier.
pub struct Fcfs {
    n: usize,
}

impl Fcfs {
    pub fn new(n: usize) -> Fcfs {
        assert!(n > 0);
        Fcfs { n }
    }
}

impl SchedulePolicy for Fcfs {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fcfs
    }

    fn poll(&mut self, _now: f64, idle: &[bool], window: &mut FrameWindow) -> Vec<Dispatch> {
        let mut out = Vec::new();
        for dev in 0..self.n {
            if !idle[dev] || out.iter().any(|d: &Dispatch| d.device == dev) {
                continue;
            }
            match window.pull() {
                Some(fid) => out.push(Dispatch { device: dev, fid }),
                None => break,
            }
        }
        out
    }
}

// -------------------------------------------------------- Proportional --

/// Performance-aware proportional scheduler: weighted rounds whose
/// weights come from EWMA-estimated service rates (recomputed every
/// round), so it adapts to runtime conditions (§III-C).
pub struct Proportional {
    estimators: Vec<Ewma>,
    /// Rounds completed (weights stay uniform until every device has at
    /// least one observation).
    observed: Vec<bool>,
}

impl Proportional {
    pub fn new(n: usize) -> Proportional {
        assert!(n > 0);
        Proportional {
            estimators: (0..n).map(|_| Ewma::new(0.25)).collect(),
            observed: vec![false; n],
        }
    }

    /// Current weight vector (1s until all devices observed).
    pub fn current_weights(&self) -> Vec<usize> {
        if !self.observed.iter().all(|&o| o) {
            return vec![1; self.estimators.len()];
        }
        let rates: Vec<f64> = self
            .estimators
            .iter()
            .map(|e| 1.0 / e.get_or(1.0).max(1e-9))
            .collect();
        weights_from_rates(&rates)
    }
}

impl SchedulePolicy for Proportional {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Proportional
    }

    fn poll(&mut self, _now: f64, idle: &[bool], window: &mut FrameWindow) -> Vec<Dispatch> {
        if !idle.iter().all(|&i| i) || window.is_empty() {
            return Vec::new();
        }
        let weights = self.current_weights();
        dispatch_weighted_round(&weights, window)
    }

    fn on_complete(&mut self, device: usize, service_time: f64, _now: f64) {
        self.estimators[device].push(service_time);
        self.observed[device] = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window_with(frames: u64) -> FrameWindow {
        let mut w = FrameWindow::new(frames.max(1) as usize);
        for f in 0..frames {
            w.arrive(f);
        }
        w
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            SchedulerKind::RoundRobin,
            SchedulerKind::WeightedRoundRobin,
            SchedulerKind::Fcfs,
            SchedulerKind::Proportional,
        ] {
            assert_eq!(SchedulerKind::parse(k.label()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("rr"), Some(SchedulerKind::RoundRobin));
        assert!(SchedulerKind::parse("sjf").is_none());
    }

    #[test]
    fn rr_waits_for_full_barrier() {
        let mut rr = RoundRobin::new(3);
        let mut w = window_with(5);
        // One device still busy -> no dispatch at all.
        let d = rr.poll(0.0, &[true, false, true], &mut w);
        assert!(d.is_empty());
        assert_eq!(w.len(), 5);
    }

    #[test]
    fn rr_round_assigns_one_frame_per_device() {
        let mut rr = RoundRobin::new(3);
        let mut w = window_with(5);
        let d = rr.poll(0.0, &[true, true, true], &mut w);
        assert_eq!(d.len(), 3);
        let mut devices: Vec<usize> = d.iter().map(|x| x.device).collect();
        devices.sort_unstable();
        assert_eq!(devices, vec![0, 1, 2]);
        let fids: Vec<u64> = d.iter().map(|x| x.fid).collect();
        assert_eq!(fids, vec![0, 1, 2]); // oldest first
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn rr_rotation_advances_across_rounds() {
        let mut rr = RoundRobin::new(3);
        let mut w = window_with(2);
        let d1 = rr.poll(0.0, &[true, true, true], &mut w);
        assert_eq!(d1.len(), 2);
        assert_eq!(d1[0].device, 0);
        assert_eq!(d1[1].device, 1);
        let mut w2 = window_with(1);
        let d2 = rr.poll(1.0, &[true, true, true], &mut w2);
        // Rotation continues at device 2.
        assert_eq!(d2[0].device, 2);
    }

    #[test]
    fn wrr_weights_proportional_to_rates() {
        // Fast CPU (13.5) + 2 sticks (2.5): weights [5, 1, 1].
        let wrr = WeightedRoundRobin::new(&[13.5, 2.5, 2.5]);
        assert_eq!(wrr.weights(), &[5, 1, 1]);
    }

    #[test]
    fn wrr_round_respects_weights() {
        let mut wrr = WeightedRoundRobin::new(&[5.0, 2.5]); // weights [2, 1]
        let mut w = window_with(3);
        let d = wrr.poll(0.0, &[true, true], &mut w);
        assert_eq!(d.len(), 3);
        let dev0 = d.iter().filter(|x| x.device == 0).count();
        let dev1 = d.iter().filter(|x| x.device == 1).count();
        assert_eq!((dev0, dev1), (2, 1));
    }

    #[test]
    fn wrr_short_window_spreads_across_devices() {
        // With fewer frames than the round quota, frames must not pile
        // onto device 0 only.
        let mut wrr = WeightedRoundRobin::new(&[5.0, 5.0]); // weights [1, 1]
        let mut w = window_with(2);
        let d = wrr.poll(0.0, &[true, true], &mut w);
        let devs: Vec<usize> = d.iter().map(|x| x.device).collect();
        assert!(devs.contains(&0) && devs.contains(&1), "{devs:?}");
    }

    #[test]
    fn fcfs_dispatches_to_all_idle_devices() {
        let mut f = Fcfs::new(3);
        let mut w = window_with(2);
        let d = f.poll(0.0, &[true, false, true], &mut w);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0], Dispatch { device: 0, fid: 0 });
        assert_eq!(d[1], Dispatch { device: 2, fid: 1 });
    }

    #[test]
    fn fcfs_no_barrier() {
        // One idle device gets work even while others are busy.
        let mut f = Fcfs::new(3);
        let mut w = window_with(1);
        let d = f.poll(0.0, &[false, true, false], &mut w);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].device, 1);
    }

    #[test]
    fn fcfs_stops_when_window_empty() {
        let mut f = Fcfs::new(4);
        let mut w = FrameWindow::new(4);
        assert!(f.poll(0.0, &[true; 4], &mut w).is_empty());
    }

    #[test]
    fn proportional_starts_uniform_then_adapts() {
        let mut p = Proportional::new(2);
        assert_eq!(p.current_weights(), vec![1, 1]);
        // Device 0 is 4x faster (service 0.1 vs 0.4).
        for _ in 0..8 {
            p.on_complete(0, 0.1, 0.0);
            p.on_complete(1, 0.4, 0.0);
        }
        assert_eq!(p.current_weights(), vec![4, 1]);
    }

    #[test]
    fn proportional_round_uses_learned_weights() {
        let mut p = Proportional::new(2);
        for _ in 0..8 {
            p.on_complete(0, 0.1, 0.0);
            p.on_complete(1, 0.4, 0.0);
        }
        let mut w = window_with(5);
        let d = p.poll(0.0, &[true, true], &mut w);
        assert_eq!(d.len(), 5);
        let dev0 = d.iter().filter(|x| x.device == 0).count();
        assert_eq!(dev0, 4);
    }

    #[test]
    fn weights_capped() {
        let w = weights_from_rates(&[1000.0, 1.0]);
        assert_eq!(w, vec![32, 1]);
    }
}
