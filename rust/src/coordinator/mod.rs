//! The paper's system contribution: multi-model parallel detection.
//!
//! * [`policy`] — the `SchedulePolicy` trait + scheduler implementations:
//!   lockstep round-robin, weighted round-robin, FCFS, and the
//!   performance-aware proportional scheduler (§III-C).
//! * [`nselect`] — choosing the parallel-detection parameter *n* (§III-B).
//! * [`source`] — the frame source: paced (live λ) or saturated
//!   (capacity measurement), with the bounded freshness window that
//!   produces the paper's "random frame dropping".
//! * [`sync`] — the sequence synchronizer: reorder buffer + stale-fill.
//! * [`engine`] — the virtual-time pipeline binding it all to the DES.
//! * [`metrics`] — run metrics: σ/σ_P, drops, utilisation, energy, latency.
//!
//! Scheduler semantics are calibrated against Table VII (see DESIGN.md):
//! the paper's RR behaves as a *barrier* round — with a fast CPU + 7
//! sticks it reaches only 20.1 FPS (= 8 frames per slowest-member round
//! of 0.4 s) while FCFS reaches 29.0 (≈ Σμᵢ, work-conserving). "Detection
//! FPS" columns are saturated-capacity measurements (they exceed the
//! input λ), while mAP columns come from the paced online run.

pub mod policy;
pub mod nselect;
pub mod source;
pub mod sync;
pub mod engine;
pub mod metrics;

pub use engine::{run_offline, run_online, OnlineRun, RunConfig, SourceMode};
pub use metrics::RunMetrics;
pub use policy::SchedulerKind;
