//! Choosing the parallel-detection parameter *n* (§III-B).
//!
//! Given input rate λ and per-model rate μ, the conservative choice is
//! `n = ⌈λ/μ⌉` (guarantees σ_P = n·μ ≥ λ: zero dropping in the ideal
//! linear-scaling case). Because 10–30 FPS is comfortable for human
//! perception of street scenes, the paper relaxes the lower bound to
//! `⌈10/μ⌉` when λ > 12, giving the near-real-time band
//! `n ∈ [⌈10/μ⌉, ⌈λ/μ⌉]`.

/// The perception floor used for the relaxed bound (FPS).
pub const PERCEPTION_FLOOR_FPS: f64 = 10.0;

/// Input-rate threshold above which the relaxed band applies.
pub const RELAXATION_THRESHOLD_FPS: f64 = 12.0;

/// Inclusive range of recommended n.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NRange {
    pub lo: usize,
    pub hi: usize,
}

impl NRange {
    pub fn contains(&self, n: usize) -> bool {
        n >= self.lo && n <= self.hi
    }
}

/// Conservative setting: smallest n with n·μ ≥ λ.
pub fn conservative_n(lambda: f64, mu: f64) -> usize {
    assert!(lambda > 0.0 && mu > 0.0);
    (lambda / mu).ceil() as usize
}

/// The paper's recommended band (§III-B).
///
/// For λ > 12 FPS: `[⌈10/μ⌉, ⌈λ/μ⌉]`; otherwise the band collapses to the
/// conservative single point `⌈λ/μ⌉`.
pub fn recommended_range(lambda: f64, mu: f64) -> NRange {
    let hi = conservative_n(lambda, mu);
    let lo = if lambda > RELAXATION_THRESHOLD_FPS {
        ((PERCEPTION_FLOOR_FPS / mu).ceil() as usize).min(hi)
    } else {
        hi
    };
    NRange { lo, hi }
}

/// Pick n within the band given how many devices are actually available;
/// `None` if even `available` devices cannot reach the perception floor.
pub fn pick_n(lambda: f64, mu: f64, available: usize) -> Option<usize> {
    let range = recommended_range(lambda, mu);
    if available >= range.lo {
        Some(range.hi.min(available))
    } else {
        None
    }
}

/// Expected parallel rate under ideal linear scaling: σ_P = n·μ.
pub fn ideal_sigma_p(n: usize, mu: f64) -> f64 {
    n as f64 * mu
}

/// Heterogeneous form: σ_P = Σ μᵢ.
pub fn ideal_sigma_p_hetero(rates: &[f64]) -> f64 {
    rates.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_eth_yolo() {
        // §III-B: λ=14, μ=2.5 -> band [⌈10/2.5⌉, ⌈14/2.5⌉] = [4, 6].
        let r = recommended_range(14.0, 2.5);
        assert_eq!(r, NRange { lo: 4, hi: 6 });
        assert!(r.contains(4) && r.contains(6) && !r.contains(7));
        assert_eq!(ideal_sigma_p(4, 2.5), 10.0);
        assert_eq!(ideal_sigma_p(6, 2.5), 15.0);
    }

    #[test]
    fn paper_example_adl() {
        // §IV-A: SSD λ=30, μ=2.3 -> [5, 14]; YOLO μ=2.5 -> [4, 12].
        assert_eq!(recommended_range(30.0, 2.3), NRange { lo: 5, hi: 14 });
        assert_eq!(recommended_range(30.0, 2.5), NRange { lo: 4, hi: 12 });
    }

    #[test]
    fn slow_streams_use_conservative_point() {
        // λ = 10 <= 12: no relaxation.
        let r = recommended_range(10.0, 2.5);
        assert_eq!(r, NRange { lo: 4, hi: 4 });
    }

    #[test]
    fn conservative_covers_lambda() {
        for &(lambda, mu) in &[(14.0, 2.5), (30.0, 2.3), (24.0, 5.0), (30.0, 13.5)] {
            let n = conservative_n(lambda, mu);
            assert!(n as f64 * mu >= lambda);
            assert!((n - 1) as f64 * mu < lambda);
        }
    }

    #[test]
    fn pick_n_respects_availability() {
        // ETH YOLO with 7 sticks available: hi = 6.
        assert_eq!(pick_n(14.0, 2.5, 7), Some(6));
        // Only 5 available: clamp.
        assert_eq!(pick_n(14.0, 2.5, 5), Some(5));
        // Fewer than the floor: refuse.
        assert_eq!(pick_n(14.0, 2.5, 3), None);
    }

    #[test]
    fn band_lo_never_exceeds_hi() {
        for lam in [12.5, 14.0, 20.0, 30.0, 60.0] {
            for mu in [0.4, 2.3, 2.5, 9.0, 13.5, 35.0] {
                let r = recommended_range(lam, mu);
                assert!(r.lo <= r.hi, "λ={lam} μ={mu}: {r:?}");
            }
        }
    }
}
