//! The sequence synchronizer (§III-C).
//!
//! Parallel detection completes frames out of order (a frame on a fast
//! device overtakes an earlier frame on a slow one). The synchronizer is
//! a reorder buffer keyed by frame id: an output record for frame *f* is
//! emitted only once the fates of all frames < *f* are known, restoring
//! the input stream's temporal order.
//!
//! Dropped frames are emitted too — carrying the detections of the latest
//! *emitted processed* frame ("the detection results from the latest
//! processed frame will be reused as the detection approximation for this
//! dropped frame"), which is exactly the stale-box mechanism behind the
//! paper's mAP degradation.

use crate::types::{Detection, FrameId, OutputRecord, Seconds};
use std::collections::BTreeMap;

/// Fate of one frame, reported by the engine.
#[derive(Debug, Clone)]
pub enum Fate {
    Processed {
        detections: Vec<Detection>,
        device: usize,
    },
    Dropped,
}

/// Reorder buffer + stale-fill.
#[derive(Debug, Default)]
pub struct Synchronizer {
    /// Next frame id to emit.
    next: FrameId,
    /// Resolved-but-not-yet-emittable fates.
    pending: BTreeMap<FrameId, (Fate, Seconds)>,
    /// Detections + id of the last *processed* frame emitted.
    last_processed: Option<(FrameId, Vec<Detection>)>,
    emitted: Vec<OutputRecord>,
    /// High-water mark of the reorder buffer (metrics).
    max_pending: usize,
}

impl Synchronizer {
    pub fn new() -> Synchronizer {
        Synchronizer::default()
    }

    /// Report frame `fid`'s fate at time `now`; `capture_ts(fid)` supplies
    /// capture timestamps for emitted records. Returns the records that
    /// became emittable (in order), as a borrowed slice of the emitted
    /// log — no cloning on the hot path (§Perf iteration 3).
    pub fn resolve<F>(
        &mut self,
        fid: FrameId,
        fate: Fate,
        now: Seconds,
        capture_ts: F,
    ) -> &[OutputRecord]
    where
        F: Fn(FrameId) -> Seconds,
    {
        assert!(
            fid >= self.next,
            "frame {fid} resolved twice (already emitted)"
        );
        let prev = self.pending.insert(fid, (fate, now));
        assert!(prev.is_none(), "frame {fid} resolved twice");
        self.max_pending = self.max_pending.max(self.pending.len());

        let first_new = self.emitted.len();
        while let Some(entry) = self.pending.remove(&self.next) {
            let (fate, resolve_ts) = entry;
            let fid = self.next;
            // Emit time: a record leaves when it is resolved AND all
            // predecessors have left; with in-order pops that is simply
            // max(resolve time, previous emit time).
            let emit_ts = self
                .emitted
                .last()
                .map(|r| resolve_ts.max(r.emit_ts))
                .unwrap_or(resolve_ts);
            let record = match fate {
                Fate::Processed { detections, device } => {
                    self.last_processed = Some((fid, detections.clone()));
                    OutputRecord {
                        frame_id: fid,
                        capture_ts: capture_ts(fid),
                        emit_ts,
                        detections,
                        stale_from: None,
                        processed_by: Some(device),
                    }
                }
                Fate::Dropped => {
                    let (src, dets) = match &self.last_processed {
                        Some((src, dets)) => (*src, dets.clone()),
                        None => (fid, Vec::new()), // nothing to reuse yet
                    };
                    OutputRecord {
                        frame_id: fid,
                        capture_ts: capture_ts(fid),
                        emit_ts,
                        detections: dets,
                        stale_from: Some(src),
                        processed_by: None,
                    }
                }
            };
            self.emitted.push(record);
            self.next += 1;
        }
        &self.emitted[first_new..]
    }

    /// All records emitted so far (in frame order).
    pub fn emitted(&self) -> &[OutputRecord] {
        &self.emitted
    }

    /// Frames whose fate is resolved but that are still blocked on
    /// predecessors.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    /// Next frame id the synchronizer is waiting for.
    pub fn next_expected(&self) -> FrameId {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::BBox;

    fn det(cx: f32) -> Detection {
        Detection {
            bbox: BBox::new(cx, 0.5, 0.1, 0.1),
            class_id: 0,
            score: 0.9,
        }
    }

    fn ts(fid: FrameId) -> Seconds {
        fid as f64 / 10.0
    }

    #[test]
    fn in_order_completions_emit_immediately() {
        let mut s = Synchronizer::new();
        let r = s.resolve(0, Fate::Processed { detections: vec![det(0.1)], device: 0 }, 1.0, ts);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].frame_id, 0);
        let r = s.resolve(1, Fate::Processed { detections: vec![det(0.2)], device: 1 }, 2.0, ts);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].frame_id, 1);
    }

    #[test]
    fn out_of_order_completion_is_held() {
        let mut s = Synchronizer::new();
        // Frame 1 finishes before frame 0.
        let r = s.resolve(1, Fate::Processed { detections: vec![det(0.2)], device: 1 }, 1.0, ts);
        assert!(r.is_empty());
        assert_eq!(s.pending_len(), 1);
        let r = s.resolve(0, Fate::Processed { detections: vec![det(0.1)], device: 0 }, 2.0, ts);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].frame_id, 0);
        assert_eq!(r[1].frame_id, 1);
        // Frame 1's emit time is gated by frame 0's (2.0).
        assert!(r[1].emit_ts >= 2.0);
    }

    #[test]
    fn dropped_frame_reuses_latest_processed() {
        let mut s = Synchronizer::new();
        s.resolve(0, Fate::Processed { detections: vec![det(0.3)], device: 0 }, 1.0, ts);
        let r = s.resolve(1, Fate::Dropped, 1.1, ts);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].stale_from, Some(0));
        assert_eq!(r[0].detections.len(), 1);
        assert!((r[0].detections[0].bbox.cx - 0.3).abs() < 1e-6);
    }

    #[test]
    fn drop_before_any_processing_is_empty() {
        let mut s = Synchronizer::new();
        let r = s.resolve(0, Fate::Dropped, 0.5, ts);
        assert_eq!(r.len(), 1);
        assert!(r[0].detections.is_empty());
        assert!(r[0].was_dropped());
    }

    #[test]
    fn stale_fill_uses_emission_order_not_resolution_order() {
        let mut s = Synchronizer::new();
        // Frame 1 (processed) resolves first, then frame 0 (processed),
        // then frame 2 (dropped): the drop must reuse frame 1's boxes
        // (latest processed in emission order).
        s.resolve(1, Fate::Processed { detections: vec![det(0.7)], device: 0 }, 1.0, ts);
        s.resolve(0, Fate::Processed { detections: vec![det(0.1)], device: 1 }, 2.0, ts);
        let r = s.resolve(2, Fate::Dropped, 2.1, ts);
        assert_eq!(r[0].stale_from, Some(1));
        assert!((r[0].detections[0].bbox.cx - 0.7).abs() < 1e-6);
    }

    #[test]
    fn first_frame_drop_then_recovery() {
        // Frame 0 drops before anything was processed (empty stale fill,
        // self-referential source); once frame 1 is processed, frame 2's
        // drop reuses frame 1's boxes.
        let mut s = Synchronizer::new();
        let r = s.resolve(0, Fate::Dropped, 0.1, ts);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].stale_from, Some(0));
        assert!(r[0].detections.is_empty());
        s.resolve(1, Fate::Processed { detections: vec![det(0.4)], device: 0 }, 0.5, ts);
        let r = s.resolve(2, Fate::Dropped, 0.6, ts);
        assert_eq!(r[0].stale_from, Some(1));
        assert_eq!(r[0].detections.len(), 1);
        assert!((r[0].detections[0].bbox.cx - 0.4).abs() < 1e-6);
    }

    #[test]
    fn all_frames_dropped_yields_empty_stale_records() {
        // Total starvation: every record emits, dropped, with no boxes
        // to reuse — stale sources degenerate to the frame itself.
        let mut s = Synchronizer::new();
        let mut emitted = 0;
        for fid in 0..5u64 {
            let r = s.resolve(fid, Fate::Dropped, 0.1 * (fid + 1) as f64, ts);
            emitted += r.len();
        }
        assert_eq!(emitted, 5);
        for (i, r) in s.emitted().iter().enumerate() {
            assert!(r.was_dropped());
            assert!(r.detections.is_empty());
            assert_eq!(r.stale_from, Some(i as u64));
            assert_eq!(r.processed_by, None);
        }
        // Emit times stay monotone even with nothing processed.
        for w in s.emitted().windows(2) {
            assert!(w[1].emit_ts >= w[0].emit_ts);
        }
    }

    #[test]
    fn out_of_order_tail_resolves_against_emission_order() {
        // In-order head (0, 1 processed), then the tail resolves
        // backwards: 4 (processed) before 3 and 2 (both dropped). The
        // drops must reuse frame 1 — the latest *emitted* processed frame
        // — not frame 4, which resolved earlier in wall time but emits
        // later in sequence order.
        let mut s = Synchronizer::new();
        s.resolve(0, Fate::Processed { detections: vec![det(0.1)], device: 0 }, 1.0, ts);
        s.resolve(1, Fate::Processed { detections: vec![det(0.2)], device: 1 }, 2.0, ts);
        let r = s.resolve(4, Fate::Processed { detections: vec![det(0.9)], device: 0 }, 3.0, ts);
        assert!(r.is_empty());
        let r = s.resolve(3, Fate::Dropped, 4.0, ts);
        assert!(r.is_empty());
        assert_eq!(s.pending_len(), 2);
        let r = s.resolve(2, Fate::Dropped, 5.0, ts);
        assert_eq!(r.len(), 3); // 2, 3, 4 unblock together
        assert_eq!(r[0].stale_from, Some(1));
        assert!((r[0].detections[0].bbox.cx - 0.2).abs() < 1e-6);
        assert_eq!(r[1].stale_from, Some(1));
        assert!((r[1].detections[0].bbox.cx - 0.2).abs() < 1e-6);
        assert_eq!(r[2].stale_from, None);
        assert_eq!(r[2].processed_by, Some(0));
        // All three unblocked records leave at (or after) the unblocking
        // resolution's time, in monotone order.
        assert!(r[0].emit_ts >= 5.0);
        assert!(r[1].emit_ts >= r[0].emit_ts);
        assert!(r[2].emit_ts >= r[1].emit_ts);
        assert_eq!(s.next_expected(), 5);
    }

    #[test]
    #[should_panic(expected = "resolved twice")]
    fn double_resolution_panics() {
        let mut s = Synchronizer::new();
        s.resolve(0, Fate::Dropped, 0.1, ts);
        s.resolve(0, Fate::Dropped, 0.2, ts);
    }

    #[test]
    fn emit_times_monotone() {
        let mut s = Synchronizer::new();
        let mut all: Vec<OutputRecord> = Vec::new();
        // Scrambled completion order.
        for (fid, t) in [(2u64, 1.0), (0, 3.0), (1, 2.0), (4, 3.5), (3, 6.0)] {
            let emitted = s.resolve(
                fid,
                Fate::Processed { detections: vec![], device: 0 },
                t,
                ts,
            );
            all.extend(emitted.iter().cloned());
        }
        assert_eq!(all.len(), 5);
        for w in all.windows(2) {
            assert!(w[1].emit_ts >= w[0].emit_ts);
            assert_eq!(w[1].frame_id, w[0].frame_id + 1);
        }
    }

    #[test]
    fn max_pending_tracks_high_water() {
        let mut s = Synchronizer::new();
        s.resolve(3, Fate::Dropped, 0.1, ts);
        s.resolve(2, Fate::Dropped, 0.2, ts);
        s.resolve(1, Fate::Dropped, 0.3, ts);
        assert_eq!(s.max_pending(), 3);
        s.resolve(0, Fate::Dropped, 0.4, ts);
        assert_eq!(s.pending_len(), 0);
        assert_eq!(s.next_expected(), 4);
    }
}
