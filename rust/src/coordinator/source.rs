//! Frame source with the bounded freshness window that produces the
//! paper's "random frame dropping".
//!
//! In *paced* mode frames become available at the stream rate λ; the
//! source keeps at most `window` unclaimed frames — when a new frame
//! arrives while the window is full, the **oldest** unclaimed frame is
//! dropped (live-video semantics: stale frames are worthless). Schedulers
//! pull the oldest unclaimed frame, so what they process is fresh and what
//! they miss is recorded as dropped.
//!
//! In *saturated* mode every frame is available immediately and nothing
//! drops — this measures pure processing capacity σ_P (how the paper's
//! "Detection FPS" columns behave; they exceed λ for large n).

use crate::types::FrameId;
use std::collections::VecDeque;

/// Outcome of offering a new arrival to the window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// Frame evicted (dropped) to make room, if the window was full.
    pub evicted: Option<FrameId>,
}

/// Bounded in-order frame window.
#[derive(Debug, Clone)]
pub struct FrameWindow {
    window: usize,
    pending: VecDeque<FrameId>,
}

impl FrameWindow {
    /// `window` must be ≥ 1.
    pub fn new(window: usize) -> FrameWindow {
        assert!(window >= 1, "frame window must hold at least one frame");
        FrameWindow {
            window,
            pending: VecDeque::with_capacity(window + 1),
        }
    }

    /// A frame arrives from the stream.
    pub fn arrive(&mut self, fid: FrameId) -> Arrival {
        self.pending.push_back(fid);
        if self.pending.len() > self.window {
            Arrival {
                evicted: self.pending.pop_front(),
            }
        } else {
            Arrival { evicted: None }
        }
    }

    /// Pull the oldest unclaimed frame.
    pub fn pull(&mut self) -> Option<FrameId> {
        self.pending.pop_front()
    }

    /// Pull up to `k` oldest unclaimed frames (lockstep rounds).
    pub fn pull_up_to(&mut self, k: usize) -> Vec<FrameId> {
        let take = k.min(self.pending.len());
        self.pending.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Drain everything left (end of stream -> dropped tail).
    pub fn drain_remaining(&mut self) -> Vec<FrameId> {
        self.pending.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_within_window_do_not_evict() {
        let mut w = FrameWindow::new(3);
        assert_eq!(w.arrive(0).evicted, None);
        assert_eq!(w.arrive(1).evicted, None);
        assert_eq!(w.arrive(2).evicted, None);
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn overflow_evicts_oldest() {
        let mut w = FrameWindow::new(2);
        w.arrive(0);
        w.arrive(1);
        let a = w.arrive(2);
        assert_eq!(a.evicted, Some(0));
        assert_eq!(w.pull(), Some(1));
        assert_eq!(w.pull(), Some(2));
        assert_eq!(w.pull(), None);
    }

    #[test]
    fn pull_is_fifo() {
        let mut w = FrameWindow::new(5);
        for f in 0..4 {
            w.arrive(f);
        }
        assert_eq!(w.pull(), Some(0));
        assert_eq!(w.pull(), Some(1));
    }

    #[test]
    fn pull_up_to_takes_oldest_block() {
        let mut w = FrameWindow::new(10);
        for f in 0..6 {
            w.arrive(f);
        }
        assert_eq!(w.pull_up_to(4), vec![0, 1, 2, 3]);
        assert_eq!(w.len(), 2);
        assert_eq!(w.pull_up_to(10), vec![4, 5]);
        assert!(w.is_empty());
    }

    #[test]
    fn drain_remaining_empties() {
        let mut w = FrameWindow::new(4);
        w.arrive(7);
        w.arrive(8);
        assert_eq!(w.drain_remaining(), vec![7, 8]);
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_window_rejected() {
        FrameWindow::new(0);
    }
}
