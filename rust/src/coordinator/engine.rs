//! Virtual-time pipeline: binds source → scheduler → (hub) → devices →
//! synchronizer on the DES kernel.
//!
//! One run simulates the full online workflow of Figure 1b: frames arrive
//! at λ, the scheduler assigns them to the n parallel model replicas
//! (crossing the shared USB hub when the device needs it), each completed
//! frame's detections come from the per-replica [`Detector`] backend, and
//! the sequence synchronizer restores temporal order — dropped frames
//! reuse the latest processed detections. mAP is then computed over *all*
//! frames by [`crate::eval::evaluate_map`], exactly as the paper measures.
//!
//! The optional `gil_serial_time` models Table X's Python prototype: every
//! dispatch first acquires a global serial resource for that long
//! (GIL-held pre/post-processing), capping effective parallelism at
//! `1 / gil_serial_time` regardless of fleet size.

use std::collections::VecDeque;

use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::policy::{SchedulePolicy, SchedulerKind};
use crate::coordinator::source::FrameWindow;
use crate::coordinator::sync::{Fate, Synchronizer};
use crate::detector::Detector;
use crate::device::energy::EnergyMeter;
use crate::device::Fleet;
use crate::sim::EventQueue;
use crate::types::{FrameId, OutputRecord};
use crate::util::stats::Percentiles;
use crate::util::Rng;
use crate::video::Clip;

/// How frames are offered to the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SourceMode {
    /// Live stream at the clip's λ; bounded freshness window -> drops.
    /// This is the mode that produces the paper's mAP columns.
    Paced,
    /// All frames available immediately; measures processing capacity
    /// σ_P — the paper's "Detection FPS" columns (they exceed λ).
    Saturated,
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub scheduler: SchedulerKind,
    pub mode: SourceMode,
    /// Freshness window (paced mode); defaults to the fleet size.
    pub window: Option<usize>,
    /// Serial coordination cost per frame (Table X GIL model).
    pub gil_serial_time: Option<f64>,
    pub seed: u64,
}

impl RunConfig {
    pub fn new(scheduler: SchedulerKind, mode: SourceMode, seed: u64) -> RunConfig {
        RunConfig {
            scheduler,
            mode,
            window: None,
            gil_serial_time: None,
            seed,
        }
    }
}

/// Result of one online run.
pub struct OnlineRun {
    pub records: Vec<OutputRecord>,
    pub metrics: RunMetrics,
}

#[derive(Debug, Clone, Copy)]
enum Event {
    /// A frame arrives from the paced stream.
    Arrival(FrameId),
    /// The GIL slice for (frame, device) finished.
    GilDone(FrameId, usize),
    /// The hub transfer for (frame, device) finished.
    HubTransferDone(FrameId, usize),
    /// Detection service finished on a device.
    ServiceDone(FrameId, usize),
}

#[derive(Debug, Default)]
struct DeviceState {
    /// Frame currently owned by the device (gil wait / transfer / service).
    current: Option<FrameId>,
    /// Engine-side FIFO of policy-assigned frames (WRR rounds).
    assigned: VecDeque<FrameId>,
    /// Drawn service time of the in-flight frame.
    pending_service: f64,
    busy_seconds: f64,
    frames_done: u64,
}

impl DeviceState {
    fn idle(&self) -> bool {
        self.current.is_none() && self.assigned.is_empty()
    }
}

/// Shared serialising FIFO resource (USB hub / GIL).
#[derive(Debug, Default)]
struct SerialResource {
    busy: bool,
    queue: VecDeque<(FrameId, usize)>,
}

impl SerialResource {
    /// Acquire for (fid, dev): returns true if acquired now, false if
    /// queued behind the current holder.
    fn acquire(&mut self, fid: FrameId, dev: usize) -> bool {
        if self.busy {
            self.queue.push_back((fid, dev));
            false
        } else {
            self.busy = true;
            true
        }
    }

    /// Release; returns the next waiter (now the holder), if any.
    fn release(&mut self) -> Option<(FrameId, usize)> {
        let next = self.queue.pop_front();
        self.busy = next.is_some();
        next
    }
}

/// Run the zero-drop offline reference (Figure 1a): every frame processed
/// sequentially by one detector. Returns per-frame detections.
pub fn run_offline(clip: &Clip, detector: &mut dyn Detector) -> Vec<Vec<crate::types::Detection>> {
    clip.frames.iter().map(|f| detector.detect(f)).collect()
}

struct Engine<'a> {
    clip: &'a Clip,
    fleet: &'a Fleet,
    detectors: Vec<Box<dyn Detector>>,
    config: &'a RunConfig,
    policy: Box<dyn SchedulePolicy>,
    window: FrameWindow,
    queue: EventQueue<Event>,
    devices: Vec<DeviceState>,
    hub: SerialResource,
    gil: SerialResource,
    sync: Synchronizer,
    latency: Percentiles,
    energy: EnergyMeter,
    rng: Rng,
    last_resolution_time: f64,
}

impl<'a> Engine<'a> {
    fn capture_ts(&self, fid: FrameId) -> f64 {
        fid as f64 / self.clip.fps()
    }

    fn resolve(&mut self, fid: FrameId, fate: Fate, now: f64) {
        let fps = self.clip.fps();
        let out = self.sync.resolve(fid, fate, now, |f| f as f64 / fps);
        self.last_resolution_time = self.last_resolution_time.max(now);
        for r in out {
            self.latency.push((r.emit_ts - r.capture_ts).max(0.0));
        }
    }

    /// Ask the policy for new assignments and start free devices.
    fn poll_policy(&mut self, now: f64) {
        let idle: Vec<bool> = self.devices.iter().map(|d| d.idle()).collect();
        let dispatches = self.policy.poll(now, &idle, &mut self.window);
        for d in dispatches {
            self.devices[d.device].assigned.push_back(d.fid);
        }
        for dev in 0..self.devices.len() {
            self.maybe_start(dev);
        }
    }

    /// If `dev` is free and has an assigned frame, begin its journey:
    /// GIL slice → hub transfer (USB devices) → service.
    fn maybe_start(&mut self, dev: usize) {
        if self.devices[dev].current.is_some() {
            return;
        }
        let Some(fid) = self.devices[dev].assigned.pop_front() else {
            return;
        };
        self.devices[dev].current = Some(fid);

        if let Some(t_gil) = self.config.gil_serial_time {
            if self.gil.acquire(fid, dev) {
                self.queue.schedule_in(t_gil, Event::GilDone(fid, dev));
            }
            return;
        }
        self.enter_hub_or_service(fid, dev);
    }

    fn enter_hub_or_service(&mut self, fid: FrameId, dev: usize) {
        let needs_hub =
            self.fleet.devices[dev].kind.needs_link() && self.fleet.hub.is_some();
        if needs_hub {
            if self.hub.acquire(fid, dev) {
                let t = self.hub_transfer_time(dev);
                self.queue.schedule_in(t, Event::HubTransferDone(fid, dev));
            }
        } else {
            self.start_service(fid, dev);
        }
    }

    fn hub_transfer_time(&self, dev: usize) -> f64 {
        let bytes = self.fleet.devices[dev].model.wire_bytes();
        self.fleet.hub.as_ref().expect("hub").transfer_time(bytes)
    }

    fn start_service(&mut self, fid: FrameId, dev: usize) {
        let t = self.fleet.devices[dev].sample_service_time(&mut self.rng);
        self.devices[dev].pending_service = t;
        self.queue.schedule_in(t, Event::ServiceDone(fid, dev));
    }

    fn handle(&mut self, now: f64, event: Event) {
        match event {
            Event::Arrival(fid) => {
                if let Some(evicted) = self.window.arrive(fid).evicted {
                    self.resolve(evicted, Fate::Dropped, now);
                }
                self.poll_policy(now);
            }
            Event::GilDone(fid, dev) => {
                if let Some((nfid, ndev)) = self.gil.release() {
                    let t_gil = self.config.gil_serial_time.unwrap_or(0.0);
                    self.queue.schedule_in(t_gil, Event::GilDone(nfid, ndev));
                }
                self.enter_hub_or_service(fid, dev);
            }
            Event::HubTransferDone(fid, dev) => {
                if let Some((nfid, ndev)) = self.hub.release() {
                    let t = self.hub_transfer_time(ndev);
                    self.queue.schedule_in(t, Event::HubTransferDone(nfid, ndev));
                }
                self.start_service(fid, dev);
            }
            Event::ServiceDone(fid, dev) => {
                let service = self.devices[dev].pending_service;
                self.devices[dev].busy_seconds += service;
                self.devices[dev].frames_done += 1;
                self.energy.record_busy(dev, service);
                self.policy.on_complete(dev, service, now);
                let detections = self.detectors[dev].detect(&self.clip.frames[fid as usize]);
                self.devices[dev].current = None;
                self.resolve(
                    fid,
                    Fate::Processed {
                        detections,
                        device: dev,
                    },
                    now,
                );
                self.maybe_start(dev);
                self.poll_policy(now);
            }
        }
    }
}

/// Run the online parallel-detection pipeline in virtual time.
///
/// `detectors` must provide one backend per fleet device (replica order).
pub fn run_online(
    clip: &Clip,
    fleet: &Fleet,
    detectors: Vec<Box<dyn Detector>>,
    config: &RunConfig,
) -> OnlineRun {
    let n = fleet.len();
    assert!(n > 0, "empty fleet");
    assert_eq!(detectors.len(), n, "one detector per device");

    let num_frames = clip.len() as u64;
    let rates: Vec<f64> = fleet.devices.iter().map(|d| d.rate()).collect();

    let window_size = match config.mode {
        SourceMode::Paced => config.window.unwrap_or(n).max(1),
        SourceMode::Saturated => num_frames.max(1) as usize,
    };

    let mut engine = Engine {
        clip,
        fleet,
        detectors,
        config,
        policy: config.scheduler.build(&rates),
        window: FrameWindow::new(window_size),
        queue: EventQueue::new(),
        devices: (0..n).map(|_| DeviceState::default()).collect(),
        hub: SerialResource::default(),
        gil: SerialResource::default(),
        sync: Synchronizer::new(),
        latency: Percentiles::new(),
        energy: EnergyMeter::new(&fleet.devices.iter().map(|d| d.kind).collect::<Vec<_>>()),
        rng: Rng::new(config.seed ^ 0x5EED_C0DE),
        last_resolution_time: 0.0,
    };

    match config.mode {
        SourceMode::Paced => {
            for fid in 0..num_frames {
                engine
                    .queue
                    .schedule(engine.capture_ts(fid), Event::Arrival(fid));
            }
        }
        SourceMode::Saturated => {
            for fid in 0..num_frames {
                engine.window.arrive(fid);
            }
        }
    }

    // Initial kick (saturated mode has no arrival events).
    engine.poll_policy(0.0);

    while let Some((now, event)) = engine.queue.pop() {
        engine.handle(now, event);
    }

    // Anything still in the window could never be scheduled: dropped tail.
    let t_end = engine.last_resolution_time.max(clip.spec.duration());
    let leftovers = engine.window.drain_remaining();
    for fid in leftovers {
        engine.resolve(fid, Fate::Dropped, t_end);
    }

    let records: Vec<OutputRecord> = engine.sync.emitted().to_vec();
    assert_eq!(
        records.len() as u64,
        num_frames,
        "every frame must get exactly one output record"
    );

    let frames_processed = records.iter().filter(|r| !r.was_dropped()).count() as u64;
    let frames_dropped = num_frames - frames_processed;
    let makespan = match config.mode {
        SourceMode::Saturated => engine.last_resolution_time,
        SourceMode::Paced => clip.spec.duration().max(engine.last_resolution_time),
    };

    let metrics = RunMetrics {
        frames_total: num_frames,
        frames_processed,
        frames_dropped,
        makespan,
        stream_duration: clip.spec.duration(),
        device_busy: engine.devices.iter().map(|d| d.busy_seconds).collect(),
        device_frames: engine.devices.iter().map(|d| d.frames_done).collect(),
        latency: engine.latency,
        max_reorder_depth: engine.sync.max_pending(),
        energy: engine.energy,
    };

    OnlineRun { records, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detector::quality::{QualityModelDetector, QualityProfile};
    use crate::device::link::LinkProfile;
    use crate::device::{DetectorModelId, DeviceInstance, DeviceKind, Fleet};
    use crate::eval::evaluate_map;
    use crate::types::{Detection, GtBox, CLASSES};
    use crate::video::{generate, presets};

    fn detectors_for(fleet: &Fleet, video: &str, seed: u64) -> Vec<Box<dyn Detector>> {
        fleet
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                Box::new(QualityModelDetector::new(
                    QualityProfile::calibrated(d.model, video),
                    seed + 1000 * i as u64,
                )) as Box<dyn Detector>
            })
            .collect()
    }

    fn eth_fleet(n: usize) -> Fleet {
        Fleet::ncs2_sticks(n, DetectorModelId::Yolov3, LinkProfile::usb3())
    }

    #[test]
    fn saturated_capacity_scales_linearly() {
        // Table IV shape: σ_P ≈ n × 2.5 for YOLOv3 on NCS2/USB3.
        let clip = generate(&presets::eth_sunnyday(1), None);
        for n in [1usize, 4, 7] {
            let fleet = eth_fleet(n);
            let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Saturated, 9);
            let run = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 5), &cfg);
            let fps = run.metrics.processing_fps();
            let ideal = 2.5 * n as f64;
            assert!(
                (fps - ideal).abs() / ideal < 0.08,
                "n={n}: fps {fps} vs ideal {ideal}"
            );
            assert_eq!(run.metrics.frames_dropped, 0);
        }
    }

    #[test]
    fn paced_single_device_drops_heavily() {
        // λ=14, μ=2.5: ~82% of frames dropped (paper §II).
        let clip = generate(&presets::eth_sunnyday(2), None);
        let fleet = eth_fleet(1);
        let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 4);
        let run = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 6), &cfg);
        let dpp = run.metrics.drops_per_processed();
        assert!(
            (dpp - 4.6).abs() < 1.0,
            "drops per processed {dpp} (expect ≈ 14/2.5 - 1 = 4.6)"
        );
        // Processing rate is pinned at ~μ.
        let fps = run.metrics.processing_fps();
        assert!((fps - 2.5).abs() < 0.3, "fps {fps}");
    }

    #[test]
    fn paced_n6_barely_drops() {
        // σ_P = 15 ≥ λ = 14: near-zero dropping.
        let clip = generate(&presets::eth_sunnyday(3), None);
        let fleet = eth_fleet(6);
        let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 4);
        let run = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 6), &cfg);
        assert!(
            run.metrics.drop_rate() < 0.05,
            "drop rate {}",
            run.metrics.drop_rate()
        );
    }

    #[test]
    fn map_recovers_with_parallelism() {
        // The headline result: mAP(n=1, dropping) << mAP(n=6) ≈ zero-drop.
        let spec = presets::eth_sunnyday(4);
        let clip = generate(&spec, None);
        let gt: Vec<&[GtBox]> = clip.frames.iter().map(|f| f.ground_truth.as_slice()).collect();

        let mut zero_drop_det = QualityModelDetector::new(
            QualityProfile::calibrated(DetectorModelId::Yolov3, "eth_sunnyday"),
            77,
        );
        let offline: Vec<Vec<Detection>> = run_offline(&clip, &mut zero_drop_det);
        let map_offline = evaluate_map(&offline, &gt, CLASSES.len(), 0.5).map;

        let mut maps = Vec::new();
        for n in [1usize, 6] {
            let fleet = eth_fleet(n);
            let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 21);
            let run = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 33), &cfg);
            let dets: Vec<Vec<Detection>> =
                run.records.iter().map(|r| r.detections.clone()).collect();
            maps.push(evaluate_map(&dets, &gt, CLASSES.len(), 0.5).map);
        }
        let (map1, map6) = (maps[0], maps[1]);
        assert!(
            map1 + 0.06 < map_offline,
            "single-device dropping must hurt: {map1} vs offline {map_offline}"
        );
        assert!(
            (map6 - map_offline).abs() < 0.07,
            "n=6 must recover: {map6} vs offline {map_offline}"
        );
    }

    #[test]
    fn rr_barrier_vs_fcfs_on_heterogeneous_fleet() {
        // Table VII shape: FCFS ≈ Σμ, RR ≈ (n+1) × slowest rate.
        let clip = generate(&presets::eth_sunnyday(5), None);
        let fleet = Fleet::cpu_plus_sticks(
            DeviceKind::FastCpu,
            7,
            DetectorModelId::Yolov3,
            LinkProfile::usb3(),
        );
        let fcfs = run_online(
            &clip,
            &fleet,
            detectors_for(&fleet, "eth_sunnyday", 1),
            &RunConfig::new(SchedulerKind::Fcfs, SourceMode::Saturated, 2),
        );
        let rr = run_online(
            &clip,
            &fleet,
            detectors_for(&fleet, "eth_sunnyday", 1),
            &RunConfig::new(SchedulerKind::RoundRobin, SourceMode::Saturated, 2),
        );
        let fcfs_fps = fcfs.metrics.processing_fps();
        let rr_fps = rr.metrics.processing_fps();
        assert!((fcfs_fps - 31.0).abs() < 2.5, "fcfs {fcfs_fps} (paper 29)");
        assert!((rr_fps - 20.0).abs() < 2.0, "rr {rr_fps} (paper 20.1)");
        assert!(fcfs_fps > rr_fps + 5.0);
    }

    #[test]
    fn usb2_hub_caps_yolo_throughput() {
        // Table IX shape: YOLOv3 on USB 2.0 plateaus near 8 FPS.
        let clip = generate(&presets::adl_rundle6(6), None);
        let fleet = Fleet::ncs2_sticks(7, DetectorModelId::Yolov3, LinkProfile::usb2());
        let run = run_online(
            &clip,
            &fleet,
            detectors_for(&fleet, "adl_rundle6", 3),
            &RunConfig::new(SchedulerKind::Fcfs, SourceMode::Saturated, 8),
        );
        let fps = run.metrics.processing_fps();
        assert!((fps - 8.0).abs() < 0.6, "usb2 plateau fps {fps}");
    }

    #[test]
    fn gil_caps_parallelism() {
        // Table X shape: with a 102 ms serial slice, throughput caps ≈9.8.
        let clip = generate(&presets::adl_rundle6(7), None);
        let mut fleet = Fleet {
            devices: (0..7)
                .map(|i| {
                    DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, 4.8)
                })
                .collect(),
            hub: Some(LinkProfile::usb3()),
        };
        for d in fleet.devices.iter_mut() {
            d.jitter_cv = 0.02;
        }
        let mut cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Saturated, 3);
        cfg.gil_serial_time = Some(1.0 / 9.8);
        let run = run_online(&clip, &fleet, detectors_for(&fleet, "adl_rundle6", 4), &cfg);
        let fps = run.metrics.processing_fps();
        assert!((fps - 9.8).abs() < 0.7, "gil fps {fps}");
    }

    #[test]
    fn every_frame_has_exactly_one_record_in_order() {
        let clip = generate(&presets::eth_sunnyday(8), None);
        let fleet = eth_fleet(3);
        let cfg = RunConfig::new(SchedulerKind::RoundRobin, SourceMode::Paced, 11);
        let run = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 2), &cfg);
        assert_eq!(run.records.len(), clip.len());
        for (i, r) in run.records.iter().enumerate() {
            assert_eq!(r.frame_id, i as u64);
        }
        // Conservation: processed + dropped = total.
        assert_eq!(
            run.metrics.frames_processed + run.metrics.frames_dropped,
            run.metrics.frames_total
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let clip = generate(&presets::eth_sunnyday(9), None);
        let fleet = eth_fleet(4);
        let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 42);
        let a = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 5), &cfg);
        let b = run_online(&clip, &fleet, detectors_for(&fleet, "eth_sunnyday", 5), &cfg);
        assert_eq!(a.metrics.frames_processed, b.metrics.frames_processed);
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.stale_from, rb.stale_from);
            assert_eq!(ra.detections.len(), rb.detections.len());
        }
    }

    #[test]
    fn offline_reference_has_zero_drops_by_construction() {
        let clip = generate(&presets::eth_sunnyday(10), None);
        let mut det = QualityModelDetector::new(
            QualityProfile::calibrated(DetectorModelId::Yolov3, "eth_sunnyday"),
            1,
        );
        let dets = run_offline(&clip, &mut det);
        assert_eq!(dets.len(), clip.len());
    }
}
