//! Core domain types: frames, boxes, detections, classes, time.

/// Object classes shared with the python training pipeline
/// (`python/compile/model.py::CLASSES`). Order matters: class ids in
/// detector outputs index into this list.
pub const CLASSES: [&str; 3] = ["person", "cyclist", "car"];

/// Class id newtype (index into [`CLASSES`]).
pub type ClassId = usize;

/// Monotone frame index within a clip/stream (0-based).
pub type FrameId = u64;

/// Simulation / wall time in seconds.
pub type Seconds = f64;

/// Axis-aligned bounding box in normalised [0,1] image coordinates,
/// stored as centre + size (the detector's native output layout).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
}

impl BBox {
    pub fn new(cx: f32, cy: f32, w: f32, h: f32) -> BBox {
        BBox { cx, cy, w, h }
    }

    /// From corner coordinates.
    pub fn from_corners(x0: f32, y0: f32, x1: f32, y1: f32) -> BBox {
        BBox {
            cx: (x0 + x1) / 2.0,
            cy: (y0 + y1) / 2.0,
            w: (x1 - x0).max(0.0),
            h: (y1 - y0).max(0.0),
        }
    }

    /// Corner coordinates (x0, y0, x1, y1).
    pub fn corners(&self) -> (f32, f32, f32, f32) {
        (
            self.cx - self.w / 2.0,
            self.cy - self.h / 2.0,
            self.cx + self.w / 2.0,
            self.cy + self.h / 2.0,
        )
    }

    pub fn area(&self) -> f32 {
        self.w.max(0.0) * self.h.max(0.0)
    }

    /// Intersection-over-union with another box.
    pub fn iou(&self, other: &BBox) -> f32 {
        let (ax0, ay0, ax1, ay1) = self.corners();
        let (bx0, by0, bx1, by1) = other.corners();
        let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
        let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
        let inter = ix * iy;
        let union = self.area() + other.area() - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }

    /// Translate by (dx, dy) in normalised coordinates.
    pub fn shifted(&self, dx: f32, dy: f32) -> BBox {
        BBox {
            cx: self.cx + dx,
            cy: self.cy + dy,
            ..*self
        }
    }

    /// Clamp the centre into [0,1] (objects may walk off-frame).
    pub fn clamped(&self) -> BBox {
        BBox {
            cx: self.cx.clamp(0.0, 1.0),
            cy: self.cy.clamp(0.0, 1.0),
            w: self.w.clamp(0.0, 1.0),
            h: self.h.clamp(0.0, 1.0),
        }
    }

    /// Fraction of this box that lies inside the [0,1]² frame.
    pub fn visible_fraction(&self) -> f32 {
        let (x0, y0, x1, y1) = self.corners();
        let vx = (x1.min(1.0) - x0.max(0.0)).max(0.0);
        let vy = (y1.min(1.0) - y0.max(0.0)).max(0.0);
        let a = self.area();
        if a <= 0.0 {
            0.0
        } else {
            (vx * vy) / a
        }
    }
}

/// One detection: box + class + confidence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Detection {
    pub bbox: BBox,
    pub class_id: ClassId,
    pub score: f32,
}

/// Ground-truth object annotation for one frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GtBox {
    pub bbox: BBox,
    pub class_id: ClassId,
    /// Stable object identity across frames (for tracking-style analyses).
    pub track_id: u32,
}

/// A raw video frame: RGB8 raster + ground truth + timing.
#[derive(Debug, Clone)]
pub struct Frame {
    pub id: FrameId,
    /// Capture timestamp (seconds since stream start): `id / fps`.
    pub ts: Seconds,
    pub width: u32,
    pub height: u32,
    /// RGB8 pixels, row-major, len = w*h*3. May be empty for
    /// "metadata-only" frames used by the virtual-time engine (the
    /// quality-model detector needs only geometry, not pixels).
    pub pixels: Vec<u8>,
    pub ground_truth: Vec<GtBox>,
}

impl Frame {
    /// Byte size of the raster payload this frame would put on a link
    /// when shipped to an AI accelerator, assuming it is first resized to
    /// `input_size` and sent at `bytes_per_channel` precision (FP16 = 2).
    pub fn wire_bytes(input_size: u32, bytes_per_channel: u32) -> u64 {
        (input_size as u64) * (input_size as u64) * 3 * bytes_per_channel as u64
    }
}

/// The per-frame output record emitted by the sequence synchronizer.
#[derive(Debug, Clone)]
pub struct OutputRecord {
    pub frame_id: FrameId,
    /// Capture timestamp of the source frame.
    pub capture_ts: Seconds,
    /// Time the record left the synchronizer.
    pub emit_ts: Seconds,
    /// Detections (fresh, or reused from `stale_from` if dropped).
    pub detections: Vec<Detection>,
    /// `None` if this frame was actually processed; `Some(src)` if it was
    /// dropped and reuses detections from processed frame `src`.
    pub stale_from: Option<FrameId>,
    /// Which model replica processed it (None for dropped frames).
    pub processed_by: Option<usize>,
}

impl OutputRecord {
    pub fn was_dropped(&self) -> bool {
        self.stale_from.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corners_roundtrip() {
        let b = BBox::new(0.5, 0.4, 0.2, 0.3);
        let (x0, y0, x1, y1) = b.corners();
        let b2 = BBox::from_corners(x0, y0, x1, y1);
        assert!((b.cx - b2.cx).abs() < 1e-6);
        assert!((b.cy - b2.cy).abs() < 1e-6);
        assert!((b.w - b2.w).abs() < 1e-6);
        assert!((b.h - b2.h).abs() < 1e-6);
    }

    #[test]
    fn iou_identical_is_one() {
        let b = BBox::new(0.5, 0.5, 0.2, 0.2);
        assert!((b.iou(&b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        let a = BBox::new(0.2, 0.2, 0.1, 0.1);
        let b = BBox::new(0.8, 0.8, 0.1, 0.1);
        assert_eq!(a.iou(&b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // Two unit-square halves: A=[0,1]x[0,1], B=[0.5,1.5]x[0,1]
        let a = BBox::from_corners(0.0, 0.0, 1.0, 1.0);
        let b = BBox::from_corners(0.5, 0.0, 1.5, 1.0);
        // inter = 0.5, union = 1.5 -> IoU = 1/3
        assert!((a.iou(&b) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn iou_symmetric() {
        let a = BBox::new(0.4, 0.4, 0.3, 0.5);
        let b = BBox::new(0.5, 0.45, 0.25, 0.4);
        assert!((a.iou(&b) - b.iou(&a)).abs() < 1e-7);
    }

    #[test]
    fn iou_zero_area_box() {
        let a = BBox::new(0.5, 0.5, 0.0, 0.0);
        let b = BBox::new(0.5, 0.5, 0.2, 0.2);
        assert_eq!(a.iou(&b), 0.0);
    }

    #[test]
    fn visible_fraction() {
        let inside = BBox::new(0.5, 0.5, 0.2, 0.2);
        assert!((inside.visible_fraction() - 1.0).abs() < 1e-6);
        let half_out = BBox::new(0.0, 0.5, 0.2, 0.2); // left half off-frame
        assert!((half_out.visible_fraction() - 0.5).abs() < 1e-6);
        let fully_out = BBox::new(-0.5, 0.5, 0.2, 0.2);
        assert_eq!(fully_out.visible_fraction(), 0.0);
    }

    #[test]
    fn shifted_moves_centre_only() {
        let b = BBox::new(0.5, 0.5, 0.2, 0.2).shifted(0.1, -0.2);
        assert!((b.cx - 0.6).abs() < 1e-6);
        assert!((b.cy - 0.3).abs() < 1e-6);
        assert!((b.w - 0.2).abs() < 1e-6);
    }

    #[test]
    fn wire_bytes_matches_paper_payloads() {
        // Paper §IV-D: YOLOv3 416*416*3 = 519168 elements; SSD 300*300*3 = 270000.
        assert_eq!(Frame::wire_bytes(416, 1), 519_168);
        assert_eq!(Frame::wire_bytes(300, 1), 270_000);
        // FP16 on the wire doubles it.
        assert_eq!(Frame::wire_bytes(416, 2), 1_038_336);
    }

    #[test]
    fn output_record_dropped() {
        let r = OutputRecord {
            frame_id: 5,
            capture_ts: 0.1,
            emit_ts: 0.2,
            detections: vec![],
            stale_from: Some(3),
            processed_by: None,
        };
        assert!(r.was_dropped());
    }
}
