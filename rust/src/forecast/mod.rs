//! Per-stream λ forecasting: one predicted Σλ signal shared by all three
//! control loops (ROADMAP item 4).
//!
//! Every control loop in the stack — admission, per-shard autoscale, and
//! the migration planner — reacts to *committed* Σλ after the
//! arrival-rate/processing-rate mismatch (§ III of the paper) has already
//! cost dropped frames. This module builds the one forecast layer they
//! all consume:
//!
//! * [`StreamForecaster`] — per-stream rate prediction from windowed
//!   arrival observations: an EWMA level ([`crate::util::stats::Ewma`])
//!   plus a seasonal decomposition that learns the diurnal shape from
//!   repeated windows (per-phase EWMA of the deviation from the level).
//!   Until one full seasonal period has been observed the seasonal term
//!   is unavailable and the forecaster degrades to EWMA-only; with no
//!   observations at all it predicts nothing.
//! * [`ShardForecast`] — the per-shard aggregate over resident streams.
//!   Both runners (the in-process co-simulation and the socket shard
//!   server) drive the *same* container at the same point of the epoch
//!   loop, so forecast-carrying digests stay bit-identical across
//!   transports by construction.
//! * Fusion verdicts — [`ShardForecast::digest_rate`] gates the digest
//!   slot on a tight confidence band, [`should_hold`] decides when
//!   admission rides out a transient burst, and the planner consumes the
//!   slot through `ShardView::load`.
//!
//! The forecaster observes *realised* per-epoch arrival rates (the
//! integer frame quotas the coordinator grants, divided by the tick), not
//! the stream's declared profile — predictions are learned, never peeked.

use std::collections::BTreeMap;

use crate::control::wire::{req_f64, req_usize, WireError};
use crate::util::json::Json;
use crate::util::stats::{Ewma, Running};

/// Tuning for the forecast layer. Rides the session handshake (an
/// optional [`crate::control::SessionCaps`] field) so remote shards run
/// exactly the coordinator's parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastConfig {
    /// EWMA weight of the newest window on the level term.
    pub alpha: f64,
    /// EWMA weight of the newest deviation on each seasonal bucket.
    pub season_alpha: f64,
    /// Seasonal cycle length in epochs (buckets of the diurnal shape).
    /// 0 disables the seasonal term entirely (pure EWMA).
    pub period: usize,
    /// How many epochs ahead the published prediction looks.
    pub horizon: usize,
    /// Confidence gate: a forecast is *tight* (trusted by the fused
    /// control loops) when its residual band is within this fraction of
    /// the predicted rate.
    pub band: f64,
    /// Admission hold window: a burst the forecast says clears within
    /// this many epochs is ridden out instead of degraded.
    pub hold_window: usize,
}

impl Default for ForecastConfig {
    fn default() -> ForecastConfig {
        ForecastConfig {
            alpha: 0.4,
            season_alpha: 0.3,
            period: 12,
            horizon: 1,
            band: 0.2,
            hold_window: 2,
        }
    }
}

/// Serialise a forecast configuration (full-field, like the autoscale
/// config codec: the handshake carries exactly the coordinator's tuning).
pub fn forecast_config_to_json(cfg: &ForecastConfig) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("alpha".to_string(), Json::Num(cfg.alpha));
    o.insert("season_alpha".to_string(), Json::Num(cfg.season_alpha));
    o.insert("period".to_string(), Json::Num(cfg.period as f64));
    o.insert("horizon".to_string(), Json::Num(cfg.horizon as f64));
    o.insert("band".to_string(), Json::Num(cfg.band));
    o.insert("hold_window".to_string(), Json::Num(cfg.hold_window as f64));
    Json::Obj(o)
}

pub fn forecast_config_from_json(v: &Json) -> Result<ForecastConfig, WireError> {
    let alpha = req_f64(v, "alpha")?;
    if !alpha.is_finite() || alpha <= 0.0 || alpha > 1.0 {
        return Err(WireError::new("forecast alpha must be in (0, 1]"));
    }
    let season_alpha = req_f64(v, "season_alpha")?;
    if !season_alpha.is_finite() || season_alpha <= 0.0 || season_alpha > 1.0 {
        return Err(WireError::new("forecast season_alpha must be in (0, 1]"));
    }
    let band = req_f64(v, "band")?;
    if !band.is_finite() || band < 0.0 {
        return Err(WireError::new("forecast band must be >= 0"));
    }
    Ok(ForecastConfig {
        alpha,
        season_alpha,
        period: req_usize(v, "period")?,
        horizon: req_usize(v, "horizon")?,
        band,
        hold_window: req_usize(v, "hold_window")?,
    })
}

/// One prediction: the expected rate at the configured horizon plus the
/// one-step residual band around it (infinite until enough prediction
/// errors have been scored to estimate it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Forecast {
    pub rate: f64,
    pub band: f64,
}

impl Forecast {
    /// Is the band tight enough for the fused control loops to act on?
    pub fn is_tight(&self, cfg: &ForecastConfig) -> bool {
        self.band.is_finite() && self.band <= cfg.band * self.rate.max(1.0)
    }
}

/// EWMA + seasonal-decomposition predictor for one stream's arrival rate.
#[derive(Debug, Clone)]
pub struct StreamForecaster {
    cfg: ForecastConfig,
    level: Ewma,
    /// Per-phase EWMA of `observation - level` (the learned shape).
    season: Vec<Ewma>,
    /// One-step-ahead prediction errors (band estimate).
    residual: Running,
    /// Windows observed so far; also the phase clock.
    ticks: usize,
}

impl StreamForecaster {
    pub fn new(cfg: ForecastConfig) -> StreamForecaster {
        let season = (0..cfg.period)
            .map(|_| Ewma::new(cfg.season_alpha))
            .collect();
        StreamForecaster {
            level: Ewma::new(cfg.alpha),
            season,
            residual: Running::new(),
            cfg,
            ticks: 0,
        }
    }

    /// Has at least one full seasonal cycle been observed? Before that
    /// the forecaster is EWMA-only.
    pub fn seasonal_ready(&self) -> bool {
        self.cfg.period > 0 && self.ticks >= self.cfg.period
    }

    /// Windows observed so far.
    pub fn observations(&self) -> usize {
        self.ticks
    }

    /// Prediction for phase-clock tick `tick`, or `None` before any
    /// observation.
    fn predict_at(&self, tick: usize) -> Option<f64> {
        let level = self.level.get()?;
        let seasonal = if self.seasonal_ready() {
            self.season[tick % self.cfg.period].get_or(0.0)
        } else {
            0.0
        };
        Some((level + seasonal).max(0.0))
    }

    /// Feed one windowed arrival-rate observation (frames/second over
    /// the epoch just served).
    pub fn observe(&mut self, rate: f64) {
        // Score the prediction this observation falsifies *before*
        // absorbing it, so the band measures genuine forecast error.
        if let Some(predicted) = self.predict_at(self.ticks) {
            self.residual.push(rate - predicted);
        }
        self.level.push(rate);
        if self.cfg.period > 0 {
            let level = self.level.get_or(rate);
            self.season[self.ticks % self.cfg.period].push(rate - level);
        }
        self.ticks += 1;
    }

    /// Predicted rate `cfg.horizon` epochs ahead, or `None` on an empty
    /// window (nothing observed yet).
    pub fn forecast(&self) -> Option<Forecast> {
        let rate = self.predict_at(self.ticks + self.cfg.horizon.saturating_sub(1))?;
        let band = if self.residual.count() >= 2 {
            // Symmetric ~95% band from the scored one-step errors.
            2.0 * self.residual.std() + self.residual.mean().abs()
        } else {
            f64::INFINITY
        };
        Some(Forecast { rate, band })
    }
}

/// Per-shard forecast state: one [`StreamForecaster`] per resident
/// stream, keyed by global stream id, aggregated into the shard's
/// forecast-Σλ digest slot.
#[derive(Debug, Clone)]
pub struct ShardForecast {
    cfg: ForecastConfig,
    streams: BTreeMap<usize, StreamForecaster>,
}

impl ShardForecast {
    pub fn new(cfg: ForecastConfig) -> ShardForecast {
        ShardForecast {
            cfg,
            streams: BTreeMap::new(),
        }
    }

    pub fn cfg(&self) -> &ForecastConfig {
        &self.cfg
    }

    /// Feed one stream's realised rate for the epoch just served. A
    /// newly resident stream gets a fresh forecaster (migrated streams
    /// re-learn on the target; state is shard-local by design).
    pub fn observe(&mut self, stream: usize, rate: f64) {
        self.streams
            .entry(stream)
            .or_insert_with(|| StreamForecaster::new(self.cfg.clone()))
            .observe(rate);
    }

    /// Drop state for a stream that left the shard.
    pub fn detach(&mut self, stream: usize) {
        self.streams.remove(&stream);
    }

    /// Keep only streams still resident (bulk sweep after migrations).
    pub fn retain_streams<F: FnMut(usize) -> bool>(&mut self, mut live: F) {
        self.streams.retain(|&id, _| live(id));
    }

    /// Aggregate shard prediction: Σ of per-stream predicted rates, band
    /// summed conservatively. `None` when no resident stream has
    /// produced a prediction yet.
    pub fn predict(&self) -> Option<Forecast> {
        let mut rate = 0.0;
        let mut band = 0.0;
        let mut any = false;
        for f in self.streams.values().filter_map(StreamForecaster::forecast) {
            rate += f.rate;
            band += f.band;
            any = true;
        }
        if any {
            Some(Forecast { rate, band })
        } else {
            None
        }
    }

    /// The value published in the gossip digest's forecast slot: the
    /// aggregate prediction *only when its band is tight* — consumers
    /// (planner, group aggregates) may then use it unconditionally.
    pub fn digest_rate(&self) -> Option<f64> {
        self.predict()
            .filter(|f| f.is_tight(&self.cfg))
            .map(|f| f.rate)
    }
}

/// Admission fusion verdict: hold (serve at current quality, let the
/// freshness window absorb the burst) instead of degrading, when the
/// shard is over-committed *now* but a tight forecast says the offered
/// load falls back within capacity — i.e. the burst clears on its own
/// within the hold window.
pub fn should_hold(
    cfg: &ForecastConfig,
    committed: f64,
    capacity: f64,
    forecast: Option<&Forecast>,
) -> bool {
    if cfg.hold_window == 0 || committed <= capacity + 1e-9 {
        return false;
    }
    match forecast {
        Some(f) => f.is_tight(cfg) && f.rate <= capacity + 1e-9,
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    fn cfg(period: usize) -> ForecastConfig {
        ForecastConfig { period, ..ForecastConfig::default() }
    }

    #[test]
    fn empty_window_predicts_nothing() {
        let f = StreamForecaster::new(cfg(8));
        assert!(f.forecast().is_none());
        let s = ShardForecast::new(cfg(8));
        assert!(s.predict().is_none());
        assert!(s.digest_rate().is_none());
    }

    #[test]
    fn constant_rate_forecast_equals_committed_with_zero_band() {
        // A constant-rate stream must forecast exactly its committed
        // rate (zero fusion delta): the EWMA level locks to the rate and
        // every seasonal bucket learns a zero deviation.
        let mut f = StreamForecaster::new(cfg(4));
        for _ in 0..20 {
            f.observe(12.5);
        }
        let fc = f.forecast().expect("forecast after observations");
        assert!((fc.rate - 12.5).abs() < 1e-12, "rate {}", fc.rate);
        assert!(fc.band.abs() < 1e-12, "band {}", fc.band);
        assert!(fc.is_tight(&cfg(4)));
    }

    #[test]
    fn window_shorter_than_one_period_falls_back_to_ewma_only() {
        // 3 observations against a 10-epoch period: the seasonal term
        // must not fire; the prediction is the bare EWMA level.
        let c = cfg(10);
        let mut f = StreamForecaster::new(c.clone());
        let mut level = None::<f64>;
        for &x in &[4.0, 8.0, 6.0] {
            f.observe(x);
            level = Some(match level {
                None => x,
                Some(v) => c.alpha * x + (1.0 - c.alpha) * v,
            });
        }
        assert!(!f.seasonal_ready());
        let fc = f.forecast().expect("ewma-only forecast");
        assert!((fc.rate - level.unwrap()).abs() < 1e-12);
    }

    #[test]
    fn seasonal_shape_is_learned_from_repeated_windows() {
        // A square diurnal wave (low, low, high, high) repeated: after a
        // few cycles the phase-ahead prediction must sit much closer to
        // the upcoming phase's rate than the flat EWMA level does.
        let c = ForecastConfig { period: 4, horizon: 1, ..ForecastConfig::default() };
        let shape = [5.0, 5.0, 15.0, 15.0];
        let mut f = StreamForecaster::new(c);
        for _cycle in 0..12 {
            for &x in &shape {
                f.observe(x);
            }
        }
        // Next phase is 0 (rate 5.0).
        let fc = f.forecast().expect("seasonal forecast");
        assert!(
            (fc.rate - 5.0).abs() < 2.0,
            "phase-ahead prediction {} should approach 5.0",
            fc.rate
        );
        // And mid-cycle the high phase is predicted high.
        f.observe(5.0);
        f.observe(5.0);
        let fc = f.forecast().expect("seasonal forecast");
        assert!(
            fc.rate > 10.0,
            "phase-ahead prediction {} should approach 15.0",
            fc.rate
        );
    }

    #[test]
    fn band_stays_loose_until_predictions_score_well() {
        let mut f = StreamForecaster::new(cfg(0));
        f.observe(10.0);
        let fc = f.forecast().unwrap();
        assert!(fc.band.is_infinite());
        assert!(!fc.is_tight(&cfg(0)));
    }

    #[test]
    fn shard_aggregate_sums_resident_streams_and_detach_drops_state() {
        let mut s = ShardForecast::new(cfg(0));
        for _ in 0..8 {
            s.observe(1, 4.0);
            s.observe(2, 6.0);
        }
        let f = s.predict().expect("aggregate");
        assert!((f.rate - 10.0).abs() < 1e-9);
        assert_eq!(s.digest_rate().map(|r| r.round()), Some(10.0));
        s.detach(2);
        let f = s.predict().expect("aggregate");
        assert!((f.rate - 4.0).abs() < 1e-9);
        s.retain_streams(|_| false);
        assert!(s.predict().is_none());
    }

    #[test]
    fn hold_fires_only_for_tight_clearing_bursts() {
        let c = cfg(0);
        let clearing = Forecast { rate: 8.0, band: 0.1 };
        let persistent = Forecast { rate: 14.0, band: 0.1 };
        let loose = Forecast { rate: 8.0, band: f64::INFINITY };
        // Over-committed now, tight forecast back under capacity: hold.
        assert!(should_hold(&c, 12.0, 10.0, Some(&clearing)));
        // Not over-committed: nothing to hold.
        assert!(!should_hold(&c, 9.0, 10.0, Some(&clearing)));
        // Forecast says the load persists: degrade as usual.
        assert!(!should_hold(&c, 12.0, 10.0, Some(&persistent)));
        // Loose band: never trusted.
        assert!(!should_hold(&c, 12.0, 10.0, Some(&loose)));
        assert!(!should_hold(&c, 12.0, 10.0, None));
        // hold_window 0 disables the behaviour.
        let off = ForecastConfig { hold_window: 0, ..c };
        assert!(!should_hold(&off, 12.0, 10.0, Some(&clearing)));
    }

    #[test]
    fn config_roundtrips_and_rejects_malformed() {
        let cfg = ForecastConfig {
            alpha: 0.25,
            season_alpha: 0.5,
            period: 6,
            horizon: 2,
            band: 0.35,
            hold_window: 3,
        };
        let text = forecast_config_to_json(&cfg).to_string();
        let back =
            forecast_config_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert!(forecast_config_from_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = forecast_config_to_json(&cfg);
        if let Json::Obj(o) = &mut j {
            o.insert("alpha".to_string(), Json::Num(1.5));
        }
        assert!(forecast_config_from_json(&j).is_err());
    }

    #[test]
    fn random_configs_survive_the_codec() {
        check("forecast config roundtrip", Config::default(), |rng| {
            let cfg = ForecastConfig {
                alpha: rng.range(0.05, 1.0),
                season_alpha: rng.range(0.05, 1.0),
                period: rng.int_in(0, 24) as usize,
                horizon: rng.int_in(0, 4) as usize,
                band: rng.range(0.0, 1.0),
                hold_window: rng.int_in(0, 6) as usize,
            };
            let text = forecast_config_to_json(&cfg).to_string();
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            let back = forecast_config_from_json(&parsed).map_err(|e| e.to_string())?;
            if back != cfg {
                return Err(format!("decoded {back:?} != original {cfg:?}"));
            }
            Ok(())
        });
    }
}
