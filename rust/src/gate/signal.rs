//! Per-stream motion-energy signal.
//!
//! The gate needs one scalar per frame: "how much did the scene change
//! since the last frame?". Two sources produce it:
//!
//! * **Pixel path** — [`frame_mse`], the normalised mean squared error
//!   between consecutive RGB8 frames from [`crate::video::raster`]
//!   (SNIPPETS.md snippet 1's gating signal). Used wherever real
//!   pixels exist: rasterised preset clips, `eva visualize`-style
//!   tooling, and the calibration tests that pin the content-dynamics
//!   ordering (static lobby < highway < sports).
//! * **Synthetic path** — [`MotionModel`], a deterministic per-stream
//!   energy process parameterised by [`MotionDynamics`]. The
//!   virtual-time engines ([`crate::fleet::sim`]) and the remote serve
//!   path ([`crate::transport::serve`]) run on metadata-only frames
//!   with no pixels, so the gate's decisions there must come from a
//!   model that is a pure function of `(stream name, frame id)` — that
//!   purity is what makes gated runs bit-identical in-process and over
//!   tcp/uds sockets.
//!
//! The synthetic presets ([`MotionDynamics::lobby`] /
//! [`MotionDynamics::highway`] / [`MotionDynamics::sports`]) mirror the
//! pixel-level content-dynamics presets in [`crate::video::presets`];
//! the tests here assert the pixel path orders them the same way the
//! synthetic bases do.

use crate::util::Rng;

/// Normalised mean squared error between two same-sized RGB8 frames,
/// in [0, 1] (channel values scaled to [0, 1] before differencing).
/// Mismatched or empty buffers read as maximal energy — a frame the
/// gate cannot compare must be detected, never skipped.
pub fn frame_mse(a: &[u8], b: &[u8]) -> f64 {
    if a.is_empty() || a.len() != b.len() {
        return 1.0;
    }
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let d = (x as f64 - y as f64) / 255.0;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Mean per-step [`frame_mse`] over a clip's consecutive rasterised
/// frames (0.0 for clips with fewer than two frames).
pub fn clip_mean_energy(clip: &crate::video::Clip) -> f64 {
    let steps: Vec<f64> = clip
        .frames
        .windows(2)
        .map(|w| frame_mse(&w[0].pixels, &w[1].pixels))
        .collect();
    if steps.is_empty() {
        return 0.0;
    }
    steps.iter().sum::<f64>() / steps.len() as f64
}

/// Parameters of the synthetic per-stream motion-energy process.
#[derive(Debug, Clone, PartialEq)]
pub struct MotionDynamics {
    /// Baseline per-frame energy (the scene's ambient change level).
    pub base: f64,
    /// Uniform jitter amplitude on top of `base`.
    pub jitter: f64,
    /// Scene-cut period in frames: every `cut_every`-th frame (after
    /// frame 0) spikes to full energy. 0 = no cuts.
    pub cut_every: u64,
}

impl MotionDynamics {
    /// Static lobby camera: almost nothing moves.
    pub fn lobby() -> MotionDynamics {
        MotionDynamics { base: 0.02, jitter: 0.01, cut_every: 0 }
    }

    /// Fixed highway camera: constant fast traffic.
    pub fn highway() -> MotionDynamics {
        MotionDynamics { base: 0.12, jitter: 0.06, cut_every: 0 }
    }

    /// Broadcast sports feed: fast play plus periodic camera cuts.
    pub fn sports() -> MotionDynamics {
        MotionDynamics { base: 0.20, jitter: 0.10, cut_every: 120 }
    }

    /// Preset by content-dynamics name (mirrors
    /// [`crate::video::presets::by_name`]'s naming).
    pub fn by_name(name: &str) -> Option<MotionDynamics> {
        match name {
            "static_lobby" | "lobby" => Some(MotionDynamics::lobby()),
            "highway_cam" | "highway" => Some(MotionDynamics::highway()),
            "sports_feed" | "sports" => Some(MotionDynamics::sports()),
            _ => None,
        }
    }
}

/// FNV-1a over a stream name: the per-stream seed of the synthetic
/// energy process (kept local so the gate has no placement dependency).
fn name_seed(name: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Deterministic per-stream motion-energy process: `energy(fid)` is a
/// pure function of the stream name and the frame id, so every engine —
/// in-process or across a socket — computes the identical signal.
#[derive(Debug, Clone)]
pub struct MotionModel {
    seed: u64,
    dynamics: MotionDynamics,
}

impl MotionModel {
    pub fn new(stream_name: &str, dynamics: MotionDynamics) -> MotionModel {
        MotionModel { seed: name_seed(stream_name), dynamics }
    }

    /// Motion energy of frame `fid` (frame 0 reads the baseline — there
    /// is no previous frame to differ against, and the gate always
    /// detects frame 0 anyway).
    pub fn energy(&self, fid: u64) -> f64 {
        let d = &self.dynamics;
        if d.cut_every > 0 && fid > 0 && fid % d.cut_every == 0 {
            return 1.0;
        }
        let mut rng = Rng::new(self.seed ^ fid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        d.base + d.jitter * rng.f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::{generate, presets};

    #[test]
    fn frame_mse_basics() {
        assert_eq!(frame_mse(&[0, 0, 0], &[0, 0, 0]), 0.0);
        assert_eq!(frame_mse(&[255, 255], &[0, 0]), 1.0);
        // Mismatched or empty buffers are maximal energy, never zero.
        assert_eq!(frame_mse(&[1, 2], &[1, 2, 3]), 1.0);
        assert_eq!(frame_mse(&[], &[]), 1.0);
        // A half-scale step lands at 0.25.
        let a = vec![0u8; 12];
        let b = vec![128u8; 12];
        let e = frame_mse(&a, &b);
        assert!((e - (128.0 / 255.0) * (128.0 / 255.0)).abs() < 1e-9, "{e}");
    }

    #[test]
    fn pixel_energy_tracks_object_speed() {
        // Single-factor check: same clip spec, same seed, only the
        // object speed range differs — faster objects must raise the
        // frame-diff energy.
        let mut slow = presets::tiny_clip(48, 16, 10.0, 9);
        slow.min_speed = 0.005;
        slow.max_speed = 0.02;
        let mut fast = slow.clone();
        fast.min_speed = 0.6;
        fast.max_speed = 0.9;
        let e_slow = clip_mean_energy(&generate(&slow, Some(48)));
        let e_fast = clip_mean_energy(&generate(&fast, Some(48)));
        assert!(
            e_fast > e_slow,
            "fast {e_fast:.5} must exceed slow {e_slow:.5}"
        );
    }

    #[test]
    fn synthetic_energy_is_deterministic_and_bounded() {
        let m = MotionModel::new("cam0", MotionDynamics::highway());
        for fid in 0..200u64 {
            let e = m.energy(fid);
            assert_eq!(e, m.energy(fid), "frame {fid} not deterministic");
            assert!(e >= 0.12 - 1e-12 && e <= 0.18 + 1e-12, "frame {fid}: {e}");
        }
        // Different streams see different (but individually stable)
        // jitter sequences.
        let other = MotionModel::new("cam1", MotionDynamics::highway());
        assert!((0..50u64).any(|f| m.energy(f) != other.energy(f)));
    }

    #[test]
    fn synthetic_presets_order_like_their_scenes() {
        let mean = |d: MotionDynamics| {
            let m = MotionModel::new("cam", d);
            // Skip cut frames so the ordering reflects the baseline.
            let vals: Vec<f64> = (1..100u64)
                .map(|f| m.energy(f))
                .filter(|&e| e < 1.0)
                .collect();
            vals.iter().sum::<f64>() / vals.len() as f64
        };
        let lobby = mean(MotionDynamics::lobby());
        let highway = mean(MotionDynamics::highway());
        let sports = mean(MotionDynamics::sports());
        assert!(lobby < highway && highway < sports, "{lobby} {highway} {sports}");
    }

    #[test]
    fn sports_cuts_spike_to_full_energy() {
        let m = MotionModel::new("feed", MotionDynamics::sports());
        assert_eq!(m.energy(120), 1.0);
        assert_eq!(m.energy(240), 1.0);
        assert!(m.energy(0) < 1.0, "frame 0 is not a cut");
        assert!(m.energy(119) < 1.0);
    }

    #[test]
    fn dynamics_lookup_by_name() {
        assert_eq!(MotionDynamics::by_name("lobby"), Some(MotionDynamics::lobby()));
        assert_eq!(
            MotionDynamics::by_name("highway_cam"),
            Some(MotionDynamics::highway())
        );
        assert_eq!(MotionDynamics::by_name("sports"), Some(MotionDynamics::sports()));
        assert_eq!(MotionDynamics::by_name("nope"), None);
    }
}
