//! Per-frame motion-gated detection (ROADMAP open item 3).
//!
//! Every control loop below this module — the admission ladder,
//! autoscale, shard migration — reacts per *stream* at epoch
//! granularity. The gate adds the per-*frame* axis: a motion-energy
//! signal ([`signal`]) decides, frame by frame, whether a detection is
//! worth a device slot at all ([`policy`]). Quiet frames are skipped and
//! covered by tracker-extrapolated stale boxes; budget-pressured frames
//! fall to a cheaper ladder rung instead of being dropped; scene cuts
//! and a hard skip-run cap always force a fresh detection.
//!
//! The engines ([`crate::fleet::sim`], [`crate::fleet::serve`],
//! [`crate::shard`]) consult the gate per arriving frame and emit each
//! non-trivial verdict as a [`crate::control::WireEvent`] with
//! [`crate::control::ControlOrigin::Gate`], so gated runs stay inside
//! the replayable `EventLog` contract and behave identically in-process
//! and across shard sockets.

pub mod policy;
pub mod signal;

pub use policy::{GateConfig, GatePolicy, GateVerdict};
pub use signal::{clip_mean_energy, frame_mse, MotionDynamics, MotionModel};
