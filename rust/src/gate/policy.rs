//! Per-frame gate controller.
//!
//! [`GatePolicy`] turns the motion-energy signal from [`crate::gate::signal`]
//! into one [`GateVerdict`] per frame:
//!
//! * **Skip** low-motion frames entirely — the synchronizer's stale-fill
//!   acts as the constant-velocity tracker proxy, and delivered mAP
//!   charges those boxes [`crate::autoscale::ladder::staleness_factor`]
//!   decay stretched by [`GateConfig::tracker_stretch`] (a tracker holds
//!   boxes fresh ~stretch× longer than blind reuse).
//! * **Down-rung** budget-pressured frames to a cheaper ladder rung
//!   instead of dropping them, when the stream's frame window is filling.
//! * **Always re-detect** on scene cuts (energy spike over
//!   [`GateConfig::scene_cut_threshold`]) and after
//!   [`GateConfig::max_skip_run`] consecutive skips — stale boxes can
//!   never coast indefinitely.
//!
//! Skip entry/exit uses hysteresis (`skip_threshold` < `resume_threshold`)
//! on an EWMA of the raw energy, so sensor jitter near the threshold
//! cannot make the gate oscillate frame by frame.

use crate::gate::signal::MotionDynamics;
use crate::util::stats::Ewma;

/// Per-frame decision of the gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateVerdict {
    /// Run the detector at the stream's current rung (steady state).
    Detect,
    /// Energy spiked past the scene-cut threshold: force a detection
    /// and reset any skip run.
    SceneCut,
    /// The skip-run cap fired: force a refresh detection even though
    /// the scene is still quiet.
    SkipCap,
    /// Skip detection; deliver tracker-extrapolated (stale) boxes.
    Skip,
    /// Detect, but at the given (cheaper) ladder rung because the
    /// stream's frame window is under pressure.
    DownRung(usize),
}

impl GateVerdict {
    /// Whether this verdict runs the detector on the frame.
    pub fn detects(&self) -> bool {
        !matches!(self, GateVerdict::Skip)
    }

    /// Stable label (wire codec and log rendering).
    pub fn label(&self) -> &'static str {
        match self {
            GateVerdict::Detect => "detect",
            GateVerdict::SceneCut => "scene-cut",
            GateVerdict::SkipCap => "skip-cap",
            GateVerdict::Skip => "skip",
            GateVerdict::DownRung(_) => "down-rung",
        }
    }
}

/// Gate tuning. Serialised onto the wire (see
/// [`crate::control::wire`]) as the optional `gate` field of `Hello`,
/// so a coordinator can arm remote shards — old peers simply omit it.
#[derive(Debug, Clone, PartialEq)]
pub struct GateConfig {
    /// Enter skip mode when the smoothed energy falls below this.
    pub skip_threshold: f64,
    /// Leave skip mode when the smoothed energy rises past this
    /// (hysteresis: must be ≥ `skip_threshold`).
    pub resume_threshold: f64,
    /// Raw energy at or above this is a scene cut: always re-detect.
    pub scene_cut_threshold: f64,
    /// Hard cap on consecutive skipped frames before a forced refresh.
    pub max_skip_run: u64,
    /// How much slower tracker-extrapolated boxes decay than blind
    /// stale reuse: effective age = age / stretch (≥ 1).
    pub tracker_stretch: f64,
    /// Frame-window occupancy fraction at which a frame that would be
    /// detected is down-runged instead.
    pub pressure_threshold: f64,
    /// Rung to fall to under pressure (0 disables down-runging).
    pub pressure_rung: usize,
    /// EWMA smoothing factor for the energy signal, in (0, 1].
    pub alpha: f64,
    /// Synthetic motion dynamics for engines with no pixel access.
    pub dynamics: MotionDynamics,
}

impl Default for GateConfig {
    fn default() -> GateConfig {
        GateConfig {
            skip_threshold: 0.05,
            resume_threshold: 0.08,
            scene_cut_threshold: 0.5,
            max_skip_run: 2,
            tracker_stretch: 6.0,
            pressure_threshold: 0.75,
            pressure_rung: 1,
            alpha: 0.4,
            dynamics: MotionDynamics::lobby(),
        }
    }
}

impl GateConfig {
    /// Default tuning with the given content dynamics.
    pub fn for_dynamics(dynamics: MotionDynamics) -> GateConfig {
        GateConfig { dynamics, ..GateConfig::default() }
    }
}

/// Per-stream gate state machine. Feed it one `(energy, pressure)`
/// sample per frame, in frame order.
#[derive(Debug, Clone)]
pub struct GatePolicy {
    cfg: GateConfig,
    ewma: Ewma,
    skipping: bool,
    run: u64,
    frames: u64,
}

impl GatePolicy {
    pub fn new(cfg: GateConfig) -> GatePolicy {
        assert!(cfg.skip_threshold >= 0.0, "skip threshold must be >= 0");
        assert!(
            cfg.resume_threshold >= cfg.skip_threshold,
            "resume threshold below skip threshold breaks hysteresis"
        );
        assert!(cfg.max_skip_run >= 1, "skip-run cap must allow at least one skip");
        assert!(cfg.tracker_stretch >= 1.0, "tracker stretch must be >= 1");
        let alpha = cfg.alpha;
        GatePolicy { cfg, ewma: Ewma::new(alpha), skipping: false, run: 0, frames: 0 }
    }

    pub fn config(&self) -> &GateConfig {
        &self.cfg
    }

    /// Decide the fate of the next frame. `raw` is the frame's motion
    /// energy; `pressure` is the stream's frame-window occupancy in
    /// [0, 1].
    pub fn decide(&mut self, raw: f64, pressure: f64) -> GateVerdict {
        self.ewma.push(raw);
        let smoothed = self.ewma.get_or(raw);
        let first = self.frames == 0;
        self.frames += 1;

        // The very first frame has no prior boxes to extrapolate from.
        if first {
            return GateVerdict::Detect;
        }
        // Scene cuts trump everything, including an active skip run.
        if raw >= self.cfg.scene_cut_threshold {
            self.skipping = false;
            self.run = 0;
            return GateVerdict::SceneCut;
        }
        if self.skipping {
            if smoothed > self.cfg.resume_threshold {
                self.skipping = false;
                self.run = 0;
                return GateVerdict::Detect;
            }
            if self.run >= self.cfg.max_skip_run {
                // Forced refresh; stay in skip mode — the scene is
                // still quiet, so the next frames skip again.
                self.run = 0;
                return GateVerdict::SkipCap;
            }
            self.run += 1;
            return GateVerdict::Skip;
        }
        if smoothed < self.cfg.skip_threshold {
            self.skipping = true;
            self.run = 1;
            return GateVerdict::Skip;
        }
        if pressure >= self.cfg.pressure_threshold && self.cfg.pressure_rung > 0 {
            return GateVerdict::DownRung(self.cfg.pressure_rung);
        }
        GateVerdict::Detect
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GateConfig {
        // alpha 1.0 removes smoothing lag so thresholds act instantly.
        GateConfig { alpha: 1.0, ..GateConfig::default() }
    }

    #[test]
    fn first_frame_always_detects() {
        let mut p = GatePolicy::new(cfg());
        assert_eq!(p.decide(0.0, 0.0), GateVerdict::Detect);
    }

    #[test]
    fn quiet_scene_skips_with_periodic_refresh() {
        let mut p = GatePolicy::new(cfg());
        assert_eq!(p.decide(0.01, 0.0), GateVerdict::Detect);
        // cap = 2: the steady pattern is skip, skip, forced refresh.
        let verdicts: Vec<GateVerdict> = (0..6).map(|_| p.decide(0.01, 0.0)).collect();
        assert_eq!(
            verdicts,
            vec![
                GateVerdict::Skip,
                GateVerdict::Skip,
                GateVerdict::SkipCap,
                GateVerdict::Skip,
                GateVerdict::Skip,
                GateVerdict::SkipCap,
            ]
        );
    }

    #[test]
    fn hysteresis_keeps_skipping_between_thresholds() {
        let mut p = GatePolicy::new(cfg());
        p.decide(0.01, 0.0);
        assert_eq!(p.decide(0.01, 0.0), GateVerdict::Skip);
        // 0.06 is above the skip threshold but below resume: still quiet.
        assert_eq!(p.decide(0.06, 0.0), GateVerdict::Skip);
        // Past the resume threshold: back to detecting.
        assert_eq!(p.decide(0.10, 0.0), GateVerdict::Detect);
        // And 0.06 from the detecting side does NOT re-enter skip mode.
        assert_eq!(p.decide(0.06, 0.0), GateVerdict::Detect);
    }

    #[test]
    fn scene_cut_interrupts_a_skip_run() {
        let mut p = GatePolicy::new(cfg());
        p.decide(0.01, 0.0);
        assert_eq!(p.decide(0.01, 0.0), GateVerdict::Skip);
        assert_eq!(p.decide(0.9, 0.0), GateVerdict::SceneCut);
        // The cut reset skip mode; quiet frames start a fresh run.
        assert_eq!(p.decide(0.01, 0.0), GateVerdict::Skip);
    }

    #[test]
    fn pressure_downrungs_instead_of_detecting() {
        let mut p = GatePolicy::new(cfg());
        p.decide(0.2, 0.0);
        assert_eq!(p.decide(0.2, 0.9), GateVerdict::DownRung(1));
        // Below the pressure threshold the same energy detects.
        assert_eq!(p.decide(0.2, 0.1), GateVerdict::Detect);
        // A quiet frame skips even under pressure — skipping is cheaper
        // than down-runging.
        assert_eq!(p.decide(0.01, 0.9), GateVerdict::Skip);
    }

    #[test]
    fn pressure_rung_zero_disables_downrunging() {
        let mut p = GatePolicy::new(GateConfig { pressure_rung: 0, ..cfg() });
        p.decide(0.2, 0.0);
        assert_eq!(p.decide(0.2, 0.95), GateVerdict::Detect);
    }

    #[test]
    fn verdict_labels_and_detects() {
        assert!(GateVerdict::Detect.detects());
        assert!(GateVerdict::SceneCut.detects());
        assert!(GateVerdict::SkipCap.detects());
        assert!(GateVerdict::DownRung(1).detects());
        assert!(!GateVerdict::Skip.detects());
        assert_eq!(GateVerdict::Skip.label(), "skip");
        assert_eq!(GateVerdict::DownRung(2).label(), "down-rung");
    }

    #[test]
    #[should_panic(expected = "hysteresis")]
    fn resume_below_skip_threshold_is_rejected() {
        GatePolicy::new(GateConfig {
            skip_threshold: 0.1,
            resume_threshold: 0.05,
            ..GateConfig::default()
        });
    }
}
