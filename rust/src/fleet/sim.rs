//! Virtual-time fleet engine: many paced streams against the shared
//! device pool, on the DES kernel from [`crate::sim`].
//!
//! This is the multi-stream generalisation of
//! [`crate::coordinator::engine::run_online`]: each stream gets its own
//! paced arrivals, freshness window and synchronizer; the pool's
//! work-conserving dispatcher keeps every idle device busy with the
//! fairest backlogged stream. The engine deals only in frame *timing*
//! (fates carry empty detection lists) — detection quality under
//! multi-stream contention is the wall-clock path's job
//! ([`crate::fleet::serve`]), which runs real detectors per frame.
//!
//! Control speaks the serialisable [`crate::control`] vocabulary and
//! comes in two flavours:
//!
//! * **Scripted** [`ControlEvent`]s (attach/detach of streams and
//!   devices at fixed times) — elasticity experiments in milliseconds of
//!   wall time. Scripted events may come from anywhere a
//!   [`crate::control::EventLog`] decodes: a prior run's log replays
//!   verbatim.
//! * A **closed-loop** [`FleetController`] hook ([`run_fleet_with`]):
//!   the controller observes every emitted output record and ticks every
//!   `interval()` virtual seconds, emitting [`ControlAction`]s computed
//!   from feedback. This is the seam the `crate::autoscale` subsystem
//!   drives — device autoscaling and model-ladder swaps replace the
//!   scripted events with feedback control.

use std::collections::BTreeMap;

use crate::control::{
    ControlAction, ControlEvent, ControlOrigin, ControlRecord, EventLog, WireEvent,
};
use crate::coordinator::sync::Fate;
use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::metrics::{finish_stream, FleetReport, StreamAccum};
use crate::fleet::pool::Job;
use crate::fleet::registry::FleetRegistry;
use crate::fleet::stream::{StreamId, StreamSpec, StreamState};
use crate::gate::{GateConfig, GatePolicy, GateVerdict, MotionModel};
use crate::sim::EventQueue;
use crate::telemetry::{record_traces, FrameTrace, Registry, RunTelemetry, TraceOutcome};
use crate::types::{FrameId, OutputRecord};
use crate::util::Rng;

/// One fleet run's full description.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Devices attached from t = 0.
    pub devices: Vec<DeviceInstance>,
    /// Streams attached at t = 0 (admission runs in order).
    pub streams: Vec<StreamSpec>,
    /// Scripted mid-run attach/detach events.
    pub events: Vec<ControlEvent>,
    pub admission: AdmissionPolicy,
    pub seed: u64,
    /// Per-frame motion gate ([`crate::gate`]); `None` detects every
    /// admitted frame (the pre-gate behaviour).
    pub gate: Option<GateConfig>,
    /// Record per-frame span traces and a metrics registry
    /// ([`crate::telemetry`]); off by default — untraced runs pay
    /// nothing.
    pub telemetry: bool,
}

impl Scenario {
    pub fn new(devices: Vec<DeviceInstance>, streams: Vec<StreamSpec>) -> Scenario {
        Scenario {
            devices,
            streams,
            events: Vec::new(),
            admission: AdmissionPolicy::default(),
            seed: 0,
            gate: None,
            telemetry: false,
        }
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Scenario {
        self.admission = admission;
        self
    }

    pub fn with_events(mut self, events: Vec<ControlEvent>) -> Scenario {
        self.events = events;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    pub fn with_gate(mut self, gate: GateConfig) -> Scenario {
        self.gate = Some(gate);
        self
    }

    pub fn with_telemetry(mut self) -> Scenario {
        self.telemetry = true;
        self
    }
}

/// Per-frame annotations the trace assembly joins against the
/// synchronizer's record log at report time. Only the facts the records
/// don't already carry: dispatch/completion times, the serving device
/// and rung, and the drop reason.
#[derive(Debug, Clone, Copy, Default)]
struct FrameAnn {
    detect_start: Option<f64>,
    detect_end: Option<f64>,
    device: Option<usize>,
    rung: Option<usize>,
    dropped: Option<TraceOutcome>,
}

/// Telemetry accumulator, allocated only when `Scenario::telemetry`.
#[derive(Debug, Default)]
struct TraceState {
    anns: BTreeMap<(StreamId, FrameId), FrameAnn>,
}

fn mark_drop(trace: &mut Option<TraceState>, sid: StreamId, fid: FrameId, outcome: TraceOutcome) {
    if let Some(t) = trace.as_mut() {
        t.anns.entry((sid, fid)).or_default().dropped = Some(outcome);
    }
}

/// Engine-side gate state: one policy + motion model per stream (grown
/// lazily so mid-run `AttachStream` verbs gate too), the pending
/// per-frame rung overrides the dispatcher consumes, and the verdict
/// log. Steady-state `Detect` verdicts are not logged — only the frames
/// where the gate changed something.
struct GateState {
    cfg: GateConfig,
    streams: Vec<Option<(GatePolicy, MotionModel)>>,
    overrides: BTreeMap<(StreamId, FrameId), usize>,
    events: Vec<WireEvent>,
}

impl GateState {
    fn new(cfg: GateConfig) -> GateState {
        GateState {
            cfg,
            streams: Vec::new(),
            overrides: BTreeMap::new(),
            events: Vec::new(),
        }
    }

    /// Gate verdict for stream `s`'s frame `fid` arriving at `now`.
    fn decide(&mut self, s: &StreamState, fid: FrameId, now: f64) -> GateVerdict {
        if self.streams.len() <= s.id {
            self.streams.resize_with(s.id + 1, || None);
        }
        let cfg = self.cfg.clone();
        let (policy, model) = self.streams[s.id].get_or_insert_with(|| {
            let model = MotionModel::new(&s.spec.name, cfg.dynamics.clone());
            (GatePolicy::new(cfg), model)
        });
        let energy = model.energy(fid);
        let pressure = s.window.len() as f64 / s.spec.window.max(1) as f64;
        let verdict = policy.decide(energy, pressure);
        match verdict {
            GateVerdict::Detect => {}
            GateVerdict::DownRung(rung) => {
                self.overrides.insert((s.id, fid), rung);
                self.events.push(WireEvent::gate(now, s.id, fid, verdict));
            }
            _ => self.events.push(WireEvent::gate(now, s.id, fid, verdict)),
        }
        verdict
    }
}

/// Closed-loop controller hook for the virtual-time engine.
///
/// The engine feeds every emitted [`OutputRecord`] to [`observe`]
/// (latency / drop signals) and calls [`act`] every [`interval`] virtual
/// seconds; returned actions are applied immediately and logged. The
/// trait lives here (not in `crate::autoscale`) so the engine stays free
/// of policy: any feedback law that speaks `ControlAction` plugs in.
///
/// [`observe`]: FleetController::observe
/// [`act`]: FleetController::act
/// [`interval`]: FleetController::interval
pub trait FleetController {
    /// Control-loop tick period in virtual seconds (> 0).
    fn interval(&self) -> f64;
    /// One output record of stream `sid` was emitted at fleet time `now`.
    fn observe(&mut self, now: f64, sid: StreamId, record: &OutputRecord);
    /// Periodic control decision against the current registry state.
    fn act(&mut self, now: f64, reg: &FleetRegistry) -> Vec<ControlAction>;
}

/// Result of a controlled fleet run: the usual report plus the full
/// control-plane action log (scripted and feedback-driven).
/// `ControlRecord` lives in [`crate::control`] — the log is one
/// [`EventLog::from_records`] call away from the serialised wire form.
pub struct FleetRunOutput {
    pub report: FleetReport,
    pub control_log: Vec<ControlRecord>,
    /// Per-frame gate verdicts (empty when the scenario has no gate).
    pub gate_log: Vec<WireEvent>,
    /// Per-frame spans + metrics registry; `Some` iff the scenario ran
    /// with [`Scenario::with_telemetry`].
    pub telemetry: Option<RunTelemetry>,
}

impl FleetRunOutput {
    /// The run's control log as a versioned, serialisable wire log,
    /// gate verdicts interleaved in time order (stable: control events
    /// sort before gate verdicts at equal times).
    pub fn wire_log(&self) -> EventLog {
        let mut log = EventLog::from_records(&self.control_log);
        for ev in &self.gate_log {
            log.push(ev.clone());
        }
        log.events.sort_by(|a, b| a.at.total_cmp(&b.at));
        log
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Frame `fid` of stream `sid` arrives.
    Arrival { sid: StreamId, fid: FrameId },
    /// The device's in-flight job finishes.
    ServiceDone { dev: usize },
    /// Apply `scenario.events[idx]`.
    Control { idx: usize },
    /// Controller tick.
    Tick,
}

/// Schedule stream `sid`'s arrival of frame `fid`, if it exists and the
/// stream is still attached; returns whether an event was scheduled.
/// Arrivals are *chained* — each pop schedules the next — so the event
/// heap stays O(streams + in-flight) instead of O(total frames), and a
/// detached stream stops generating events (keeping `queue.now()`, and
/// with it the reported makespan, pinned to real activity).
fn schedule_next_arrival(
    queue: &mut EventQueue<Ev>,
    reg: &FleetRegistry,
    sid: StreamId,
    fid: FrameId,
) -> bool {
    let s = &reg.streams[sid];
    if s.detached || fid >= s.spec.num_frames {
        return false;
    }
    queue.schedule(s.capture_ts(fid), Ev::Arrival { sid, fid });
    true
}

/// Feed the last `n_new` emitted records of `s` to the controller.
fn feed(
    controller: &mut Option<&mut dyn FleetController>,
    s: &StreamState,
    n_new: usize,
    now: f64,
) {
    if n_new == 0 {
        return;
    }
    if let Some(c) = controller.as_mut() {
        let em = s.sync.emitted();
        for r in &em[em.len() - n_new..] {
            c.observe(now, s.id, r);
        }
    }
}

fn arrival(
    reg: &mut FleetRegistry,
    sid: StreamId,
    fid: FrameId,
    now: f64,
    controller: &mut Option<&mut dyn FleetController>,
    gate: &mut Option<GateState>,
    trace: &mut Option<TraceState>,
) {
    let n_new = {
        let s = &mut reg.streams[sid];
        if s.detached {
            return;
        }
        s.arrived += 1;
        if !s.decision.is_admitted() {
            // Rejected stream: every frame is dropped on arrival, so the
            // record log still covers the whole stream.
            mark_drop(trace, sid, fid, TraceOutcome::DroppedRejected);
            s.resolve(fid, Fate::Dropped, now)
        } else if !s.keeps(fid) {
            // Degraded stream: admission-mandated subsampling.
            mark_drop(trace, sid, fid, TraceOutcome::DroppedStride);
            s.resolve(fid, Fate::Dropped, now)
        } else if gate
            .as_mut()
            .map(|g| g.decide(s, fid, now))
            .is_some_and(|v| !v.detects())
        {
            // Gate-skipped quiet frame: never enters the window, costs
            // no device time; the synchronizer's stale-fill stands in
            // for the constant-velocity tracker and delivered-mAP
            // charges it the (stretched) staleness decay.
            mark_drop(trace, sid, fid, TraceOutcome::DroppedGate);
            s.resolve(fid, Fate::Dropped, now)
        } else if let Some(evicted) = s.window.arrive(fid).evicted {
            mark_drop(trace, sid, evicted, TraceOutcome::DroppedEvicted);
            s.resolve(evicted, Fate::Dropped, now)
        } else {
            0
        }
    };
    feed(controller, &reg.streams[sid], n_new, now);
}

/// Work-conserving dispatch: pair idle devices with backlogged streams
/// until one side runs out. Returns how many jobs were started (the
/// caller tracks in-flight work for controller-tick termination).
fn dispatch(
    reg: &mut FleetRegistry,
    queue: &mut EventQueue<Ev>,
    rng: &mut Rng,
    gate: &mut Option<GateState>,
    trace: &mut Option<TraceState>,
) -> usize {
    let mut started = 0;
    loop {
        let Some(dev) = reg.pool.next_idle() else { break };
        let Some(sid) = reg.pick_stream() else { break };
        let fid = reg.streams[sid]
            .window
            .pull()
            .expect("backlogged stream has a frame");
        let weight = reg.streams[sid].spec.weight.max(1e-9);
        reg.streams[sid].vtime += 1.0 / weight;
        // Model-ladder hook: a stream on a faster rung costs the device
        // proportionally less service time per frame. A gate down-rung
        // override applies to this frame only, never upgrades below the
        // stream's admitted rung, and is clamped to the ladder (under
        // stride-mode admission there is no ladder, so the override is
        // logged but has no speed effect).
        let base_rung = reg.streams[sid].decision.rung();
        let rung = match gate.as_mut().and_then(|g| g.overrides.remove(&(sid, fid))) {
            Some(r) => r.max(base_rung).min(reg.admission.max_rung()),
            None => base_rung,
        };
        let speedup = reg.admission.rung_speedup(rung);
        if let Some(tr) = trace.as_mut() {
            let ann = tr.anns.entry((sid, fid)).or_default();
            ann.detect_start = Some(queue.now());
            ann.device = Some(dev);
            ann.rung = Some(rung);
        }
        let t = reg
            .pool
            .start_scaled(dev, Job { stream: sid, fid }, speedup, rng);
        queue.schedule_in(t, Ev::ServiceDone { dev });
        started += 1;
    }
    started
}

/// Apply one control action (scripted or controller-emitted) at `now`.
fn apply_action(
    reg: &mut FleetRegistry,
    queue: &mut EventQueue<Ev>,
    action: ControlAction,
    now: f64,
    pending_arrivals: &mut u64,
    controller: &mut Option<&mut dyn FleetController>,
) {
    match action {
        ControlAction::AttachStream(spec) => {
            let sid = reg.attach_stream(spec, now);
            if schedule_next_arrival(queue, reg, sid, 0) {
                *pending_arrivals += 1;
            }
        }
        ControlAction::DetachStream(id) => {
            let drained = reg.detach_stream(id, now);
            for fid in drained {
                let n = reg.streams[id].resolve(fid, Fate::Dropped, now);
                feed(controller, &reg.streams[id], n, now);
            }
        }
        ControlAction::AttachDevice(instance) => {
            reg.attach_device(instance, now);
        }
        ControlAction::DetachDevice(dev) => {
            reg.detach_device(dev, now);
        }
        ControlAction::SwapModel { stream, rung } => {
            reg.set_stream_rung(stream, rung, now);
        }
    }
}

/// Run the scenario to completion and report (scripted control only).
pub fn run_fleet(scenario: &Scenario) -> FleetReport {
    run_fleet_with(scenario, None).report
}

/// Run the scenario with an optional closed-loop controller. Scripted
/// events still apply (they model external load/failures); controller
/// actions are interleaved at tick boundaries and logged alongside them.
pub fn run_fleet_with(
    scenario: &Scenario,
    mut controller: Option<&mut dyn FleetController>,
) -> FleetRunOutput {
    let mut reg = FleetRegistry::new(scenario.devices.clone(), scenario.admission.clone());
    let mut queue: EventQueue<Ev> = EventQueue::new();
    let mut rng = Rng::new(scenario.seed ^ 0x0F1E_E75E_ED00_0001);
    let mut control_log: Vec<ControlRecord> = Vec::new();
    let mut gate = scenario.gate.clone().map(GateState::new);
    let mut trace: Option<TraceState> = scenario.telemetry.then(TraceState::default);

    // Outstanding-work counters: a controller tick re-arms only while
    // any of these is non-zero, so the run terminates.
    // `pending_arrivals` counts *scheduled* arrival events (one per live
    // stream, chained), not total remaining frames.
    let mut pending_arrivals: u64 = 0;
    let mut in_flight: usize = 0;
    let mut pending_controls = scenario.events.len();
    // Time of the last *real* event (ticks excluded): controller ticks
    // re-arm while work is pending and always fire one final time, and
    // that dead time must not inflate the reported makespan.
    let mut last_activity = 0.0f64;

    for spec in &scenario.streams {
        let sid = reg.attach_stream(spec.clone(), 0.0);
        if schedule_next_arrival(&mut queue, &reg, sid, 0) {
            pending_arrivals += 1;
        }
    }
    for (idx, ev) in scenario.events.iter().enumerate() {
        queue.schedule(ev.at.max(0.0), Ev::Control { idx });
    }
    let tick = controller.as_ref().map(|c| c.interval().max(1e-3));
    if let Some(dt) = tick {
        queue.schedule(dt, Ev::Tick);
    }

    in_flight += dispatch(&mut reg, &mut queue, &mut rng, &mut gate, &mut trace);

    while let Some((now, ev)) = queue.pop() {
        match ev {
            Ev::Arrival { sid, fid } => {
                last_activity = now;
                pending_arrivals = pending_arrivals.saturating_sub(1);
                if schedule_next_arrival(&mut queue, &reg, sid, fid + 1) {
                    pending_arrivals += 1;
                }
                arrival(&mut reg, sid, fid, now, &mut controller, &mut gate, &mut trace);
                in_flight += dispatch(&mut reg, &mut queue, &mut rng, &mut gate, &mut trace);
            }
            Ev::ServiceDone { dev } => {
                last_activity = now;
                in_flight -= 1;
                let (job, service) = reg.pool.complete(dev);
                if let Some(tr) = trace.as_mut() {
                    tr.anns.entry((job.stream, job.fid)).or_default().detect_end = Some(now);
                }
                let n_new = {
                    let s = &mut reg.streams[job.stream];
                    if dev < s.device_busy.len() {
                        s.device_busy[dev] += service;
                        s.device_frames[dev] += 1;
                    }
                    s.resolve(
                        job.fid,
                        Fate::Processed {
                            detections: Vec::new(),
                            device: dev,
                        },
                        now,
                    )
                };
                feed(&mut controller, &reg.streams[job.stream], n_new, now);
                in_flight += dispatch(&mut reg, &mut queue, &mut rng, &mut gate, &mut trace);
            }
            Ev::Control { idx } => {
                last_activity = now;
                pending_controls -= 1;
                let action = scenario.events[idx].action.clone();
                apply_action(
                    &mut reg,
                    &mut queue,
                    action.clone(),
                    now,
                    &mut pending_arrivals,
                    &mut controller,
                );
                control_log.push(ControlRecord {
                    at: now,
                    action,
                    origin: ControlOrigin::Scripted,
                });
                in_flight += dispatch(&mut reg, &mut queue, &mut rng, &mut gate, &mut trace);
            }
            Ev::Tick => {
                let actions = match controller.as_mut() {
                    Some(c) => c.act(now, &reg),
                    None => Vec::new(),
                };
                for action in actions {
                    apply_action(
                        &mut reg,
                        &mut queue,
                        action.clone(),
                        now,
                        &mut pending_arrivals,
                        &mut controller,
                    );
                    control_log.push(ControlRecord {
                        at: now,
                        action,
                        origin: ControlOrigin::Controller,
                    });
                }
                in_flight += dispatch(&mut reg, &mut queue, &mut rng, &mut gate, &mut trace);
                if pending_arrivals > 0 || in_flight > 0 || pending_controls > 0 {
                    queue.schedule_in(tick.expect("tick scheduled only with controller"), Ev::Tick);
                }
            }
        }
    }

    // Frames still windowed when the event queue drains could never be
    // scheduled: a dropped tail, resolved at the end of virtual time
    // (the last real event, not a trailing controller tick).
    let t_end = last_activity;
    for sid in 0..reg.streams.len() {
        let leftover = reg.streams[sid].window.drain_remaining();
        for fid in leftover {
            let n = reg.streams[sid].resolve(fid, Fate::Dropped, t_end);
            feed(&mut controller, &reg.streams[sid], n, t_end);
        }
    }

    // Assemble frame traces: join the synchronizer's record log (one
    // record per arrived frame, with capture/emit times) against the
    // dispatch annotations. Frames that died in the window with no
    // explicit drop mark were drained at shutdown or detach.
    let telemetry = trace.map(|tr| {
        let mut traces: Vec<FrameTrace> = Vec::new();
        for s in &reg.streams {
            for r in s.sync.emitted() {
                let ann = tr
                    .anns
                    .get(&(s.id, r.frame_id))
                    .copied()
                    .unwrap_or_default();
                let dropped = r.was_dropped();
                traces.push(FrameTrace {
                    stream: s.id,
                    frame: r.frame_id,
                    capture: r.capture_ts,
                    admit: r.capture_ts,
                    detect_start: ann.detect_start,
                    detect_end: ann.detect_end,
                    deliver: Some(r.emit_ts),
                    outcome: if dropped {
                        ann.dropped.unwrap_or(TraceOutcome::DroppedDrained)
                    } else {
                        TraceOutcome::Delivered
                    },
                    rung: ann.rung,
                    device: ann.device,
                });
            }
        }
        let mut registry = Registry::new();
        record_traces(&mut registry, &traces);
        RunTelemetry { registry, traces }
    });

    let kinds = reg.pool.kinds();
    let device_labels = reg.pool.labels();
    let device_busy: Vec<f64> = reg.pool.devices().iter().map(|d| d.busy_seconds).collect();
    let device_frames: Vec<u64> = reg.pool.devices().iter().map(|d| d.frames_done).collect();
    let makespan = t_end.max(
        reg.streams
            .iter()
            .map(|s| s.last_resolution)
            .fold(0.0, f64::max),
    );

    let streams = reg
        .streams
        .into_iter()
        .map(|s| {
            let makespan_s = (s.last_resolution - s.attached_at).max(s.spec.duration());
            debug_assert_eq!(
                s.sync.emitted().len() as u64,
                s.arrived,
                "stream {}: record log must cover exactly the arrived frames",
                s.id
            );
            let acc = StreamAccum {
                id: s.id,
                name: s.spec.name.clone(),
                weight: s.spec.weight,
                decision: s.decision,
                records: s.sync.emitted().to_vec(),
                max_reorder_depth: s.sync.max_pending(),
                latency: s.latency,
                device_busy: s.device_busy,
                device_frames: s.device_frames,
                makespan: makespan_s,
                stream_duration: s.spec.duration(),
                rung_log: s.rung_log,
            };
            finish_stream(acc, &kinds)
        })
        .collect();

    FleetRunOutput {
        report: FleetReport {
            streams,
            makespan,
            device_busy,
            device_frames,
            device_labels,
        },
        control_log,
        gate_log: gate.map(|g| g.events).unwrap_or_default(),
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};
    use crate::fleet::admission::Decision;

    fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
        rates
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r)
            })
            .collect()
    }

    fn specs(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
        (0..n)
            .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
            .collect()
    }

    #[test]
    fn every_arrived_frame_gets_exactly_one_record_in_order() {
        let scenario = Scenario::new(devices(&[2.5, 2.5]), specs(3, 10.0, 80, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(7);
        let report = run_fleet(&scenario);
        assert_eq!(report.streams.len(), 3);
        for s in &report.streams {
            assert_eq!(s.records.len(), 80, "stream {}", s.id);
            for (i, r) in s.records.iter().enumerate() {
                assert_eq!(r.frame_id, i as u64);
            }
            assert_eq!(
                s.metrics.frames_processed + s.metrics.frames_dropped,
                s.metrics.frames_total
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let scenario = Scenario::new(devices(&[2.5, 13.5]), specs(4, 8.0, 60, 4)).with_seed(42);
        let a = run_fleet(&scenario);
        let b = run_fleet(&scenario);
        assert_eq!(a.total_processed(), b.total_processed());
        for (sa, sb) in a.streams.iter().zip(&b.streams) {
            assert_eq!(sa.metrics.frames_processed, sb.metrics.frames_processed);
        }
    }

    #[test]
    fn single_stream_single_device_matches_known_drop_shape() {
        // λ=10 vs μ=2.5: the stream keeps ≈ μ/λ of its frames.
        let scenario = Scenario::new(devices(&[2.5]), specs(1, 10.0, 200, 1))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(3);
        let report = run_fleet(&scenario);
        let s = &report.streams[0];
        let sigma = s.metrics.processing_fps();
        assert!((sigma - 2.5).abs() < 0.4, "σ {sigma}");
        assert!(s.metrics.drop_rate() > 0.6, "{}", s.metrics.drop_rate());
    }

    #[test]
    fn rejected_stream_gets_all_dropped_records() {
        // Capacity 2.375 with min_rate 1.0: two 5-FPS streams exhaust it;
        // the third is rejected but still fully recorded.
        let scenario = Scenario::new(devices(&[2.5]), specs(3, 5.0, 50, 4)).with_seed(5);
        let report = run_fleet(&scenario);
        let rejected: Vec<_> = report
            .streams
            .iter()
            .filter(|s| s.decision == Decision::Reject)
            .collect();
        assert!(!rejected.is_empty(), "expected at least one rejection");
        for s in &rejected {
            assert_eq!(s.records.len(), 50);
            assert!(s.records.iter().all(|r| r.was_dropped()));
            assert_eq!(s.metrics.frames_processed, 0);
        }
    }

    #[test]
    fn degraded_stream_processes_roughly_its_share() {
        // One device μ=2.5, one stream λ=5: degrade stride ≈ 3
        // (share 2.375); the stream keeps every 3rd frame and processes
        // nearly all kept frames.
        let scenario = Scenario::new(devices(&[2.5]), specs(1, 5.0, 150, 4)).with_seed(11);
        let report = run_fleet(&scenario);
        let s = &report.streams[0];
        match s.decision {
            Decision::Degrade { stride, .. } => assert_eq!(stride, 3),
            ref other => panic!("expected degrade, got {other:?}"),
        }
        let kept = (0..150u64).filter(|f| f % 3 == 0).count() as u64;
        assert!(
            s.metrics.frames_processed >= kept - 3,
            "processed {} of {kept} kept",
            s.metrics.frames_processed
        );
    }

    #[test]
    fn mid_run_device_attach_raises_throughput() {
        // One device for the first 15s, a second from t=15: processed
        // count lands between the always-1 and always-2 device runs.
        let base = Scenario::new(devices(&[2.5]), specs(1, 10.0, 300, 8))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(9);
        let one = run_fleet(&base);

        let two_late = base.clone().with_events(vec![ControlEvent {
            at: 15.0,
            action: ControlAction::AttachDevice(DeviceInstance::with_rate(
                DeviceKind::Ncs2,
                DetectorModelId::Yolov3,
                1,
                2.5,
            )),
        }]);
        let elastic = run_fleet(&two_late);

        let both = Scenario::new(devices(&[2.5, 2.5]), specs(1, 10.0, 300, 8))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(9);
        let two = run_fleet(&both);

        let (p1, pe, p2) = (
            one.total_processed(),
            elastic.total_processed(),
            two.total_processed(),
        );
        assert!(pe > p1 + 10, "elastic {pe} vs static-1 {p1}");
        assert!(pe < p2, "elastic {pe} vs static-2 {p2}");
    }

    #[test]
    fn mid_run_stream_detach_frees_capacity() {
        // Two streams share one device; stream 0 detaches at t=10, after
        // which stream 1 should process roughly twice as fast.
        let scenario = Scenario::new(devices(&[2.5]), specs(2, 5.0, 150, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(13)
            .with_events(vec![ControlEvent {
                at: 10.0,
                action: ControlAction::DetachStream(0),
            }]);
        let report = run_fleet(&scenario);
        let s0 = &report.streams[0];
        let s1 = &report.streams[1];
        // Detached stream's record log stops at (or shortly after) detach.
        assert!(
            s0.records.len() < 80,
            "detached stream has {} records",
            s0.records.len()
        );
        // Survivor gets more frames through than its pre-detach half share
        // (1.25 FPS × 30 s) would allow.
        assert!(
            s1.metrics.frames_processed > 45,
            "survivor processed {}",
            s1.metrics.frames_processed
        );
    }

    #[test]
    fn mid_run_stream_detach_restores_survivor_admission() {
        // Admission enforced this time: both streams start degraded
        // (share 2.375 < λ = 5); stream 0's departure at t=20 must
        // re-level stream 1 back to full-rate admission mid-run — the
        // detach-re-level path end to end.
        let scenario = Scenario::new(devices(&[2.5, 2.5, 2.5]), specs(2, 5.0, 300, 4))
            .with_seed(19)
            .with_events(vec![ControlEvent {
                at: 20.0,
                action: ControlAction::DetachStream(0),
            }]);
        let report = run_fleet(&scenario);
        let s1 = &report.streams[1];
        assert!(
            matches!(s1.decision, Decision::Admit { .. }),
            "survivor decision {:?}",
            s1.decision
        );
        // Restored at full rate for 2/3 of its life, so it processes far
        // more than the degraded stride-2 share (2.5 FPS × 60 s) alone.
        assert!(
            s1.metrics.frames_processed > 180,
            "survivor processed {}",
            s1.metrics.frames_processed
        );
    }

    #[test]
    fn weighted_streams_split_throughput_by_weight() {
        // Saturated pool, weights 3:1 -> throughput ratio ≈ 3.
        let streams = vec![
            StreamSpec::new("heavy", 10.0, 300).with_window(16).with_weight(3.0),
            StreamSpec::new("light", 10.0, 300).with_window(16).with_weight(1.0),
        ];
        let scenario = Scenario::new(devices(&[2.5, 2.5]), streams)
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(17);
        let report = run_fleet(&scenario);
        let heavy = report.streams[0].metrics.frames_processed as f64;
        let light = report.streams[1].metrics.frames_processed as f64;
        let ratio = heavy / light.max(1.0);
        assert!(ratio > 2.2 && ratio < 3.8, "ratio {ratio}");
    }

    #[test]
    fn model_swap_admission_processes_all_frames_at_lower_cost() {
        // One 2.5-FPS device, one 5-FPS stream. Stride mode keeps every
        // 3rd frame; ladder mode swaps to a 2.6× rung and keeps *all*
        // frames (5/2.6 ≈ 1.92 ≤ share 2.375).
        let ladder = AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]);
        let scenario = Scenario::new(devices(&[2.5]), specs(1, 5.0, 150, 4))
            .with_admission(ladder)
            .with_seed(29);
        let report = run_fleet(&scenario);
        let s = &report.streams[0];
        assert!(
            matches!(s.decision, Decision::SwapModel { rung: 1, stride: 1, .. }),
            "{:?}",
            s.decision
        );
        // Nearly every frame processes: the rung buys back the stride.
        assert!(
            s.metrics.frames_processed >= 140,
            "processed {}",
            s.metrics.frames_processed
        );
        // And the stride-mode baseline processes only ~1/3 as many.
        let stride_run = run_fleet(
            &Scenario::new(devices(&[2.5]), specs(1, 5.0, 150, 4)).with_seed(29),
        );
        assert!(
            stride_run.streams[0].metrics.frames_processed < 60,
            "stride baseline processed {}",
            stride_run.streams[0].metrics.frames_processed
        );
    }

    /// Minimal controller: counts observations and attaches one device
    /// at the first tick after t=10.
    struct ProbeController {
        observed: usize,
        attached: bool,
    }

    impl FleetController for ProbeController {
        fn interval(&self) -> f64 {
            2.0
        }
        fn observe(&mut self, _now: f64, _sid: StreamId, _record: &OutputRecord) {
            self.observed += 1;
        }
        fn act(&mut self, now: f64, reg: &FleetRegistry) -> Vec<ControlAction> {
            if now >= 10.0 && !self.attached {
                self.attached = true;
                return vec![ControlAction::AttachDevice(DeviceInstance::with_rate(
                    DeviceKind::Ncs2,
                    DetectorModelId::Yolov3,
                    reg.pool.len(),
                    2.5,
                ))];
            }
            Vec::new()
        }
    }

    #[test]
    fn gated_quiet_stream_skips_most_frames_and_logs_verdicts() {
        use crate::control::WirePayload;
        // Lobby-quiet dynamics: after the first detection the gate runs
        // skip, skip, forced refresh (cap 2) forever — 2/3 of the frames
        // never cost device time, but every frame still gets a record.
        let scenario = Scenario::new(devices(&[18.0]), specs(1, 15.0, 90, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(21)
            .with_gate(GateConfig::default());
        let out = run_fleet_with(&scenario, None);
        let s = &out.report.streams[0];
        assert_eq!(s.records.len(), 90);
        let skips = out
            .gate_log
            .iter()
            .filter(|e| {
                matches!(
                    e.payload,
                    WirePayload::Gate { verdict: GateVerdict::Skip, .. }
                )
            })
            .count();
        let caps = out
            .gate_log
            .iter()
            .filter(|e| {
                matches!(
                    e.payload,
                    WirePayload::Gate { verdict: GateVerdict::SkipCap, .. }
                )
            })
            .count();
        // Frame 0 detects, then 89 frames in (skip, skip, cap) cycles.
        assert_eq!(skips, 60, "cap log: {caps}");
        assert_eq!(caps, 29);
        assert_eq!(s.metrics.frames_processed, 30);
        // The ungated twin pays a device slot for every frame.
        let plain = {
            let mut sc = scenario.clone();
            sc.gate = None;
            run_fleet(&sc)
        };
        assert_eq!(plain.streams[0].metrics.frames_processed, 90);
        // Deterministic, and the merged wire log replays verbatim.
        let again = run_fleet_with(&scenario, None);
        assert_eq!(again.gate_log, out.gate_log);
        let log = out.wire_log();
        assert_eq!(log.len(), out.gate_log.len());
        let back = EventLog::decode(&log.encode()).expect("replay");
        assert_eq!(back, log);
    }

    #[test]
    fn gated_busy_stream_downrungs_under_pressure() {
        use crate::control::WirePayload;
        use crate::fleet::admission::{AdmissionMode, DegradeMode};
        use crate::gate::MotionDynamics;
        // Highway-busy dynamics never drop below the skip threshold, so
        // the gate's only lever is the pressure down-rung. λ=10 against
        // μ=5 keeps the 4-slot window at the pressure threshold; with a
        // 2.6× rung the down-runged frames drain fast enough to beat
        // the ungated run's throughput.
        let admission = AdmissionPolicy {
            mode: AdmissionMode::AdmitAll,
            degrade: DegradeMode::ModelSwap { speedups: vec![1.0, 2.6] },
            ..AdmissionPolicy::default()
        };
        let gate = GateConfig {
            dynamics: MotionDynamics::highway(),
            ..GateConfig::default()
        };
        let scenario = Scenario::new(devices(&[5.0]), specs(1, 10.0, 200, 4))
            .with_admission(admission)
            .with_seed(23)
            .with_gate(gate);
        let out = run_fleet_with(&scenario, None);
        let downrungs = out
            .gate_log
            .iter()
            .filter(|e| {
                matches!(
                    e.payload,
                    WirePayload::Gate { verdict: GateVerdict::DownRung(1), .. }
                )
            })
            .count();
        assert!(downrungs > 10, "only {downrungs} down-rung verdicts");
        assert!(
            out.gate_log.iter().all(|e| !matches!(
                e.payload,
                WirePayload::Gate { verdict: GateVerdict::Skip, .. }
            )),
            "highway dynamics must never skip"
        );
        let mut plain = scenario.clone();
        plain.gate = None;
        let baseline = run_fleet(&plain);
        assert!(
            out.report.total_processed() > baseline.total_processed() + 20,
            "gated {} vs ungated {}",
            out.report.total_processed(),
            baseline.total_processed()
        );
    }

    #[test]
    fn scene_cut_always_forces_a_fresh_detection() {
        use crate::control::WirePayload;
        use crate::gate::MotionDynamics;
        // Quiet baseline with a cut every 10 frames: each cut must land
        // as a SceneCut verdict (a fresh detection), never a skip.
        let gate = GateConfig::for_dynamics(MotionDynamics {
            base: 0.02,
            jitter: 0.01,
            cut_every: 10,
        });
        let scenario = Scenario::new(devices(&[18.0]), specs(1, 15.0, 60, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(31)
            .with_gate(gate);
        let out = run_fleet_with(&scenario, None);
        let cut_frames: Vec<u64> = out
            .gate_log
            .iter()
            .filter_map(|e| match e.payload {
                WirePayload::Gate { frame, verdict: GateVerdict::SceneCut, .. } => Some(frame),
                _ => None,
            })
            .collect();
        assert_eq!(cut_frames, vec![10, 20, 30, 40, 50]);
        for f in cut_frames {
            assert!(
                !out.report.streams[0].records[f as usize].was_dropped(),
                "cut frame {f} must be freshly detected"
            );
        }
    }

    #[test]
    fn traced_run_covers_every_frame_and_partitions_latency() {
        use crate::telemetry::p99_breakdown;
        let scenario = Scenario::new(devices(&[2.5, 2.5]), specs(2, 10.0, 80, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(7)
            .with_telemetry();
        let out = run_fleet_with(&scenario, None);
        let tel = out.telemetry.as_ref().expect("telemetry requested");
        // Exactly one trace per arrived frame; delivered count agrees
        // with the report.
        assert_eq!(tel.traces.len() as u64, out.report.total_frames());
        let delivered: Vec<_> = tel
            .traces
            .iter()
            .filter(|t| t.outcome == TraceOutcome::Delivered)
            .collect();
        assert_eq!(delivered.len() as u64, out.report.total_processed());
        // Every delivered trace partitions its own e2e latency exactly
        // and knows which device/rung served it.
        for t in &delivered {
            let stages = t.stage_seconds().expect("delivered frames have stages");
            let e2e = t.e2e().expect("delivered frames have e2e");
            assert!(
                (stages.iter().sum::<f64>() - e2e).abs() < 1e-9,
                "stages {stages:?} vs e2e {e2e}"
            );
            assert!(t.device.is_some() && t.rung.is_some());
        }
        // Registry totals agree with the report, and the p99 budget
        // decomposes without residue.
        assert_eq!(
            tel.registry.counter_family_total("eva_frames_total"),
            out.report.total_frames()
        );
        let b = p99_breakdown(&tel.traces).expect("delivered frames exist");
        assert!((b.stages.iter().sum::<f64>() - b.e2e_p99).abs() < 1e-9);
        // Tracing is an observer: the untraced twin reports identically.
        let mut plain = scenario.clone();
        plain.telemetry = false;
        let base = run_fleet_with(&plain, None);
        assert!(base.telemetry.is_none());
        assert_eq!(base.report.total_processed(), out.report.total_processed());
        assert_eq!(base.report.makespan, out.report.makespan);
    }

    #[test]
    fn traced_gated_run_attributes_skips_to_the_gate() {
        // The lobby-quiet gate scenario from above, traced: 60 skipped
        // frames carry the gate drop reason, and joining traces with
        // the wire log buckets every gate-logged frame under "gate".
        let scenario = Scenario::new(devices(&[18.0]), specs(1, 15.0, 90, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(21)
            .with_gate(GateConfig::default())
            .with_telemetry();
        let out = run_fleet_with(&scenario, None);
        let tel = out.telemetry.as_ref().expect("telemetry requested");
        let gate_drops = tel
            .traces
            .iter()
            .filter(|t| t.outcome == TraceOutcome::DroppedGate)
            .count();
        assert_eq!(gate_drops, 60);
        let buckets = crate::telemetry::attribute_latency(&tel.traces, &out.wire_log());
        // 89 frames got a gate verdict (skips + forced refreshes);
        // frame 0's steady Detect is unlogged, so it buckets "none".
        assert_eq!(buckets.get("gate").map(|p| p.len()), Some(89));
        assert_eq!(buckets.get("none").map(|p| p.len()), Some(1));
    }

    #[test]
    fn controller_hook_observes_and_acts() {
        let scenario = Scenario::new(devices(&[2.5]), specs(1, 10.0, 300, 8))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(9);
        let mut ctl = ProbeController { observed: 0, attached: false };
        let out = run_fleet_with(&scenario, Some(&mut ctl));
        // Every record was observed.
        assert_eq!(ctl.observed, 300);
        // The controller's attach is in the log, flagged as unscripted.
        let attaches: Vec<_> = out
            .control_log
            .iter()
            .filter(|r| matches!(r.action, ControlAction::AttachDevice(_)))
            .collect();
        assert_eq!(attaches.len(), 1);
        assert_eq!(attaches[0].origin, ControlOrigin::Controller);
        assert!(attaches[0].at >= 10.0);
        // And the extra capacity shows up as throughput vs the plain run.
        let plain = run_fleet(&scenario);
        assert!(
            out.report.total_processed() > plain.total_processed() + 10,
            "controlled {} vs plain {}",
            out.report.total_processed(),
            plain.total_processed()
        );
    }
}
